"""Assignment-required smoke tests: every arch's REDUCED config runs one
forward + one train step on CPU with finite outputs and correct shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ParallelConfig, ShapeConfig, registry
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.train import optimizer as opt
from repro.train import train_step as ts

S, B = 32, 2


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = registry.get_smoke_config(arch)
    shape = ShapeConfig("smoke", S, B, "train")
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = shd.Plan(mesh, cfg, shape, ParallelConfig(attn_impl="naive"))
    rt = plan.runtime()
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, rt)

    rng = np.random.default_rng(0)
    text = S - cfg.prefix_len
    batch = {
        "tokens": rng.integers(0, cfg.vocab_size, (B, text)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32),
        "loss_mask": np.ones((B, S), np.float32),
    }
    if cfg.enc_dec:
        batch["enc_frames"] = rng.standard_normal(
            (B, S, cfg.d_model)).astype(np.float32) * 0.1
    if cfg.prefix_len:
        batch["prefix_embeds"] = rng.standard_normal(
            (B, cfg.prefix_len, cfg.d_model)).astype(np.float32) * 0.1

    # forward: output shapes + finite
    hidden, _, _ = T.forward(params, cfg, rt,
                             jnp.asarray(batch["tokens"]),
                             prefix_embeds=batch.get("prefix_embeds"),
                             enc_frames=batch.get("enc_frames"))
    assert hidden.shape == (B, S, cfg.d_model)
    logits = T.lm_head(params, cfg, hidden)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    # one train step: loss finite, params updated
    adamw = opt.AdamWConfig(lr=1e-3, warmup=1)
    opt_state = opt.init_opt_state(params, adamw)
    step = jax.jit(ts.make_train_step(cfg, rt, plan.constrain, adamw,
                                      ce_chunk=16))
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), "non-finite loss"
    assert float(metrics["grad_norm"]) > 0
    changed = jax.tree.map(
        lambda a, b: bool((np.asarray(a) != np.asarray(b)).any()),
        params, new_params)
    assert any(jax.tree.leaves(changed)), "no parameter changed"
