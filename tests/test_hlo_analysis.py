"""The trip-count-aware HLO analyzer must reproduce hand-computed FLOPs."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze, parse_hlo


def _compile(fn, *shapes):
    return jax.jit(fn).lower(*shapes).compile()


def test_scan_trip_counts():
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 256), jnp.float32)

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c = _compile(scanned, x, w)
    res = analyze(c.as_text(), 1)
    expect = 2 * 32 * 256 * 256 * 10
    assert abs(res["flops"] - expect) / expect < 1e-6


def test_nested_scans():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 128), jnp.float32)

    def nested(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    c = _compile(nested, x, w)
    res = analyze(c.as_text(), 1)
    expect = 2 * 16 * 128 * 128 * 20
    assert abs(res["flops"] - expect) / expect < 1e-6


def test_remat_counts_recompute():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 128), jnp.float32)

    def loss(x, w):
        def body(c, _):
            return jax.checkpoint(lambda a: jnp.tanh(a @ w))(c), None
        y, _ = jax.lax.scan(body, x, None, length=6)
        return (y.astype(jnp.float32) ** 2).sum()

    c = _compile(jax.grad(loss, argnums=1), x, w)
    res = analyze(c.as_text(), 1)
    one = 2 * 16 * 128 * 128
    # fwd + recompute + 2 bwd matmuls = 4x per layer
    expect = 4 * one * 6
    assert 0.9 * expect < res["flops"] < 1.35 * expect, \
        (res["flops"], expect)


def test_parse_hlo_computations():
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    c = _compile(lambda a: a @ a, x)
    comps = parse_hlo(c.as_text())
    assert any("main" in n for n in comps)
    res = analyze(c.as_text(), 1)
    assert res["flops"] == 2 * 8 * 8 * 8
    assert res["bytes"] > 0
