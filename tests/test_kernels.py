"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "b,s,kh,g,dh,causal,window,cap,dtype",
    [
        (2, 128, 2, 4, 64, True, 0, 0.0, jnp.bfloat16),
        (1, 256, 1, 8, 128, True, 64, 50.0, jnp.bfloat16),
        (2, 128, 4, 1, 64, False, 0, 0.0, jnp.float32),
        (1, 256, 2, 2, 64, True, 128, 0.0, jnp.float32),
        (1, 128, 2, 3, 32, True, 0, 30.0, jnp.bfloat16),  # odd group
    ])
def test_flash_attention(b, s, kh, g, dh, causal, window, cap, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, kh * g, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kh, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kh, dh), jnp.float32).astype(dtype)
    o = flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                        interpret=True, bq=64, bk=64)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=causal,
                        window=window, cap=cap).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


# ---------------------------------------------------------------------------
# rglru
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,w,block", [(2, 64, 32, 16), (1, 128, 64, 32),
                                         (3, 96, 16, 32)])
def test_rglru(b, s, w, block):
    from repro.kernels.rglru.ops import rglru
    from repro.kernels.rglru.ref import rglru_ref
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    log_a = -jnp.abs(jax.random.normal(k1, (b, s, w))) * 0.2 - 1e-3
    gated = jax.random.normal(k2, (b, s, w))
    h = rglru(log_a, gated, block=block, interpret=True)
    href = rglru_ref(log_a, gated)
    np.testing.assert_allclose(np.asarray(h), np.asarray(href), atol=1e-5,
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# ssd (mamba2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [(2, 64, 4, 16, 2, 32, 16),
                                               (1, 48, 2, 8, 1, 16, 16),
                                               (1, 64, 4, 16, 4, 16, 32)])
def test_ssd(b, s, h, p, g, n, chunk):
    from repro.kernels.ssd.ops import ssd
    from repro.kernels.ssd.ref import ssd_ref
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    cc = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    y, st = ssd(x, dt, a, bb, cc, chunk=chunk, interpret=True)
    yref, stref = ssd_ref(x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), a,
                          bb.transpose(0, 2, 1, 3), cc.transpose(0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(yref.transpose(0, 2, 1, 3)),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st),
                               np.asarray(stref.transpose(0, 1, 3, 2)),
                               atol=1e-4, rtol=1e-4)


def test_ssd_matches_model_chunked():
    """kernels/ssd == models/ssm.ssd_chunked (two independent impls)."""
    from repro.kernels.ssd.ops import ssd
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, s, h, p, g, n = 2, 64, 4, 8, 1, 16
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    cc = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    y1, st1 = ssd(x, dt, a, bb, cc, chunk=16, interpret=True)
    y2, st2 = ssd_chunked(x, dt, a, bb, cc, 16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2, np.float32),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), atol=1e-4,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# moe grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,d,e,f,bt", [(64, 32, 4, 64, 8), (32, 16, 2, 32, 8)])
def test_gmm(t, d, e, f, bt):
    from repro.kernels.moe_gmm.kernel import gmm
    from repro.kernels.moe_gmm.ref import gmm_ref
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (e, d, f)) * 0.1
    be = (jnp.arange(t // bt) % e).astype(jnp.int32)
    y = gmm(x, w, be, bt=bt, bf=min(32, f), interpret=True)
    yref = gmm_ref(x, w, be, bt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=1e-4,
                               rtol=1e-4)


def test_moe_ffn_sorted_vs_dense():
    from repro.kernels.moe_gmm.ops import moe_ffn_sorted
    T, D, E, F = 64, 32, 4, 64
    x = jax.random.normal(jax.random.PRNGKey(0), (T, D), jnp.float32)
    eid = jax.random.randint(jax.random.PRNGKey(1), (T,), 0, E)
    wi = jax.random.normal(jax.random.PRNGKey(2), (E, D, F)) * 0.1
    wg = jax.random.normal(jax.random.PRNGKey(3), (E, D, F)) * 0.1
    wo = jax.random.normal(jax.random.PRNGKey(4), (E, F, D)) * 0.1
    ym = moe_ffn_sorted(x, eid, wi, wg, wo, n_experts=E, bt=8, bf=32,
                        interpret=True)
    h = jnp.einsum("td,edf->tef", x, wi)
    g = jnp.einsum("td,edf->tef", x, wg)
    yall = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * h, wo)
    yd = yall[jnp.arange(T), eid]
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yd), atol=1e-4,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# checkpoint codec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,dtype", [(5000, jnp.float32), (2048, jnp.bfloat16),
                                     (1024, jnp.float32)])
def test_ckpt_codec_roundtrip(n, dtype):
    from repro.kernels.ckpt_codec.ops import delta_decode, delta_encode
    base = jax.random.normal(jax.random.PRNGKey(0), (n,)).astype(dtype)
    new = base + (jax.random.normal(jax.random.PRNGKey(1), (n,)) * 0.01
                  ).astype(dtype)
    q, s = delta_encode(new, base, interpret=True)
    dec = delta_decode(q, s, base, shape=(n,), dtype=dtype, interpret=True)
    err = np.abs(np.asarray(dec, np.float32) - np.asarray(new, np.float32))
    # absmax-int8: per-tile error bounded by scale (=absmax/127) + eps
    bound = np.repeat(np.asarray(s)[:, 0], 1024)[:n] + 1e-6
    assert (err <= bound).all()


def test_ckpt_codec_kernel_matches_ref():
    from repro.kernels.ckpt_codec.ops import delta_encode
    from repro.kernels.ckpt_codec.ref import encode_ref
    new = np.random.RandomState(0).randn(4096).astype(np.float32)
    base = new + np.random.RandomState(1).randn(4096).astype(np.float32) * .1
    q, s = delta_encode(jnp.asarray(new), jnp.asarray(base), interpret=True)
    qr, sr = encode_ref(new.reshape(-1, 1024), base.reshape(-1, 1024))
    assert (np.asarray(q) == qr).mean() > 0.999  # rounding ties may differ
    np.testing.assert_allclose(np.asarray(s), sr, rtol=1e-6)
