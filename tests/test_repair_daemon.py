"""Continuous repair daemon + drain-tier rehydration: the single-copy
window between recovery points is closed by a heartbeat-driven
background sweep (rate-limited below foreground I/O), and a checkpoint
shard whose pmem copies all died comes back into the fast tier from its
acked external drain. Plus the monitor satellites: heartbeat first-seen
grace, new-deaths-only check_and_recover, straggler forget."""
import time

import numpy as np
import pytest

from repro.core.dataset_exchange import ack_targets
from repro.core.resilience import StragglerDetector
from repro.core.workflow import JobSpec


def _tree(seed=0, n=64):
    return {"x": np.random.RandomState(seed).randn(n).astype(np.float32)}


def _beat_all(cluster, step=1):
    for nid in cluster.node_ids:
        cluster.heartbeat.beat(nid, step)


def _ckpt_copies(cluster, step, lost):
    """Surviving acked copy-holder sets per shard owner at ``step``."""
    acks = cluster.checkpointer.acks(step)
    rec = cluster.checkpointer._meta_get_json(
        f"ckpt/manifest_step{step}.json")
    out = {}
    for nid in rec.get("nodes") or cluster.node_ids:
        holders = set(ack_targets(acks.get(nid, {}).get("replica")))
        holders.add(nid)
        out[nid] = holders - set(lost)
    return out


def _record_store_reads(cluster):
    """Wrap every store's object-read/probe entry points, recording the
    object names touched. Pool JSON (ack records, catalog records,
    heartbeats) stays unrecorded — metadata reads are always allowed."""
    reads = []

    def wrap(st):
        orig_get, orig_exists = st.get_with_manifest, st.exists

        def get_with_manifest(name, *a, **k):
            reads.append(name)
            return orig_get(name, *a, **k)

        def exists(name, *a, **k):
            reads.append(name)
            return orig_exists(name, *a, **k)
        st.get_with_manifest, st.exists = get_with_manifest, exists

    for st in cluster.stores.values():
        wrap(st)
    return reads


# ---------------------------------------------------------------------------
# satellite: heartbeat first-seen grace window
# ---------------------------------------------------------------------------

def test_heartbeat_grace_for_unbeaten_node(cluster):
    """A node that has never written a heartbeat is NOT dead on sight:
    the monitor gives it a first-seen grace window (a just-joined or
    just-restarted node must not get repaired-around before it ever
    beats). After the window expires unbeaten, it IS dead."""
    hb = cluster.heartbeat
    t0 = time.time()
    assert hb.dead_nodes(30.0, now=t0, grace_s=1.0) == []
    hb.beat("node0", 1)
    assert hb.dead_nodes(30.0, now=t0 + 0.5, grace_s=1.0) == []
    dead = hb.dead_nodes(30.0, now=t0 + 2.0, grace_s=1.0)
    assert dead == ["node1", "node2", "node3"]  # node0 beat in time


def test_heartbeat_grace_cleared_by_first_beat(cluster):
    hb = cluster.heartbeat
    t0 = time.time()
    hb.dead_nodes(30.0, now=t0, grace_s=1.0)  # first-seen clocks start
    _beat_all(cluster)
    assert hb.dead_nodes(30.0, now=t0 + 5.0, grace_s=1.0) == []


def test_heartbeat_dead_pool_bypasses_grace(cluster):
    """An unreachable pmem pool is unambiguously dead — the grace
    window never hides a real node loss."""
    t0 = time.time()
    cluster.heartbeat.dead_nodes(30.0, now=t0, grace_s=30.0)
    cluster.kill_node("node1")
    assert cluster.heartbeat.dead_nodes(
        30.0, now=t0 + 0.01, grace_s=30.0) == ["node1"]


# ---------------------------------------------------------------------------
# satellite: check_and_recover acts on NEW deaths only
# ---------------------------------------------------------------------------

def test_check_and_recover_only_new_deaths(cluster):
    """Polling check_and_recover in a loop (as the daemon's monitor
    does) must restore/repair each loss exactly once, not once per
    poll — and a later NEW death must trigger again with the full
    cumulative dead set."""
    c = cluster
    t = _tree(1)
    c.tiered.save_async(1, t).result(timeout=30)
    c.tiered.quiesce()
    _beat_all(c)
    c.kill_node("node1")
    rec = c.recovery.check_and_recover()
    assert rec is not None and rec[2] == ["node1"]
    assert c.recovery.check_and_recover() is None  # same dead set
    assert c.recovery.check_and_recover() is None
    c.kill_node("node2")
    rec2 = c.recovery.check_and_recover()  # new death re-triggers
    assert rec2 is not None and set(rec2[2]) == {"node1", "node2"}
    np.testing.assert_array_equal(rec2[0]["x"], t["x"])


# ---------------------------------------------------------------------------
# satellite: straggler detector forgets removed nodes
# ---------------------------------------------------------------------------

def test_straggler_detector_forget():
    sd = StragglerDetector(threshold=1.5)
    for _ in range(4):
        sd.record("slow", 10.0)
        sd.record("a", 1.0)
        sd.record("b", 1.0)
    assert sd.stragglers() == ["slow"]
    sd.forget("slow")  # node removed: stale times must stop skewing
    assert sd.stragglers() == []
    sd.forget("slow")  # idempotent on unknown/already-forgotten nodes


def test_straggler_forget_unskews_median():
    """A DEAD fast node's stale times deflate the fleet median and can
    flag a healthy (merely average) survivor forever; forget fixes."""
    sd = StragglerDetector(threshold=1.5)
    for _ in range(4):
        sd.record("fast_dead", 0.1)
        sd.record("fast_dead2", 0.1)
        sd.record("a", 1.0)
        sd.record("b", 1.1)
    assert "b" in sd.stragglers()  # skewed by the dead pair
    sd.forget("fast_dead")
    sd.forget("fast_dead2")
    assert sd.stragglers() == []


# ---------------------------------------------------------------------------
# the daemon: repair BEFORE any recovery point
# ---------------------------------------------------------------------------

def test_daemon_restores_rf_before_recovery_point(cluster):
    """Kill a node with the daemon running and never call
    check_and_recover/resume: the replication factor comes back anyway,
    driven purely by the heartbeat sweep."""
    c = cluster
    c.tiered.save_async(1, _tree(1)).result(timeout=30)
    c.tiered.quiesce()
    _beat_all(c)
    daemon = c.start_repair_daemon(poll_s=0.01)
    c.kill_node("node1")
    assert daemon.wait_for(["node1"], timeout=30)
    report = daemon.report()
    assert report["checkpoint"] == 2  # victim's shard + its buddy's
    assert not report["errors"]
    assert report["handled"] == ["node1"]
    for nid, holders in _ckpt_copies(c, 1, ["node1"]).items():
        assert len(holders) >= 2, (nid, holders)


def test_daemon_idempotent_across_polls(cluster):
    """An already-handled death must not re-trigger sweeps on every
    poll: after convergence the sweep count stays put."""
    c = cluster
    c.tiered.save_async(1, _tree(2)).result(timeout=30)
    c.tiered.quiesce()
    _beat_all(c)
    daemon = c.start_repair_daemon(poll_s=0.005)
    c.kill_node("node1")
    assert daemon.wait_for(["node1"], timeout=30)
    sweeps = daemon.report()["sweeps"]
    time.sleep(0.1)  # ~20 more polls
    assert daemon.report()["sweeps"] == sweeps


# ---------------------------------------------------------------------------
# drain-tier rehydration: back into pmem from the external drain
# ---------------------------------------------------------------------------

def test_drain_rehydration_returns_shard_to_pmem(cluster):
    """Kill every pmem holder of a drained shard: repair stages the
    acked external copy back into a live pool, re-replicates it to a
    fresh buddy and re-acks the pair — drain_only reaches 0."""
    c = cluster
    t = _tree(3)
    c.tiered.save_async(1, t, drain=True).result(timeout=30)
    c.tiered.quiesce()
    # node1's shard lives on node1 (home) + node2 (ring buddy): kill both
    c.kill_node("node1")
    c.kill_node("node2")
    report = c.repair(["node1", "node2"])
    assert report["rehydrated"] == 1
    assert report["drain_only"] == 0 and report["unrepairable"] == 0
    assert not report["errors"]
    targets = ack_targets(c.checkpointer.acks(1)["node1"]["replica"])
    assert targets == ["node0", "node3"]  # two LIVE pmem copies again
    # the bytes really are back in the fast tier: restore reads pmem
    # replicas, newest step, no walking back, no blind probes
    out, man = c.checkpointer.restore_latest_recoverable(
        lost_nodes=["node1", "node2"])
    assert man["step"] == 1
    np.testing.assert_array_equal(out["x"], t["x"])
    assert c.checkpointer.last_restore_stats == \
        {"skipped_by_ack": 0, "probed": 1}


def test_rehydration_disabled_counts_drain_only(cluster):
    """rehydrate=False preserves the PR 4 accounting: the drain-covered
    object is reported, not acted on (the baseline the bench compares
    against)."""
    c = cluster
    c.tiered.save_async(1, _tree(4), drain=True).result(timeout=30)
    c.tiered.quiesce()
    c.kill_node("node1")
    c.kill_node("node2")
    report = c.repair(["node1", "node2"], rehydrate=False)
    assert report["rehydrated"] == 0
    assert report["drain_only"] == 1 and report["unrepairable"] >= 1


def test_rehydration_scan_zero_blind_probes(cluster, monkeypatch):
    """The rehydrating scan stays metadata-only: every store access is
    the source of a raw-path copy actually made (the staged shard
    feeding its new buddy, or a surviving replica being re-replicated)
    — the only external reads are the rehydration sources, and no copy
    ever materializes a tree (the tree-read entry points stay
    untouched)."""
    from repro.core import data_scheduler as ds
    c = cluster
    c.tiered.save_async(1, _tree(5), drain=True).result(timeout=30)
    c.tiered.quiesce()
    c.kill_node("node1")
    c.kill_node("node2")
    c.tiered.quiesce()
    reads = _record_store_reads(c)
    copies = []
    orig_copy = ds.copy_object

    def copy_object(src, dst, name, *a, **k):
        copies.append(name)
        return orig_copy(src, dst, name, *a, **k)
    monkeypatch.setattr(ds, "copy_object", copy_object)
    ext_reads = []
    orig_ext_get = c.external.get
    c.external.get = lambda name: (ext_reads.append(name),
                                   orig_ext_get(name))[1]
    report = c.repair(["node1", "node2"])
    assert report["rehydrated"] == 1 and not report["errors"]
    # one raw-path source copy per repair made (incl. the staged shard
    # copied once to place its buddy), nothing probed, no tree built
    assert len(copies) == len(report["repaired"]), (copies, report)
    assert reads == [], f"tree reads/probes during repair: {reads}"
    for name in copies:
        assert name.startswith(("ckpt/slot", "replica/", "dlm/", "wf/")), \
            f"unexpected copy source during repair: {name}"
    # the single external read is the rehydration source
    assert ext_reads == ["ckpt_step1_node1"]


def test_daemon_rehydrates_drain_only_to_zero(cluster):
    """The acceptance criterion: a double loss strips a drained shard
    of every pmem copy BEFORE the daemon can intervene; once the daemon
    runs, the report converges to drain_only == 0 via rehydration (a
    recovery point never fires)."""
    c = cluster
    c.tiered.save_async(1, _tree(6), drain=True).result(timeout=30)
    c.tiered.quiesce()
    _beat_all(c)
    c.kill_node("node1")
    c.kill_node("node2")  # node1's shard: home + buddy gone, drain left
    daemon = c.start_repair_daemon(poll_s=0.01)
    assert daemon.wait_for(["node1", "node2"], timeout=30)
    report = daemon.report()
    assert report["rehydrated"] >= 1
    assert report["drain_only"] == 0
    for nid, holders in _ckpt_copies(c, 1, ["node1", "node2"]).items():
        assert len(holders) >= 2, (nid, holders)


def test_daemon_sequential_losses_converge(cluster):
    """Losses the daemon handles one at a time never become drain-only
    at all: each sweep restores the replication factor before the next
    loss lands, so the accumulated report still ends at drain_only == 0
    without needing the external tier."""
    c = cluster
    c.tiered.save_async(1, _tree(8), drain=True).result(timeout=30)
    c.tiered.quiesce()
    _beat_all(c)
    daemon = c.start_repair_daemon(poll_s=0.01)
    c.kill_node("node1")
    assert daemon.wait_for(["node1"], timeout=30)
    c.kill_node("node2")
    assert daemon.wait_for(["node1", "node2"], timeout=30)
    report = daemon.report()
    assert report["drain_only"] == 0
    for nid, holders in _ckpt_copies(c, 1, ["node1", "node2"]).items():
        assert len(holders) >= 2, (nid, holders)


# ---------------------------------------------------------------------------
# second loss mid-sweep: re-plan from the acks
# ---------------------------------------------------------------------------

def test_second_loss_mid_sweep_replans(cluster):
    """A membership change while a sweep is running fails some of its
    transfers; the next poll re-plans the cumulative dead set from the
    persisted targets lists and converges — every acked object ends
    with >= 2 surviving copies (or rehydrated from drain)."""
    c = cluster
    c.tiered.save_async(1, _tree(7), drain=True).result(timeout=30)
    for k in range(6):
        c.tiered.offload(f"serve/s{k}", _tree(10 + k)).result(timeout=30)
    c.tiered.quiesce()
    _beat_all(c)
    # max_inflight=1 stretches the sweep so the second kill lands mid-way
    daemon = c.start_repair_daemon(poll_s=0.005, max_inflight=1)
    c.kill_node("node1")
    c.kill_node("node2")
    assert daemon.wait_for(["node1", "node2"], timeout=60)
    lost = {"node1", "node2"}
    for nid, holders in _ckpt_copies(c, 1, lost).items():
        assert len(holders) >= 2, (nid, holders)
    for name, rec in c.tiered.dlm_acks.objects().items():
        holders = ({rec["home"]} | set(ack_targets(rec))) - lost
        assert len(holders) >= 2, (name, rec)
    out, man = c.checkpointer.restore_latest_recoverable(
        lost_nodes=sorted(lost))
    assert man["step"] == 1
    np.testing.assert_array_equal(out["x"], _tree(7)["x"])


# ---------------------------------------------------------------------------
# rate limiting: the token/backlog budget bounds repair concurrency
# ---------------------------------------------------------------------------

def test_rate_limiter_bounds_concurrent_repair_tasks(cluster):
    c = cluster
    for k in range(8):
        c.tiered.offload(f"serve/s{k}", _tree(20 + k)).result(timeout=30)
    c.tiered.quiesce()
    c.kill_node("node0")  # DLM home: all 8 objects need repair
    c.tiered.quiesce()
    outstanding = []
    peak = [0]
    orig = c.scheduler.replicate

    def tracked(*a, **k):
        fut = orig(*a, **k)
        outstanding.append(fut)
        peak[0] = max(peak[0],
                      sum(1 for f in outstanding if not f.done()))
        return fut
    c.scheduler.replicate = tracked
    report = c.tiered.repair(["node0"], max_inflight=2)
    assert report["dlm"] == 8 and not report["errors"]
    assert report["peak_inflight"] <= 2
    assert peak[0] <= 2, f"budget exceeded: {peak[0]} concurrent tasks"


def test_repair_runs_at_background_priority(cluster):
    """priority passes through to the scheduler so daemon repairs rank
    below every foreground channel."""
    c = cluster
    c.tiered.offload("serve/s", _tree(30)).result(timeout=30)
    c.tiered.quiesce()
    c.kill_node("node0")
    c.tiered.quiesce()
    prios = []
    orig = c.scheduler.replicate

    def tracked(*a, **k):
        prios.append(k.get("priority", 2))
        return orig(*a, **k)
    c.scheduler.replicate = tracked
    report = c.tiered.repair(["node0"], priority=4)
    assert report["dlm"] == 1
    assert prios and all(p == 4 for p in prios)


# ---------------------------------------------------------------------------
# recovery points consult the daemon's ledger instead of re-scanning
# ---------------------------------------------------------------------------

def test_resume_consults_daemon_ledger(cluster):
    c = cluster
    calls = {"n": 0}

    def fn(ctx):
        calls["n"] += 1
        return {"da": _tree(40)}
    jobs = [JobSpec("p", fn, retain=("da",))]
    c.workflows.run(jobs, workflow="wfD")
    c.tiered.quiesce()
    _beat_all(c)
    victim = c.catalog.record("da", "wfD")["home"]
    daemon = c.start_repair_daemon(poll_s=0.01)
    c.kill_node(victim)
    assert daemon.wait_for([victim], timeout=30)
    n_rescans = {"n": 0}
    orig = c.tiered.repair

    def counted(*a, **k):
        n_rescans["n"] += 1
        return orig(*a, **k)
    c.tiered.repair = counted
    res = c.workflows.resume(jobs, "wfD", lost_nodes=[victim])
    assert n_rescans["n"] == 0  # ledger used, no fresh scan
    assert res.repair_report.get("sweeps", 0) >= 1
    assert calls["n"] == 1 and res.replayed == []  # and no replays
    rec = c.catalog.record("da", "wfD")
    holders = ({rec["home"]} | set(ack_targets(
        rec["acks"]["replica"]))) - {victim}
    assert len(holders) >= 2


def test_check_and_recover_uses_daemon_ledger(cluster):
    c = cluster
    state = _tree(41)
    c.tiered.save_async(2, state).result(timeout=30)
    c.tiered.quiesce()
    _beat_all(c, step=2)
    daemon = c.start_repair_daemon(poll_s=0.01)
    c.kill_node("node1")
    assert daemon.wait_for(["node1"], timeout=30)
    n_rescans = {"n": 0}
    orig = c.tiered.repair

    def counted(*a, **k):
        n_rescans["n"] += 1
        return orig(*a, **k)
    c.tiered.repair = counted
    tree, manifest, dead = c.recovery.check_and_recover()
    assert dead == ["node1"]
    np.testing.assert_array_equal(tree["x"], state["x"])
    assert n_rescans["n"] == 0
    assert c.recovery.last_repair_report.get("sweeps", 0) >= 1
    assert c.recovery.last_repair_report["checkpoint"] == 2


def test_serve_repair_uses_daemon_ledger(cluster):
    from repro.serve.engine import ServeEngine
    c = cluster
    c.tiered.offload("serve/sess", _tree(42)).result(timeout=30)
    c.tiered.quiesce()
    _beat_all(c)
    daemon = c.start_repair_daemon(poll_s=0.01)
    c.kill_node("node0")
    assert daemon.wait_for(["node0"], timeout=30)
    eng = ServeEngine.__new__(ServeEngine)  # wiring-only: no model
    eng.tiered = c.tiered
    report = eng.repair(["node0"])
    assert report.get("sweeps", 0) >= 1 and report["dlm"] >= 1
