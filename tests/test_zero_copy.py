"""Zero-copy byte-range data plane: raw pmem->pmem copy, byte-range
leaf reads, crash-state enumeration of the copy path, and the delta-int8
wire codec on the replicate/drain channels (ROADMAP item 4)."""
import tempfile
from pathlib import Path

import numpy as np
import pytest

from repro.core.object_store import (PMemObjectStore, content_digest,
                                     copy_object, export_object,
                                     import_object, wire_tree)
from repro.core.pmem import PMemPool


def _tree(seed=0, n=256):
    r = np.random.RandomState(seed)
    return {"layer": {"w": r.randn(n, 8).astype(np.float32),
                      "b": r.randn(8).astype(np.float32)},
            "ids": np.arange(n, dtype=np.int32)}


def _qtree(seed=0, n=2048):
    """Quantization-friendly state: integer-grid float leaves survive
    the strict delta-int8 codec bit-exactly (scale snaps to 1.0), so
    these trees actually travel encoded rather than falling back to
    raw per leaf."""
    r = np.random.RandomState(seed)
    return {"layer": {"w": r.randint(-100, 100, (n, 8))
                      .astype(np.float32),
                      "b": r.randn(8).astype(np.float32)},
            "ids": np.arange(n, dtype=np.int32)}


def _two_stores(tmp_path):
    pools = {n: PMemPool(Path(tmp_path), n) for n in ("a", "b")}
    return {n: PMemObjectStore(p) for n, p in pools.items()}


# ---------------------------------------------------------------------------
# tentpole layer 1: raw copy path + byte-range reads
# ---------------------------------------------------------------------------

def test_copy_object_commits_source_manifest_verbatim(tmp_path):
    st = _two_stores(tmp_path)
    tree = _tree(1)
    man_src = st["a"].put("obj", tree, meta={"step": 7})
    man_dst = copy_object(st["a"], st["b"], "obj",
                          expect_meta={"step": 7})
    # leaf table verbatim: same CRCs, offsets, shapes — no recompute
    assert man_dst["leaves"] == man_src["leaves"]
    assert man_dst["nbytes"] == man_src["nbytes"]
    assert man_dst["meta"]["step"] == 7
    assert content_digest(man_dst) == content_digest(man_src)
    out = st["b"].get("obj", verify=True)
    np.testing.assert_array_equal(out["layer"]["w"], tree["layer"]["w"])
    np.testing.assert_array_equal(out["ids"], tree["ids"])


def test_copy_object_never_materializes_a_tree(tmp_path, monkeypatch):
    """The acceptance-criteria audit in unit form: the pmem->pmem raw
    path must never invoke _flatten/_unflatten."""
    import repro.core.object_store as mod
    st = _two_stores(tmp_path)
    st["a"].put("obj", _tree(2))
    calls = []
    orig_f, orig_u = mod._flatten, mod._unflatten
    monkeypatch.setattr(mod, "_flatten",
                        lambda *a, **k: calls.append("flatten")
                        or orig_f(*a, **k))
    monkeypatch.setattr(mod, "_unflatten",
                        lambda *a, **k: calls.append("unflatten")
                        or orig_u(*a, **k))
    copy_object(st["a"], st["b"], "obj")
    assert calls == [], f"tree materialized on the raw path: {calls}"


def test_get_leaf_reads_one_leaf_without_touching_siblings(tmp_path):
    st = _two_stores(tmp_path)["a"]
    tree = _tree(3)
    st.put("obj", tree)
    np.testing.assert_array_equal(st.get_leaf("obj", "layer/b"),
                                  tree["layer"]["b"])
    # corrupt a SIBLING leaf: the byte-range read of the healthy leaf
    # must still succeed (it never maps the sibling's range) while the
    # whole-object read fails its CRC
    man = st.manifest("obj")
    region = st.pool.open("objects/obj@v0.data")
    region._mm[man["leaves"]["ids"]["offset"] + 1] ^= 0xFF
    np.testing.assert_array_equal(st.get_leaf("obj", "layer/w"),
                                  tree["layer"]["w"])
    with pytest.raises(IOError):
        st.get("obj", verify=True)
    with pytest.raises(IOError):
        st.get_leaf("obj", "ids")
    with pytest.raises(KeyError):
        st.get_leaf("obj", "nope")


def test_read_leaf_slice_returns_owned_copy(tmp_path):
    """Regression (live-memmap-view bug): a slice held across an
    overwrite of the same object must keep its original bytes."""
    st = _two_stores(tmp_path)["a"]
    arr = np.arange(64, dtype=np.float32).reshape(16, 4)
    st.put("obj", {"x": arr})
    sl = st.read_leaf_slice("obj", "x", 4, 3)
    leaf = st.get_leaf("obj", "x")
    st.put("obj", {"x": np.zeros_like(arr)})  # slot-reuse analogue
    np.testing.assert_array_equal(sl, arr[4:7])
    np.testing.assert_array_equal(leaf, arr)


def test_get_with_manifest_verify_crc_over_read_buffer(tmp_path):
    """Regression (double-materialization fix): verify still catches a
    flipped byte when the CRC runs directly over the read buffer."""
    st = _two_stores(tmp_path)["a"]
    st.put("obj", _tree(4))
    region = st.pool.open("objects/obj@v0.data")
    region._mm[5] ^= 0xFF
    with pytest.raises(IOError):
        st.get_with_manifest("obj", verify=True)


def test_copy_superseded_source_is_benign(tmp_path):
    from repro.core.object_store import SupersededError
    st = _two_stores(tmp_path)
    st["a"].put("obj", _tree(5), meta={"step": 1})
    st["a"].put("obj", _tree(6), meta={"step": 2})  # overwritten
    with pytest.raises(SupersededError):
        copy_object(st["a"], st["b"], "obj", expect_meta={"step": 1})
    assert not st["b"].exists("obj")


# ---------------------------------------------------------------------------
# satellite: crash mid-copy — partial replica never committed nor acked
# ---------------------------------------------------------------------------

def test_crash_mid_copy_never_commits_partial_replica(pmem_sanitizer):
    """Enumerate every crash state of the copy's destination writes:
    the chunks stream into a shadow region no manifest ever references,
    so a crash at ANY write leaves the destination store without the
    object — and a commit-point failure propagates without an ack."""
    tmp = Path(tempfile.mkdtemp(prefix="repro_zc_"))
    st = _two_stores(tmp)
    st["a"].put("obj", _tree(7), meta={"step": 3})
    acked = []
    orig = st["b"].pool.put_json

    def failing_put_json(name, obj):
        if name.endswith(".manifest"):
            raise IOError("injected crash at the commit point")
        return orig(name, obj)

    st["b"].pool.put_json = failing_put_json
    with pytest.raises(IOError):
        man = copy_object(st["a"], st["b"], "obj",
                          expect_meta={"step": 3}, chunk_bytes=1024)
        acked.append(man)  # never reached: ack hooks run after commit
    st["b"].pool.put_json = orig
    assert acked == []
    assert not st["b"].exists("obj")
    # every torn/lost/persisted image of the destination writes lives
    # under a shadow name — materializing it cannot make the object
    # visible because visibility IS the manifest commit
    images = [(label, img) for label, img
              in pmem_sanitizer.crash_images("b/objects/obj")
              if ".data" in label]
    assert images, "no destination write states captured"
    for label, img in images:
        assert ".shadow" in label
        pmem_sanitizer.materialize(img, st["b"].pool,
                                   "objects/obj@v0.data")
        assert not st["b"].exists("obj"), label
    # the copy retries cleanly after the crash and commits whole
    man = copy_object(st["a"], st["b"], "obj", expect_meta={"step": 3})
    out = st["b"].get("obj", verify=True)
    np.testing.assert_array_equal(out["layer"]["w"],
                                  _tree(7)["layer"]["w"])
    assert man["meta"]["step"] == 3


def test_failed_replicate_records_no_ack(cluster):
    """Channel-level version of the same invariant: a replicate whose
    destination pool dies mid-task must not land an ack."""
    t = cluster.tiered.save_async(1, _tree(8))
    t.result(timeout=30)
    cluster.tiered.quiesce()
    buddy = cluster.checkpointer.buddy_of("node0")
    cluster.kill_node(buddy)
    fut = cluster.scheduler.replicate(
        "node0", "ckpt/slot0", buddy, expect_meta={"step": 1},
        on_complete=lambda man: pytest.fail("acked a dead-pool copy"))
    with pytest.raises(IOError):
        fut.result(timeout=30)


# ---------------------------------------------------------------------------
# tentpole layer 4: codec on the wire
# ---------------------------------------------------------------------------

def test_copy_encoded_roundtrips_bit_exact(tmp_path):
    st = _two_stores(tmp_path)
    tree = _qtree(9)
    st["a"].put("obj", tree)
    man = copy_object(st["a"], st["b"], "obj", codec=True)
    wc = man["meta"]["wire_codec"]
    modes = {p: e["mode"] for p, e in wc["leaves"].items()}
    assert modes["layer/w"] == "delta8"  # big float leaf encodes
    assert wc["nbytes_encoded"] < man["nbytes"]
    # original digests survive the encoding: acks/repair stay
    # encoding-invariant
    assert man["leaves"] == st["a"].manifest("obj")["leaves"]
    out = st["b"].get("obj", verify=True)
    for path in ("layer/w", "layer/b"):
        a, b = path.split("/")
        np.testing.assert_array_equal(out[a][b], tree[a][b])
    np.testing.assert_array_equal(out["ids"], tree["ids"])
    # byte-range reads decode only the covering tiles
    np.testing.assert_array_equal(
        st["b"].read_leaf_slice("obj", "layer/w", 100, 17),
        tree["layer"]["w"][100:117])
    np.testing.assert_array_equal(st["b"].get_leaf("obj", "layer/w"),
                                  tree["layer"]["w"])


def test_second_hop_copy_never_double_encodes(tmp_path):
    pools = {n: PMemPool(Path(tmp_path), n) for n in ("a", "b", "c")}
    st = {n: PMemObjectStore(p) for n, p in pools.items()}
    tree = _qtree(10)
    st["a"].put("obj", tree)
    man1 = copy_object(st["a"], st["b"], "obj", codec=True)
    man2 = copy_object(st["b"], st["c"], "obj", codec=True)
    # the encoded segment table raw-streams verbatim
    assert man2["meta"]["wire_codec"]["leaves"] == \
        man1["meta"]["wire_codec"]["leaves"]
    out = st["c"].get("obj", verify=True)
    np.testing.assert_array_equal(out["layer"]["w"], tree["layer"]["w"])


def test_export_import_roundtrip_codec_on_and_off(tmp_path):
    st = _two_stores(tmp_path)
    tree = _qtree(11)
    st["a"].put("obj", tree, meta={"step": 4})
    for codec in (None, True):
        wire = export_object(st["a"], "obj", expect_meta={"step": 4},
                             codec=codec)
        dec = wire_tree(wire)
        np.testing.assert_array_equal(dec["layer"]["w"],
                                      tree["layer"]["w"])
        man = import_object(st["b"], wire, "staged")
        out = st["b"].get("staged", verify=True)
        np.testing.assert_array_equal(out["layer"]["w"],
                                      tree["layer"]["w"])
        np.testing.assert_array_equal(out["ids"], tree["ids"])
        assert man["leaves"] == st["a"].manifest("obj")["leaves"]


def test_import_rejects_corrupt_wire_bytes(tmp_path):
    st = _two_stores(tmp_path)
    st["a"].put("obj", _tree(12))
    wire = export_object(st["a"], "obj")
    path = next(iter(wire["leaves"]))
    blob = bytearray(wire["leaves"][path]["data"])
    blob[0] ^= 0xFF
    wire["leaves"][path]["data"] = bytes(blob)
    with pytest.raises(IOError):
        import_object(st["b"], wire, "staged")
    assert not st["b"].exists("staged")


# ---------------------------------------------------------------------------
# cluster-level: codec-on channels, partial restore, fetch_leaf
# ---------------------------------------------------------------------------

@pytest.fixture()
def cluster_codec():
    from repro.core.cluster import SimCluster
    root = Path(tempfile.mkdtemp(prefix="repro_test_"))
    c = SimCluster(root, n_nodes=4, wire_codec=True)
    yield c
    c.shutdown()


def test_codec_cluster_replicate_restore_bit_equal(cluster_codec):
    c = cluster_codec
    state = _qtree(13)
    t = c.tiered.save_async(1, state, drain=True)
    t.result(timeout=30)
    c.tiered.quiesce()
    assert t.durability() == "DRAINED"
    c.kill_node("node1")
    out, man = c.checkpointer.restore(1, lost_nodes=["node1"])
    np.testing.assert_array_equal(out["layer"]["w"], state["layer"]["w"])
    np.testing.assert_array_equal(out["ids"], state["ids"])
    # the replica that served node1's shard really is encoded
    holder = c.checkpointer.buddy_of("node1")
    rep_man = c.stores[holder].manifest("replica/node1/ckpt/slot0")
    assert "wire_codec" in rep_man["meta"]


def test_codec_drain_rehydrates_bit_equal(cluster_codec):
    c = cluster_codec
    state = _qtree(14)
    t = c.tiered.save_async(2, state, drain=True)
    t.result(timeout=30)
    c.tiered.quiesce()
    fut = c.scheduler.stage_in("node2", "ckpt_step2_node0",
                               "staged/shard0")
    fut.result(timeout=30)
    staged_man = c.stores["node2"].manifest("staged/shard0")
    assert staged_man["meta"]["step"] == 2
    out = c.stores["node2"].get("staged/shard0", verify=True)
    flat_w = out["layer"]["w"]
    own = c.stores["node0"].get("ckpt/slot0")["layer"]["w"]
    np.testing.assert_array_equal(flat_w, own)


def test_restore_leaves_partial(cluster):
    state = _tree(15, n=512)
    cluster.checkpointer.save(1, state)
    cluster.checkpointer.wait_async()
    cluster.tiered.quiesce()
    out = cluster.checkpointer.restore_leaves(1, ["layer/w"])
    assert set(out) == {"layer/w"}
    np.testing.assert_array_equal(out["layer/w"], state["layer"]["w"])
    with pytest.raises(KeyError):
        cluster.checkpointer.restore_leaves(1, ["nope"])
    # partial restore over a lost node rides the replica byte ranges
    cluster.kill_node("node2")
    out = cluster.checkpointer.restore_leaves(1, ["ids"],
                                              lost_nodes=["node2"])
    np.testing.assert_array_equal(out["ids"], state["ids"])


def test_fetch_leaf_home_and_replica_fallback(cluster):
    obj = {"cache": {"k": np.arange(32, dtype=np.float32)},
           "pos": np.int32(17)}
    cluster.tiered.offload("sess", obj).result(timeout=30)
    cluster.tiered.quiesce()
    # evict DRAM residency so the read exercises the pmem byte range
    cluster.tiered.evict_cold(0.0)
    np.testing.assert_array_equal(
        cluster.tiered.fetch_leaf("sess", "cache/k"), obj["cache"]["k"])
    assert int(cluster.tiered.fetch_leaf("sess", "pos")) == 17
    # home node dies: the leaf comes off the acked replica
    cluster.kill_node("node0")
    np.testing.assert_array_equal(
        cluster.tiered.fetch_leaf("sess", "cache/k"), obj["cache"]["k"])
