"""TieredIO engine: async saves, crash-mid-drain safety, prefetch
accounting, cold eviction, and the mesh version-compat helper."""
import time

import numpy as np
import pytest


def _tree(seed=0):
    r = np.random.RandomState(seed)
    return {"w": r.randn(16, 8).astype(np.float32),
            "b": r.randn(8).astype(np.float32)}


# ---------------------------------------------------------------------------
# checkpoint channel
# ---------------------------------------------------------------------------

def test_save_async_completes_and_restores(cluster):
    t = _tree(1)
    ticket = cluster.tiered.save_async(1, t)
    man = ticket.result(timeout=30)
    assert man["step"] == 1
    assert ticket.wait_post_commit(timeout=30) == []
    out, man2 = cluster.checkpointer.restore()
    assert man2["step"] == 1
    np.testing.assert_array_equal(out["w"], t["w"])


def test_save_async_overlaps_and_slots_stay_safe(cluster):
    """Three overlapping saves reuse slot 0 for steps 1 and 3; FIFO
    ordering + backpressure must keep every committed manifest readable."""
    trees = {s: _tree(s) for s in (1, 2, 3)}
    tickets = [cluster.tiered.save_async(s, trees[s]) for s in (1, 2, 3)]
    for tk in tickets:
        tk.result(timeout=30)
    # step-1's background replicate may race step-3's reuse of its slot
    # and fail; that is collected, never raised, and harmless — the
    # replica would be invalid anyway (its source slot was rewritten).
    cluster.tiered.quiesce()
    # two slots -> the last two steps are restorable, bit-exact
    for s in (2, 3):
        out, man = cluster.checkpointer.restore(s)
        assert man["step"] == s
        np.testing.assert_array_equal(out["w"], trees[s]["w"])
    assert cluster.checkpointer.latest_step() == 3


def test_submit_returns_before_drain_completes(cluster_slow_external):
    """save_async must not pay for the external tier: the submit returns
    while the throttled drain is still in flight."""
    c = cluster_slow_external
    t0 = time.perf_counter()
    ticket = c.tiered.save_async(1, _tree(2), drain=True)
    submit_s = time.perf_counter() - t0
    ticket.result(timeout=30)
    assert submit_s < 0.5  # drain of ~0.5MB at 1MB/s would take ~0.5s+
    assert ticket.wait_post_commit(timeout=60) == []
    assert c.external.exists("ckpt_step1_node0")


def test_crash_mid_drain_keeps_previous_manifest(cluster):
    """A failing drain (external tier dies mid-flush) must surface on the
    ticket without touching the committed node-local checkpoint."""
    c = cluster
    c.tiered.save_async(1, _tree(3)).result(timeout=30)
    c.tiered.quiesce()

    def boom(name, tree):
        raise IOError("external store died mid-drain")
    c.external.put = boom
    ticket = c.tiered.save_async(2, _tree(4), drain=True)
    ticket.result(timeout=30)  # node-local commit is unaffected
    errors = ticket.wait_post_commit(timeout=30)
    assert errors and all("mid-drain" in str(e) for e in errors)
    # both checkpoints still restorable from pmem
    assert c.checkpointer.latest_step() == 2
    out, _ = c.checkpointer.restore(2)
    np.testing.assert_array_equal(out["w"], _tree(4)["w"])
    out, _ = c.checkpointer.restore(1)
    np.testing.assert_array_equal(out["w"], _tree(3)["w"])


def test_raise_if_failed_surfaces_commit_errors(cluster):
    """A failed checkpoint COMMIT must surface at the next checkpoint
    boundary (the loop calls raise_if_failed), not at shutdown hours
    later."""
    c = cluster

    def boom(*a, **k):
        raise MemoryError("pmem full")
    c.checkpointer.save = boom
    t = c.tiered.save_async(1, _tree(0))
    with pytest.raises(MemoryError):
        t.result(timeout=30)
    with pytest.raises(MemoryError):
        c.tiered.raise_if_failed()
    c.tiered.quiesce()  # collected errors cleared; engine reusable


def test_quiesce_swallows_inflight_errors_for_recovery(cluster):
    c = cluster
    c.tiered.save_async(1, _tree(5)).result(timeout=30)

    def boom(name, tree):
        raise IOError("dead node")
    c.external.put = boom
    c.tiered.save_async(2, _tree(6), drain=True)
    errors = c.recovery.quiesce_inflight()
    assert errors, "drain failure must be collected"
    assert c.recovery.inflight_errors
    # recovery still proceeds off the committed manifests
    out, man = c.checkpointer.restore_latest_recoverable()
    assert man["step"] == 2


def test_restore_latest_recoverable_falls_back(cluster):
    """If the newest checkpoint's shards died with a node before
    replication, recovery must fall back to the previous step."""
    c = cluster
    c.tiered.save_async(1, _tree(7)).result(timeout=30)
    c.tiered.quiesce()  # step-1 replicas are all placed
    victim = c.node_ids[-1]
    # step 2 commits, then the victim dies before its replica lands:
    # emulate by dropping both the victim's shard and its replica.
    man2 = c.tiered.save_async(2, _tree(8)).result(timeout=30)
    c.tiered.quiesce()
    slot2 = man2["slot"]
    c.stores[victim].delete(f"ckpt/slot{slot2}")
    c.stores[c.checkpointer.buddy_of(victim)].delete(
        f"replica/{victim}/ckpt/slot{slot2}")
    out, man = c.checkpointer.restore_latest_recoverable(
        lost_nodes=[victim])
    assert man["step"] == 1
    np.testing.assert_array_equal(out["w"], _tree(7)["w"])


def test_slot_rotation_even_stride(cluster):
    """Even checkpoint strides (e.g. ckpt_every=2) must still alternate
    shadow slots — raw step % slots would pin every save to slot 0."""
    m2 = cluster.checkpointer.save(2, _tree(2))
    m4 = cluster.checkpointer.save(4, _tree(4))
    assert m2["slot"] != m4["slot"]
    cluster.checkpointer.wait_async()
    for s in (2, 4):
        out, _ = cluster.checkpointer.restore(s)
        np.testing.assert_array_equal(out["w"], _tree(s)["w"])


def test_restore_rejects_reused_slot(cluster):
    """An old manifest pointing at a slot a newer save overwrote must
    raise, not silently return mixed-step data."""
    c = cluster
    for s in (1, 2, 3):  # slots: 0, 1, 0 — step 1's slot now holds step 3
        c.checkpointer.save(s, _tree(s))
    c.checkpointer.wait_async()
    with pytest.raises(IOError):
        c.checkpointer.restore(1)


def test_delta_chain_never_overwrites_base(cluster_delta):
    """Slot rotation must skip the slot holding the active delta base —
    otherwise the third delta save destroys the base and orphans every
    delta checkpoint in the chain."""
    c = cluster_delta
    base = _tree(1)
    c.checkpointer.save(1, base)  # full
    for s in (2, 3, 4):  # three deltas against the same base
        t = {k: v + np.float32(1e-3) for k, v in base.items()}
        man = c.checkpointer.save(s, t, base_step=1)
        assert man["slot"] != 0, "delta save rotated onto the base slot"
    c.checkpointer.wait_async()
    out, man = c.checkpointer.restore(4)
    assert man["delta_base"] == 1
    assert np.abs(out["w"] - (base["w"] + 1e-3)).max() < 1e-4


def test_checkpoint_index_survives_node0_loss(cluster):
    """Manifests are replicated to every live pool, so losing the first
    node (the old single meta store) keeps the index readable and
    subsequent saves land on the survivors."""
    c = cluster
    c.tiered.save_async(1, _tree(1)).result(timeout=30)
    c.tiered.quiesce()
    c.kill_node("node0")
    assert c.checkpointer.latest_step() == 1
    out, man = c.checkpointer.restore_latest_recoverable(
        lost_nodes=["node0"])
    assert man["step"] == 1
    np.testing.assert_array_equal(out["w"], _tree(1)["w"])
    # the survivors keep checkpointing
    man2 = c.checkpointer.save(2, _tree(2))
    assert "node0" not in man2["nodes"]
    c.checkpointer.wait_async()
    out, _ = c.checkpointer.restore(2)
    np.testing.assert_array_equal(out["w"], _tree(2)["w"])


# ---------------------------------------------------------------------------
# object / prefetch channel
# ---------------------------------------------------------------------------

def test_offload_fetch_prefetch_accounting(cluster):
    t = _tree(9)
    cluster.tiered.offload("serve/sessA", t).result(timeout=30)
    # resident -> prefetch hit
    res = cluster.tiered.prefetch(["serve/sessA"]).result(timeout=30)
    assert res == {"hits": 1, "loads": 0, "missing": 0}
    # evict everything, then prefetch must load from pmem
    assert cluster.tiered.evict_cold() >= 1
    res = cluster.tiered.prefetch(["serve/sessA"]).result(timeout=30)
    assert res == {"hits": 0, "loads": 1, "missing": 0}
    # demand fetch is now a DRAM hit
    h0 = cluster.dlm.hits
    out = cluster.tiered.fetch("serve/sessA")
    np.testing.assert_array_equal(out["w"], t["w"])
    assert cluster.dlm.hits == h0 + 1
    assert cluster.tiered.stats["prefetch_hits"] == 1
    assert cluster.tiered.stats["prefetch_loads"] == 1


def test_prefetch_missing_object_is_advisory(cluster):
    """Prefetch is a hint: an object absent from pmem is counted, never
    raised, and must not poison the rest of the batch or a later join."""
    cluster.tiered.offload("serve/x", _tree(0)).result(timeout=30)
    cluster.tiered.evict_cold()
    res = cluster.tiered.prefetch(
        ["serve/never-written", "serve/x"]).result(timeout=30)
    assert res == {"hits": 0, "loads": 1, "missing": 1}
    cluster.tiered.join()  # nothing fatal was recorded


def test_evict_cold_respects_idle_threshold(cluster):
    cluster.tiered.offload("serve/hot", _tree(1)).result(timeout=30)
    # nothing is older than an hour
    assert cluster.tiered.evict_cold(max_idle_s=3600.0) == 0
    assert cluster.tiered.evict_cold(max_idle_s=0.0) == 1


def test_stage_in_hit_rate(cluster):
    c = cluster
    for i in range(3):
        c.external.put(f"shard{i}", {"x": np.arange(i + 1)})
    futs = c.tiered.stage_in("node0", ["shard0", "shard1"])
    for f in futs:
        f.result(timeout=30)
    futs = c.tiered.stage_in("node0", ["shard0", "shard1", "shard2"])
    for f in futs:
        f.result(timeout=30)
    assert c.tiered.stats["stage_in_hits"] == 2
    assert c.tiered.stats["stage_in_loads"] == 3
    assert abs(c.tiered.stage_in_hit_rate() - 0.4) < 1e-9


# ---------------------------------------------------------------------------
# serve-engine integration: spill/resume/prefetch through TieredIO
# ---------------------------------------------------------------------------

def test_serve_spill_resume_via_tiered(cluster):
    from repro.serve.engine import ServeEngine
    eng = ServeEngine.__new__(ServeEngine)  # no model needed for spill
    eng.tiered = cluster.tiered
    eng.store = None
    eng.cache = {"k": np.ones((2, 4), np.float32)}
    eng.pos = 7
    eng.spill("sess0")
    assert eng.cache is None
    eng.prefetch_sessions(["sess0"]).result(timeout=30)
    eng.resume("sess0")
    assert eng.pos == 7
    np.testing.assert_array_equal(np.asarray(eng.cache["k"]),
                                  np.ones((2, 4), np.float32))


# ---------------------------------------------------------------------------
# mesh version compat (satellite regression test)
# ---------------------------------------------------------------------------

class _FakeAxisType:
    Auto = "auto"


class _NewSharding:
    AxisType = _FakeAxisType


class _OldSharding:
    pass


def test_mesh_axis_kwargs_both_jax_variants():
    from repro.launch.mesh import _mesh_axis_kwargs
    assert _mesh_axis_kwargs(2, sharding_mod=_OldSharding) == {}
    kw = _mesh_axis_kwargs(3, sharding_mod=_NewSharding)
    assert kw == {"axis_types": ("auto", "auto", "auto")}


def test_make_mesh_on_installed_jax():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    assert mesh.axis_names == ("data", "model")
