"""Distributed runtime tests (subprocesses with multi-device CPU meshes,
because the main pytest process must keep the real single-device count)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    return p.stdout


def test_flash_decode_matches_jnp():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh
    from repro.distributed.decode_attn import make_flash_decode
    from repro.models.transformer import _jnp_decode_attn
    mesh = make_mesh((2, 4), ("data", "model"))
    B, Sc, Kh, H, Dh = 4, 16, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    kc = jax.random.normal(ks[0], (B, Sc, Kh, Dh), jnp.float32)
    vc = jax.random.normal(ks[1], (B, Sc, Kh, Dh), jnp.float32)
    kpos = jnp.broadcast_to(jnp.arange(Sc), (B, Sc)).astype(jnp.int32)
    kpos = jnp.where(kpos < 10, kpos, -1)
    q = jax.random.normal(ks[2], (B, H, Dh), jnp.float32)
    kn = jax.random.normal(ks[3], (B, Kh, Dh), jnp.float32)
    vn = jax.random.normal(ks[4], (B, Kh, Dh), jnp.float32)
    pos = jnp.int32(10)
    fd = make_flash_decode(mesh)
    for window in (0, 8):
        o1, c1 = fd(kc, vc, kpos, kn, vn, q, pos, window=window, cap=0.0)
        o2, c2 = _jnp_decode_attn(kc, vc, kpos, kn, vn, q, pos,
                                  window=window, cap=0.0)
        assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-5, window
        assert float(jnp.max(jnp.abs(c1['k'] - c2['k']))) == 0.0
    # batch=1 long-context case must also work (no batch sharding)
    o3, _ = fd(kc[:1], vc[:1], kpos[:1], kn[:1], vn[:1], q[:1], pos,
               window=0, cap=0.0)
    o4, _ = _jnp_decode_attn(kc[:1], vc[:1], kpos[:1], kn[:1], vn[:1],
                             q[:1], pos, window=0, cap=0.0)
    assert float(jnp.max(jnp.abs(o3 - o4))) < 1e-5
    print("OK")
    """)


@pytest.mark.parametrize("n_experts", [8, 2])
def test_moe_parallel_matches_gshard(n_experts):
    _run(f"""
    import jax, jax.numpy as jnp
    from repro.configs.base import (ModelConfig, MoEConfig, LayerSpec,
                                    ATTN_GLOBAL, MLP_MOE)
    from repro.models.moe import init_moe, make_moe_layout, apply_moe_gshard
    from repro.models.layers import ParamBuilder
    from repro.distributed.moe_parallel import (make_moe_etp,
                                                make_moe_replicated)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                      pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_MOE),),
                      moe=MoEConfig(n_experts={n_experts}, top_k=2,
                                    capacity_factor=8.0))
    pb = ParamBuilder(jax.random.PRNGKey(0))
    init_moe(pb, cfg, make_moe_layout(cfg, 4))
    params = {{k: v.astype(jnp.float32) for k, v in pb.params.items()}}
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32)) * 0.5
    y_ref, _ = apply_moe_gshard(params, x, cfg)
    etp = make_moe_etp(mesh)
    y1, _ = jax.jit(lambda p, xx: etp(p, xx, cfg))(params, x)
    rep = make_moe_replicated(mesh)
    y2, _ = jax.jit(lambda p, xx: rep(p, xx, cfg))(params, x)
    assert float(jnp.max(jnp.abs(y1 - y_ref))) < 1e-4
    assert float(jnp.max(jnp.abs(y2 - y_ref))) < 1e-4
    print("OK")
    """)


@pytest.mark.parametrize("n_experts", [8, 2])
def test_moe_decode_2d_experts(n_experts):
    """Perf-iteration 3: fully-resident 2D-sharded experts must be exact."""
    _run(f"""
    import jax, jax.numpy as jnp
    from repro.configs.base import (ModelConfig, MoEConfig, LayerSpec,
                                    ATTN_GLOBAL, MLP_MOE)
    from repro.models.moe import init_moe, make_moe_layout, apply_moe_gshard
    from repro.models.layers import ParamBuilder
    from repro.distributed.moe_parallel import make_moe_replicated
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                      pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_MOE),),
                      moe=MoEConfig(n_experts={n_experts}, top_k=2,
                                    capacity_factor=8.0))
    pb = ParamBuilder(jax.random.PRNGKey(0))
    init_moe(pb, cfg, make_moe_layout(cfg, 4))
    params = {{k: v.astype(jnp.float32) for k, v in pb.params.items()}}
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 32)) * 0.5
    y_ref, _ = apply_moe_gshard(params, x, cfg)
    rep2d = make_moe_replicated(mesh, expert_2d=True)
    y, _ = jax.jit(lambda p, xx: rep2d(p, xx, cfg))(params, x)
    assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-4
    print("OK")
    """)


def test_compressed_psum():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.distributed.compression import (
        compressed_psum_scatter_gather, init_error_state)
    mesh = make_mesh((8,), ("data",))
    n = 8 * 1024 * 2
    x = jax.random.normal(jax.random.PRNGKey(0), (8, n)) * 0.1
    err0 = jnp.zeros((8, n // 8), jnp.float32)
    def f(xl, el):
        y, e = compressed_psum_scatter_gather(xl[0], "data", el[0])
        return y[None], e[None]
    y, e = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                     out_specs=(P("data"), P("data")),
                     check_vma=False)(x, err0)
    ref = x.mean(0)
    rel = float(jnp.abs(y[0] - ref).max() / jnp.abs(ref).max())
    assert rel < 0.02, rel  # int8 broadcast error ~1/127
    # error feedback: repeated reductions stay unbiased
    acc = jnp.zeros_like(ref); eacc = err0
    for i in range(8):
        y, eacc = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                            out_specs=(P("data"), P("data")),
                            check_vma=False)(x, eacc)
        acc = acc + y[0]
    rel = float(jnp.abs(acc / 8 - ref).max() / jnp.abs(ref).max())
    assert rel < 0.005, rel
    print("OK")
    """)


def test_pipeline_matches_sequential():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh
    from repro.distributed.pipeline import pipeline_apply
    mesh = make_mesh((4, 2), ("pod", "data"))
    n_stages, n_micro, mb, d = 4, 8, 2, 16
    ws = jax.random.normal(jax.random.PRNGKey(0), (n_stages, d, d)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
    def stage_fn(w, h):
        return jnp.tanh(h @ w)
    out = pipeline_apply(mesh, stage_fn, ws, x, axis="pod")
    ref = x
    for s in range(n_stages):
        ref = jnp.tanh(ref @ ws[s])
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
    print("OK")
    """)


@pytest.mark.slow
def test_production_dryrun_multipod_smoke():
    """Deliverable (e): one full cell lower+compile on the 2x16x16 mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "decode_32k", "--mesh", "multi",
         "--out", "/tmp/test_dryrun_artifacts"],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=str(REPO))
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    assert "ok:" in p.stdout
