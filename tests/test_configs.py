"""Config registry: every assigned arch resolves, with sane param counts."""
import pytest

from repro.configs import SHAPES, cells, get_config, get_smoke_config
from repro.configs.registry import ARCH_IDS

# advertised sizes (embeddings untied in our impl -> tolerance is generous)
EXPECT = {
    "recurrentgemma-9b": 9e9,
    "whisper-tiny": 39e6,
    "gemma2-9b": 9e9,
    "qwen2-72b": 72e9,
    "starcoder2-15b": 15e9,
    "deepseek-coder-33b": 33e9,
    "grok-1-314b": 314e9,
    "arctic-480b": 480e9,
    "mamba2-1.3b": 1.3e9,
    "internvl2-26b": 26e9,
}


def test_ten_archs():
    assert len(ARCH_IDS) == 10


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_loads(arch):
    cfg = get_config(arch)
    assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 0
    smoke = get_smoke_config(arch)
    assert smoke.d_model <= 128


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_close(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    expect = EXPECT[arch]
    assert 0.5 * expect < n < 1.75 * expect, (arch, n, expect)


def test_cell_matrix_is_40():
    all_cells = list(cells())
    assert len(all_cells) == 40
    skipped = [c for c in all_cells if c.skip]
    runnable = [c for c in all_cells if not c.skip]
    # long_500k runs only for the sub-quadratic archs
    long_runs = [c for c in runnable if c.shape.name == "long_500k"]
    assert sorted(c.arch for c in long_runs) == ["mamba2-1.3b",
                                                 "recurrentgemma-9b"]
    assert len(skipped) == 8
    for c in skipped:
        assert c.shape.name == "long_500k"


def test_padded_vocab_divisible():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size


def test_padded_heads():
    cfg = get_config("deepseek-coder-33b")
    assert cfg.padded_heads(16) == 64  # 56 -> 64
    cfg = get_config("qwen2-72b")
    assert cfg.padded_heads(16) == 64  # already divisible


def test_moe_active_params():
    cfg = get_config("arctic-480b")
    assert cfg.active_param_count() < 0.1 * cfg.param_count()
    dense = get_config("qwen2-72b")
    assert dense.active_param_count() == dense.param_count()
