"""Tests for repro.analysis: pmemlint golden fixtures + the sanitizer.

Lint tests feed each rule a known-bad snippet (must flag) and a clean
sibling (must not). Sanitizer tests drive real ``PMemPool``/``MetaLog``
objects through the shim: the committed-tail discipline is checked live,
and ``crash_images`` + ``MetaLog`` replay prove every reachable crash
state recovers to a committed prefix of the appended events.
"""
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import locks, persistence, recovery
from repro.analysis.core import collect
from repro.analysis.lint import main as lint_main
from repro.analysis.sanitizer import PMemSanitizer
from repro.core.meta_log import MetaLog
from repro.core.object_store import PMemObjectStore
from repro.core.pmem import PMemPool

ALL_PASSES = (persistence.run, recovery.run, locks.run)


def _findings(tmp_path, source, passes=ALL_PASSES, fname="snippet.py"):
    f = tmp_path / fname
    f.write_text(textwrap.dedent(source))
    mods = collect([f], tmp_path)
    out = []
    for p in passes:
        out.extend(p(mods))
    return out


def _rules(findings):
    return {f.rule for f in findings}


# ---- family (a): persistence ordering --------------------------------

def test_missing_flush_flagged(tmp_path):
    found = _findings(tmp_path, """
        def write_no_flush(pool, data):
            region = pool.create("x", 64)
            region.write(0, data)
    """)
    assert "missing-flush" in _rules(found)


def test_write_then_flush_clean(tmp_path):
    found = _findings(tmp_path, """
        def write_flush(pool, data):
            region = pool.create("x", 64)
            region.write(0, data)
            region.flush()
    """)
    assert not found


def test_commit_before_flush_flagged(tmp_path):
    found = _findings(tmp_path, """
        def commit_unflushed(pool, data):
            region = pool.open("x")
            region.write(0, data)
            pool.put_json("m.json", {"ok": 1})
    """)
    assert "commit-before-flush" in _rules(found)


def test_tail_advance_without_flush_flagged(tmp_path):
    # the MetaLog bug class: entry bytes -> tail advance, no flush between
    found = _findings(tmp_path, """
        _TAIL_OFF = 8

        def torn_append(pool, blob, tail_bytes):
            region = pool.open("log")
            region.write(64, blob)
            region.write(_TAIL_OFF, tail_bytes)
            region.flush()
    """)
    assert "commit-before-flush" in _rules(found)


def test_disciplined_append_clean(tmp_path):
    found = _findings(tmp_path, """
        _TAIL_OFF = 8

        def good_append(pool, blob, tail_bytes):
            region = pool.open("log")
            region.write(64, blob)
            region.flush()
            region.write(_TAIL_OFF, tail_bytes)
            region.flush()
    """)
    assert not found


def test_raw_pool_path_flagged_and_suppressible(tmp_path):
    bad = _findings(tmp_path, """
        def raw_touch(pool):
            with open(pool.root / "obj.bin", "wb") as f:
                f.write(b"x")
    """)
    assert "raw-pool-path" in _rules(bad)
    ok = _findings(tmp_path, """
        def raw_touch(pool):
            with open(pool.root / "obj.bin", "wb") as f:  # pmemlint: disable=raw-pool-path
                f.write(b"x")
    """, fname="suppressed.py")
    assert "raw-pool-path" not in _rules(ok)


def test_silent_swallow_flagged(tmp_path):
    found = _findings(tmp_path, """
        def persist(pool, obj):
            try:
                pool.put_json("m.json", obj)
            except IOError:
                pass
    """)
    assert "silent-swallow" in _rules(found)


def test_accounted_failure_clean(tmp_path):
    found = _findings(tmp_path, """
        def persist(pool, obj, stats):
            try:
                pool.put_json("m.json", obj)
            except IOError:
                stats["put_failures"] += 1
    """)
    assert "silent-swallow" not in _rules(found)


# ---- family (b): metadata-only recovery ------------------------------

def test_metadata_only_direct_read_flagged(tmp_path):
    found = _findings(tmp_path, """
        from repro.analysis.annotations import metadata_only

        class Catalog:
            @metadata_only
            def decide(self):
                return self.store.get("obj")
    """)
    assert "metadata-only-read" in _rules(found)


def test_metadata_only_transitive_read_flagged(tmp_path):
    found = _findings(tmp_path, """
        from repro.analysis.annotations import metadata_only

        class Catalog:
            @metadata_only
            def decide(self):
                return self._probe()

            def _probe(self):
                return self.store.get("obj")
    """)
    hits = [f for f in found if f.rule == "metadata-only-read"]
    assert hits
    # the finding anchors at the annotated root with a witness path
    assert hits[0].func == "Catalog.decide"
    assert "_probe" in hits[0].message


def test_metadata_only_stops_at_rehydration_entry(tmp_path):
    found = _findings(tmp_path, """
        from repro.analysis.annotations import metadata_only, \\
            rehydration_entry

        class Catalog:
            @metadata_only
            def decide(self):
                return self._copy()

            @rehydration_entry
            def _copy(self):
                return self.store.get("obj")
    """)
    assert "metadata-only-read" not in _rules(found)


def test_metadata_only_plain_dict_get_clean(tmp_path):
    found = _findings(tmp_path, """
        from repro.analysis.annotations import metadata_only

        class Catalog:
            @metadata_only
            def decide(self, rec):
                return rec.get("acks")
    """)
    assert "metadata-only-read" not in _rules(found)


def test_metadata_only_closure_read_flagged(tmp_path):
    # closures run in this flow (submitted as callbacks) — reads inside
    # them count against the encloser's promise
    found = _findings(tmp_path, """
        from repro.analysis.annotations import metadata_only

        class Catalog:
            @metadata_only
            def decide(self):
                def go():
                    return self.store.get("obj")
                return go
    """)
    assert "metadata-only-read" in _rules(found)


# ---- family (c): lock discipline -------------------------------------

def test_unguarded_write_flagged(tmp_path):
    found = _findings(tmp_path, """
        import threading

        class Registry:
            def __init__(self):
                self.lock = threading.Lock()
                self.cache = {}

            def put(self, k, v):
                with self.lock:
                    self.cache[k] = v

            def fill(self, k, v):
                self.cache[k] = v
    """)
    hits = [f for f in found if f.rule == "unguarded-write"]
    assert hits and hits[0].func == "Registry.fill"


def test_lock_held_helper_clean(tmp_path):
    # the repo's "Lock held." private-helper idiom must not false-positive
    found = _findings(tmp_path, """
        import threading

        class Registry:
            def __init__(self):
                self.lock = threading.Lock()
                self.cache = {}

            def put(self, k, v):
                with self.lock:
                    self._insert(k, v)

            def drop(self, k):
                with self.lock:
                    self._insert(k, None)

            def _insert(self, k, v):
                self.cache[k] = v
    """)
    assert "unguarded-write" not in _rules(found)


def test_closure_write_counts_as_unguarded(tmp_path):
    # a closure defined under the lock runs later on a worker thread
    found = _findings(tmp_path, """
        import threading

        class Registry:
            def __init__(self):
                self.lock = threading.Lock()
                self.acked = {}

            def put(self, k, v):
                with self.lock:
                    self.acked[k] = v

            def make_callback(self, k):
                def cb(result):
                    self.acked[k] = result
                return cb
    """)
    assert "unguarded-write" in _rules(found)


def test_blocking_under_lock_flagged(tmp_path):
    found = _findings(tmp_path, """
        import threading

        class Channel:
            def __init__(self):
                self.lock = threading.Lock()

            def flush_one(self, fut):
                with self.lock:
                    return fut.result()
    """)
    assert "blocking-under-lock" in _rules(found)


def test_blocking_outside_lock_clean(tmp_path):
    found = _findings(tmp_path, """
        import threading

        class Channel:
            def __init__(self):
                self.lock = threading.Lock()
                self.n = 0

            def flush_one(self, fut):
                with self.lock:
                    self.n += 1
                return fut.result()
    """)
    assert "blocking-under-lock" not in _rules(found)


def test_string_join_not_blocking(tmp_path):
    found = _findings(tmp_path, """
        import threading

        class Fmt:
            def __init__(self):
                self.lock = threading.Lock()

            def render(self, parts):
                with self.lock:
                    return b"".join(parts)
    """)
    assert "blocking-under-lock" not in _rules(found)


# ---- driver: baseline + exit codes -----------------------------------

def test_lint_main_baseline_roundtrip(tmp_path, capsys):
    snip = tmp_path / "bad.py"
    snip.write_text(textwrap.dedent("""
        def write_no_flush(pool, data):
            region = pool.create("x", 64)
            region.write(0, data)
    """))
    base = tmp_path / "baseline.json"
    # raw: the finding fails the run
    assert lint_main([str(snip), "--no-baseline"]) == 1
    # baseline it: subsequent runs pass, the finding is reported as known
    assert lint_main([str(snip), "--baseline", str(base),
                      "--update-baseline"]) == 0
    assert lint_main([str(snip), "--baseline", str(base)]) == 0
    # a NEW finding still fails against that baseline
    snip.write_text(snip.read_text() + textwrap.dedent("""
        def persist(pool, obj):
            try:
                pool.put_json("m.json", obj)
            except IOError:
                pass
    """))
    assert lint_main([str(snip), "--baseline", str(base)]) == 1


def test_repo_is_lint_clean_vs_baseline():
    """The shipped tree must pass its own lint against the checked-in
    baseline — the same invocation `make analyze` / CI runs."""
    root = Path(__file__).resolve().parent.parent
    target = root / "src" / "repro"
    assert target.is_dir()
    assert lint_main([str(target)]) == 0


# ---- sanitizer: live ordering checks ---------------------------------

_MAGIC = b"MLOG1\x00"


def _mk_pool(path):
    return PMemPool(path, "node0", capacity_bytes=1 << 24)


def _absolve(outer):
    """Tests below stage violations ON PURPOSE. When the whole suite
    runs under ``--pmem-sanitize`` the autouse shim records them too —
    clear it so the deliberate bad sequence doesn't fail the run."""
    if outer is not None:
        outer.violations.clear()
        for st in outer.regions.values():
            st.dirty = False


def test_sanitizer_flags_tail_advance_over_unflushed(tmp_path,
                                                     _pmem_sanitize):
    san = PMemSanitizer().install()
    try:
        pool = _mk_pool(tmp_path)
        region = pool.create("t/log", 4096)
        region.write(0, np.frombuffer(_MAGIC, dtype=np.uint8))
        region.flush()
        # entry bytes land but are NOT flushed before the tail advance
        region.write(64, np.full(16, 7, dtype=np.uint8))
        region.write(8, np.frombuffer((80).to_bytes(8, "little"),
                                      dtype=np.uint8))
        region.flush()
    finally:
        san.uninstall()
    assert any("committed-tail" in v for v in san.violations)
    with pytest.raises(AssertionError, match="committed-tail"):
        san.raise_violations()
    _absolve(_pmem_sanitize)


def test_sanitizer_accepts_disciplined_append(tmp_path):
    san = PMemSanitizer().install()
    try:
        pool = _mk_pool(tmp_path)
        region = pool.create("t/log", 4096)
        region.write(0, np.frombuffer(_MAGIC, dtype=np.uint8))
        region.flush()
        region.write(64, np.full(16, 7, dtype=np.uint8))
        region.flush()  # entry durable BEFORE the tail moves
        region.write(8, np.frombuffer((80).to_bytes(8, "little"),
                                      dtype=np.uint8))
        region.flush()
    finally:
        san.uninstall()
    assert san.violations == []
    san.raise_violations()


def test_sanitizer_flags_dirty_close(tmp_path, _pmem_sanitize):
    san = PMemSanitizer().install()
    try:
        pool = _mk_pool(tmp_path)
        region = pool.create("t/x", 64)
        region.write(0, np.full(8, 1, dtype=np.uint8))
        region.close()  # close() flushes, but a crash never calls close
    finally:
        san.uninstall()
    assert any("dirty-close" in v for v in san.violations)
    _absolve(_pmem_sanitize)


def test_sanitizer_flags_dirty_delete(tmp_path, _pmem_sanitize):
    san = PMemSanitizer().install()
    try:
        pool = _mk_pool(tmp_path)
        region = pool.create("t/x", 64)
        region.write(0, np.full(8, 1, dtype=np.uint8))
        pool.delete("t/x")
    finally:
        san.uninstall()
    assert any("dirty-drop" in v for v in san.violations)
    _absolve(_pmem_sanitize)


def test_metalog_append_passes_sanitizer(tmp_path):
    """The real MetaLog append path (entry -> flush -> tail -> flush)
    must run violation-free under the sanitizer."""
    san = PMemSanitizer().install()
    try:
        pool = _mk_pool(tmp_path)
        stores = {"node0": PMemObjectStore(pool)}

        def fold(state, ev):
            state[str(ev["i"])] = ev["v"]

        log = MetaLog(stores, ["node0"], "t/log", fold=fold)
        for i in range(6):
            log.append({"i": i, "v": i * 10})
        assert log.state() == {str(i): i * 10 for i in range(6)}
    finally:
        san.uninstall()
    san.raise_violations()


# ---- sanitizer: crash-state enumeration ------------------------------

def test_crash_images_replay_to_committed_prefix(tmp_path):
    """Every reachable crash state of a MetaLog append sequence —
    unflushed stores lost, persisted early, or the final store torn —
    must replay to a committed PREFIX of the appended events (possibly
    empty), never a torn or reordered mix."""
    san = PMemSanitizer(capture=True).install()
    try:
        pool = _mk_pool(tmp_path / "live")
        stores = {"node0": PMemObjectStore(pool)}

        def fold(state, ev):
            state[str(ev["i"])] = ev["v"]

        log = MetaLog(stores, ["node0"], "t/log", fold=fold)
        n = 4
        for i in range(n):
            log.append({"i": i, "v": i * 10})
    finally:
        san.uninstall()
    san.raise_violations()

    prefixes = [{str(j): j * 10 for j in range(k)} for k in range(n + 1)]
    images = list(san.crash_images("t/log"))
    assert len(images) >= 3 * n  # >= one write per append, 3 states each
    reached = set()
    for label, img in images:
        rpool = _mk_pool(tmp_path / "replay")
        PMemSanitizer.materialize(img, rpool, "t/log")
        rlog = MetaLog({"node0": PMemObjectStore(rpool)}, ["node0"],
                       "t/log", fold=fold)
        state = dict(rlog.state())
        assert state in prefixes, \
            f"crash state {label} replayed to non-prefix {state}"
        reached.add(len(state))
    # the enumeration must actually exercise more than the final state
    assert len(reached) > 1


def test_crash_images_requires_capture(tmp_path):
    san = PMemSanitizer()  # capture defaults off
    with pytest.raises(RuntimeError):
        list(san.crash_images("x"))


# ---- satellites: pmem.py surfacing -----------------------------------

def test_region_dirty_property_and_close_flush(tmp_path, _pmem_sanitize):
    pool = _mk_pool(tmp_path)
    region = pool.create("d/x", 64)
    assert region.dirty  # fresh create: bytes not yet flushed
    region.flush()
    assert not region.dirty
    region.write(0, np.full(8, 3, dtype=np.uint8))
    assert region.dirty
    region.close()  # flushes because dirty
    # a fresh pool (new process analogue) must see the flushed bytes
    reopened = _mk_pool(tmp_path).open("d/x")
    assert not reopened.dirty
    assert bytes(reopened.read(0, 8)) == bytes([3] * 8)
    _absolve(_pmem_sanitize)  # the dirty close above was the point


def test_dir_fsync_failure_counted_and_warned_once(tmp_path, monkeypatch):
    import os as _os
    pool = _mk_pool(tmp_path)
    real_fsync = _os.fsync

    def deny_dir_fsync(fd):
        # file fsyncs (writable fd) succeed; directory fsyncs refuse —
        # the EINVAL some filesystems return for O_RDONLY dir handles
        import stat
        if stat.S_ISDIR(_os.fstat(fd).st_mode):
            raise OSError("fsync on directory refused")
        return real_fsync(fd)

    monkeypatch.setattr(_os, "fsync", deny_dir_fsync)
    with pytest.warns(RuntimeWarning, match="dir_fsync_failures"):
        pool.put_json("m/a.json", {"v": 1})
    pool.put_json("m/b.json", {"v": 2})  # counted, but no second warning
    assert pool.dir_fsync_failures == 2
    assert pool.get_json("m/a.json") == {"v": 1}
    assert pool.get_json("m/b.json") == {"v": 2}
