"""Persistent Dataset Exchange: lease/refcount GC, lineage round-trips,
replica-acked recoverability, lease-aware eviction, concurrent
two-workflow isolation, and resume-after-node-loss replaying only the
jobs whose retained outputs are ack-unrecoverable."""
import threading
import time

import numpy as np
import pytest

from repro.core.dataset_exchange import cache_key
from repro.core.workflow import JobSpec


def _tree(seed=0, n=64):
    return {"x": np.random.RandomState(seed).randn(n).astype(np.float32)}


def _record_store_reads(cluster):
    """Wrap every store's object-read/probe entry points, recording the
    object names touched. Pool JSON (catalog records, journals) stays
    unrecorded — recoverability ranking is ALLOWED to read metadata."""
    reads = []

    def wrap(st):
        orig_get, orig_exists = st.get_with_manifest, st.exists

        def get_with_manifest(name, *a, **k):
            reads.append(name)
            return orig_get(name, *a, **k)

        def exists(name, *a, **k):
            reads.append(name)
            return orig_exists(name, *a, **k)
        st.get_with_manifest, st.exists = get_with_manifest, exists

    for st in cluster.stores.values():
        wrap(st)
    return reads


# ---------------------------------------------------------------------------
# catalog: leases, refcount, GC
# ---------------------------------------------------------------------------

def test_lease_blocks_gc_release_enables_it(cluster):
    cat = cluster.catalog
    cat.publish("ds", _tree(1), workflow="w", retained=False)
    lease = cat.acquire("ds", workflow="w", owner="consumer")
    assert cat.refcount("ds", "w") == 1
    assert cat.gc() == []  # leased: bytes stay
    assert np.allclose(cat.get("ds", "w")["x"], _tree(1)["x"])
    cat.release(lease)
    assert cat.refcount("ds", "w") == 0
    assert cat.gc() == [("w", "ds", 1)]
    # bytes gone, record (and lineage) survive
    rec = cat.record("ds", "w")
    assert rec["reclaimed"]
    with pytest.raises(KeyError):
        cat.get("ds", "w")


def test_expired_lease_is_reclaimed(cluster):
    cat = cluster.catalog
    cat.publish("ds", _tree(2), workflow="w", retained=False)
    cat.acquire("ds", workflow="w", owner="laggard", ttl_s=30.0)
    assert cat.gc() == []  # unexpired
    assert cat.gc(now=time.time() + 60.0) == [("w", "ds", 1)]
    assert cat.record("ds", "w")["reclaimed"]


def test_retained_dataset_survives_gc_until_unretained(cluster):
    cat = cluster.catalog
    cat.publish("ds", _tree(3), workflow="w", retained=True)
    assert cat.gc() == []
    cat.unretain("ds", "w")
    assert cat.gc() == [("w", "ds", 1)]


def test_reclaim_terminal_across_stale_pool_copies(cluster):
    """A pool that missed the GC write must not resurrect the record."""
    cat = cluster.catalog
    cat.publish("ds", _tree(4), workflow="w", retained=False)
    cat.gc()
    assert cat.record("ds", "w")["reclaimed"]
    # hand-write a stale unreclaimed copy onto one pool
    rec = dict(cat.record("ds", "w"))
    rec["reclaimed"] = False
    cluster.stores["node2"].pool.put_json("exch/w/ds@v1.json", rec)
    assert cat.record("ds", "w")["reclaimed"]  # merge keeps it terminal


# ---------------------------------------------------------------------------
# lineage
# ---------------------------------------------------------------------------

def test_lineage_round_trip_through_workflow(cluster):
    cluster.external.put("raw", _tree(0))

    def prep(ctx):
        return {"clean": {"x": ctx.read("raw")["x"] * 2}}

    def train(ctx):
        return {"model": {"w": ctx.read("clean")["x"] + 1}}

    res = cluster.workflows.run([
        JobSpec("prep", prep, inputs=("raw",), retain=("clean",)),
        JobSpec("train", train, inputs=("clean",), after=("prep",),
                retain=("model",)),
    ])
    wf = res.workflow_id
    chain = cluster.catalog.lineage("model", wf)
    # model -> clean -> external raw, with producing jobs + versions
    assert chain[0]["name"] == "model"
    assert chain[0]["lineage"]["job"] == "train"
    assert chain[0]["lineage"]["inputs"] == [["clean", wf, 1]]
    assert chain[1]["name"] == "clean"
    assert chain[1]["lineage"]["job"] == "prep"
    assert {"external": "raw"} in chain
    # content digest matches the stored object's manifest
    from repro.core.object_store import content_digest
    rec = chain[0]
    man = cluster.stores[rec["home"]].manifest(rec["object"],
                                              rec["version"])
    assert rec["digest"] == content_digest(man)


def test_lineage_survives_reclaim(cluster):
    cat = cluster.catalog
    cat.publish("a", _tree(1), workflow="w", retained=False)
    cat.publish("b", _tree(2), workflow="w", producer="jb",
                inputs=[["a", "w", 1]], retained=False)
    cat.gc()
    chain = cat.lineage("b", "w")
    assert [r.get("name") for r in chain] == ["b", "a"]
    assert all(r["reclaimed"] for r in chain)


# ---------------------------------------------------------------------------
# placement map durability: replica acks, fallback reads
# ---------------------------------------------------------------------------

def test_replica_fallback_read_after_home_loss(cluster):
    cat = cluster.catalog
    rec = cat.publish("ds", _tree(5), workflow="w")
    cluster.tiered.quiesce()  # replica placed + acked
    rec = cat.record("ds", "w")
    target = rec["acks"]["replica"]["target"]
    assert target != rec["home"]
    cluster.kill_node(rec["home"])
    got = cat.get("ds", "w")
    np.testing.assert_array_equal(got["x"], _tree(5)["x"])
    assert cat.stats["replica_reads"] == 1


def test_recoverable_is_metadata_only(cluster):
    cat = cluster.catalog
    cat.publish("ds", _tree(6), workflow="w")
    cluster.tiered.quiesce()
    rec = cat.record("ds", "w")
    home, target = rec["home"], rec["acks"]["replica"]["target"]
    reads = _record_store_reads(cluster)
    assert cat.recoverable("ds", "w", lost_nodes=[home])
    assert not cat.recoverable("ds", "w", lost_nodes=[home, target])
    assert reads == []  # decided from the record alone


def test_unacked_dataset_not_recoverable_after_home_loss(cluster):
    """Replication still in flight (no ack) must read as unrecoverable —
    the catalog under-promises, never over-promises."""
    cat = cluster.catalog
    cat.exchange = None  # publish without any replica fan-out
    cat.publish("ds", _tree(7), workflow="w")
    rec = cat.record("ds", "w")
    assert not cat.recoverable("ds", "w", lost_nodes=[rec["home"]])


# ---------------------------------------------------------------------------
# lease-aware eviction (TieredIO + DLM cache)
# ---------------------------------------------------------------------------

def test_leased_dataset_pinned_through_evict_cold(cluster):
    cat, tio = cluster.catalog, cluster.tiered
    cat.publish("hot", _tree(8), workflow="w")
    cat.get("hot", "w")  # admitted into the DLM cache
    key = cache_key("w", "hot", 1)
    assert cluster.dlm.contains(key)
    lease = cat.acquire("hot", workflow="w", owner="consumer")
    tio.evict_cold(0.0)  # evict-everything sweep
    assert cluster.dlm.contains(key)  # pinned by the live lease
    cat.release(lease)
    tio.evict_cold(0.0)
    assert not cluster.dlm.contains(key)


def test_reclaim_drops_cache_entry_without_writeback(cluster):
    cat = cluster.catalog
    cat.publish("ds", _tree(9), workflow="w", retained=False)
    cat.get("ds", "w")
    key = cache_key("w", "ds", 1)
    assert cluster.dlm.contains(key)
    cat.gc()
    assert not cluster.dlm.contains(key)
    # no resurrection: the cache never wrote dlm/<key> back to pmem
    assert not cluster.stores[cluster.node_ids[0]].exists(f"dlm/{key}")


def test_prefetch_datasets_warms_cache(cluster):
    cat, tio = cluster.catalog, cluster.tiered
    cat.publish("warm", _tree(10), workflow="w")
    out = tio.prefetch_datasets(["warm", "absent"], "w").result(timeout=30)
    assert out["loads"] == 1 and out["missing"] == 1
    assert cluster.dlm.contains(cache_key("w", "warm", 1))
    out2 = tio.prefetch_datasets(["warm"], "w").result(timeout=30)
    assert out2["hits"] == 1


# ---------------------------------------------------------------------------
# concurrent workflows
# ---------------------------------------------------------------------------

def test_two_workflows_run_concurrently_isolated(cluster):
    """Same dataset names in two workflows, run from two threads at
    once: each consumer must see ITS producer's bytes, and the catalog
    must keep per-workflow records."""
    results, errors = {}, []

    def make_jobs(tag, scale):
        def produce(ctx):
            return {"data": {"x": np.full(32, float(scale))}}

        def consume(ctx):
            results[tag] = ctx.read("data")["x"].copy()
            return {"out": {"s": np.array([ctx.read("data")["x"].sum()])}}
        return [
            JobSpec("produce", produce, retain=("data",)),
            JobSpec("consume", consume, inputs=("data",),
                    after=("produce",), retain=("out",)),
        ]

    def go(tag, scale):
        try:
            cluster.workflows.run(make_jobs(tag, scale), workflow=tag)
        except Exception as e:  # noqa: BLE001 — surfaced below
            errors.append(e)

    t1 = threading.Thread(target=go, args=("wfA", 3))
    t2 = threading.Thread(target=go, args=("wfB", 7))
    t1.start(); t2.start(); t1.join(timeout=60); t2.join(timeout=60)
    assert not errors
    np.testing.assert_array_equal(results["wfA"], np.full(32, 3.0))
    np.testing.assert_array_equal(results["wfB"], np.full(32, 7.0))
    assert cluster.catalog.record("data", "wfA")["workflow"] == "wfA"
    assert cluster.catalog.record("data", "wfB")["workflow"] == "wfB"
    assert float(cluster.catalog.get("out", "wfA")["s"][0]) == 96.0
    assert float(cluster.catalog.get("out", "wfB")["s"][0]) == 224.0


def test_independent_branches_overlap(cluster):
    """Ready jobs dispatch onto DataScheduler workers in parallel: two
    input-free branches must actually overlap in time."""
    spans = {}

    def branch(tag):
        def fn(ctx):
            t0 = time.time()
            time.sleep(0.25)
            spans[tag] = (t0, time.time())
            return {f"out_{tag}": {"x": np.ones(4)}}
        return fn

    cluster.workflows.run([
        JobSpec("b1", branch("b1")),
        JobSpec("b2", branch("b2")),
    ])
    (s1, e1), (s2, e2) = spans["b1"], spans["b2"]
    assert max(s1, s2) < min(e1, e2), "branches never overlapped"


def test_serial_mode_never_overlaps(cluster):
    running = []
    overlap = []

    def fn(ctx):
        running.append(1)
        if len(running) - len(overlap) > 1:
            overlap.append(1)
        time.sleep(0.05)
        running.pop()
        return {}

    cluster.workflows.run([JobSpec(f"j{i}", fn) for i in range(4)],
                          max_concurrent=1)
    assert not overlap


# ---------------------------------------------------------------------------
# journal + resume after node loss (the acceptance criterion)
# ---------------------------------------------------------------------------

def _pinned_jobs(cluster, calls):
    """Two independent producers pinned to different homes via
    pre-placed inputs, plus a sink consuming both."""
    cluster.stores["node0"].put("seed_a", _tree(1))
    cluster.stores["node2"].put("seed_b", _tree(2))
    # the seeds also live on the external store, so a replayed job can
    # burst-buffer them back in after its pre-placed copy died
    cluster.external.put("seed_a", _tree(1))
    cluster.external.put("seed_b", _tree(2))

    def mk(tag, out, inputs):
        def fn(ctx):
            calls[tag] += 1
            for i in inputs:
                ctx.read(i)
            return {out: _tree(hash(tag) % 100)}
        return fn

    return [
        JobSpec("pa", mk("pa", "da", ("seed_a",)), inputs=("seed_a",),
                retain=("da",)),
        JobSpec("pb", mk("pb", "db", ("seed_b",)), inputs=("seed_b",),
                retain=("db",)),
        JobSpec("sink", mk("sink", "dc", ("da", "db")),
                inputs=("da", "db"), after=("pa", "pb"), retain=("dc",)),
    ]


def test_resume_replays_only_ack_unrecoverable_jobs(cluster):
    calls = {"pa": 0, "pb": 0, "sink": 0}
    jobs = _pinned_jobs(cluster, calls)
    res = cluster.workflows.run(jobs, workflow="wfR")
    cluster.tiered.quiesce()  # replica acks land
    assert calls == {"pa": 1, "pb": 1, "sink": 1}
    rec_b = cluster.catalog.record("db", "wfR")
    rec_a = cluster.catalog.record("da", "wfR")
    rec_c = cluster.catalog.record("dc", "wfR")
    # kill pb's output home AND its replica target -> db unrecoverable.
    dead = {rec_b["home"], rec_b["acks"]["replica"]["target"]}
    # the scenario needs pa's and sink's outputs to survive that loss
    for rec in (rec_a, rec_c):
        assert not ({rec["home"],
                     rec["acks"]["replica"]["target"]} <= dead)
    for nid in dead:
        cluster.kill_node(nid)
    res2 = cluster.workflows.resume(jobs, "wfR", lost_nodes=sorted(dead))
    # ONLY pb re-invoked; pa and sink untouched
    assert calls == {"pa": 1, "pb": 2, "sink": 1}
    assert set(res2.skipped) == {"pa", "sink"}
    assert res2.replayed == ["pb"]
    # the replayed producer published a new version
    assert cluster.catalog.record("db", "wfR")["version"] == 2


def test_resume_decision_makes_zero_object_store_probes(cluster):
    calls = {"pa": 0, "pb": 0, "sink": 0}
    jobs = _pinned_jobs(cluster, calls)
    cluster.workflows.run(jobs, workflow="wfZ")
    cluster.tiered.quiesce()
    # lose ONE node: every dataset has a surviving copy (home or acked
    # replica), so resume must skip every job — without a single
    # object-store read or probe
    victim = cluster.catalog.record("db", "wfZ")["home"]
    cluster.kill_node(victim)
    reads = _record_store_reads(cluster)
    # repair=False isolates the DECISION: repair's re-replication reads
    # the objects it copies (by design — covered in test_repair.py)
    res = cluster.workflows.resume(jobs, "wfZ", lost_nodes=[victim],
                                   repair=False)
    assert calls == {"pa": 1, "pb": 1, "sink": 1}  # nothing re-invoked
    assert set(res.skipped) == {"pa", "pb", "sink"}
    assert reads == []


def test_journal_survives_node0_loss(cluster):
    calls = {"pa": 0, "pb": 0, "sink": 0}
    jobs = _pinned_jobs(cluster, calls)
    cluster.workflows.run(jobs, workflow="wfJ")
    cluster.kill_node("node0")
    j = cluster.workflows.journal("wfJ")
    assert j["status"] == "done"
    assert set(j["jobs"]) == {"pa", "pb", "sink"}


def test_failed_final_drain_fails_workflow(cluster):
    """Satellite: drain futures are joined at the end of run — a failed
    final-output drain fails the workflow instead of vanishing."""
    def boom(name, tree):
        raise IOError("external store died mid-drain")
    cluster.external.put = boom

    def job(ctx):
        return {"report": {"x": np.ones(4)}}

    with pytest.raises(RuntimeError, match="drain of final output"):
        cluster.workflows.run([JobSpec("j", job, drain=("report",))])


def test_byte_weighted_placement(cluster):
    """Satellite: _place weights affinity by object BYTES — one big
    input on node3 must outrank two small ones on node1."""
    cluster.stores["node3"].put("big", {"x": np.zeros(4096)})
    cluster.stores["node1"].put("small1", {"x": np.zeros(4)})
    cluster.stores["node1"].put("small2", {"x": np.zeros(4)})
    placed = {}

    def job(ctx):
        placed["nodes"] = ctx.nodes
        return {}

    cluster.workflows.run([JobSpec("j", job,
                                   inputs=("big", "small1", "small2"))])
    assert placed["nodes"][0] == "node3"
