"""examples/workflow_pipeline.py end to end under pytest: the Fig. 8
pipeline over the dataset exchange — concurrent branches, lineage,
node-loss resume with zero replays — must keep working as a whole."""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def test_workflow_pipeline_example_end_to_end():
    env_path = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / "workflow_pipeline.py")],
        cwd=REPO, capture_output=True, text=True, timeout=280,
        env={"PYTHONPATH": env_path, "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": "/tmp", "JAX_PLATFORMS": "cpu"})
    out = proc.stdout
    assert proc.returncode == 0, f"example failed:\n{out}\n{proc.stderr}"
    # the Fig. 8 lifecycle ran over the exchange...
    for marker in ("stage_in", "in_situ", "retain", "drain"):
        assert marker in out, f"missing {marker} event:\n{out}"
    # ...lineage resolved down to the external root input...
    assert "external:raw_corpus" in out
    assert "produced by train" in out
    # ...and resume after the node loss replayed nothing
    assert "replayed []" in out
    assert "1 replica reads" in out
