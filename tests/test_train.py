"""Training substrate: CE oracle, microbatch equivalence, loss decrease,
optimizer correctness, workflow/resilience integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ParallelConfig, ShapeConfig, registry
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.train import optimizer as opt
from repro.train import train_step as ts


def _setup(arch="qwen2-72b", S=32, B=4):
    cfg = registry.get_smoke_config(arch)
    shape = ShapeConfig("t", S, B, "train")
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = shd.Plan(mesh, cfg, shape, ParallelConfig(attn_impl="naive"))
    rt = plan.runtime()
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, rt)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32),
        "loss_mask": np.ones((B, S), np.float32),
    }
    return cfg, plan, rt, params, batch


def test_chunked_ce_matches_full_softmax():
    cfg, plan, rt, params, batch = _setup()
    hidden, _, _ = T.forward(params, cfg, rt, jnp.asarray(batch["tokens"]))
    nll, cnt = ts.chunked_ce_loss(hidden, params["out_embed"],
                                  jnp.asarray(batch["labels"]),
                                  jnp.asarray(batch["loss_mask"]), cfg,
                                  plan.constrain, chunk=8)
    # oracle: full logits log-softmax
    logits = T.lm_head(params, cfg, hidden)
    logits = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                       logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lbl = jnp.take_along_axis(logits, jnp.asarray(batch["labels"])[..., None],
                              axis=-1)[..., 0]
    ref = jnp.sum(lse - lbl)
    assert abs(float(nll - ref)) / abs(float(ref)) < 1e-4
    assert float(cnt) == batch["loss_mask"].sum()


def test_microbatch_equals_full_batch():
    cfg, plan, rt, params, batch = _setup(B=4)
    adamw = opt.AdamWConfig(lr=1e-3, warmup=1, clip_norm=0.0)
    ost = opt.init_opt_state(params, adamw)
    s1 = jax.jit(ts.make_train_step(cfg, rt, plan.constrain, adamw,
                                    microbatches=1, ce_chunk=8))
    s2 = jax.jit(ts.make_train_step(cfg, rt, plan.constrain, adamw,
                                    microbatches=2, ce_chunk=8))
    p1, _, m1 = s1(params, ost, batch)
    p2, _, m2 = s2(params, ost, batch)
    # losses match; params match to accumulation-dtype noise
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_loss_decreases_10_steps():
    cfg, plan, rt, params, batch = _setup(arch="gemma2-9b")
    adamw = opt.AdamWConfig(lr=2e-3, warmup=2)
    ost = opt.init_opt_state(params, adamw)
    step = jax.jit(ts.make_train_step(cfg, rt, plan.constrain, adamw,
                                      ce_chunk=8))
    losses = []
    for _ in range(10):
        params, ost, m = step(params, ost, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_adamw_matches_numpy_reference():
    adamw = opt.AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8,
                            weight_decay=0.0, clip_norm=0.0, warmup=1)
    p = {"w": jnp.asarray(np.random.RandomState(0).randn(32).astype(
        np.float32))}
    g = {"w": jnp.asarray(np.random.RandomState(1).randn(32).astype(
        np.float32))}
    st = opt.init_opt_state(p, adamw)
    newp, st2, gnorm = opt.apply_updates(p, g, st, adamw)
    # numpy adam
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    upd = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.99)) + 1e-8)
    ref = np.asarray(p["w"]) - 1e-2 * upd
    np.testing.assert_allclose(np.asarray(newp["w"]), ref, atol=1e-5)


def test_int8_moments_track_float32():
    adamw8 = opt.AdamWConfig(lr=1e-2, warmup=1, moments_dtype="int8",
                             clip_norm=0.0)
    adamwf = opt.AdamWConfig(lr=1e-2, warmup=1, moments_dtype="float32",
                             clip_norm=0.0)
    p = {"w": jnp.asarray(np.random.RandomState(0).randn(512)
                          .astype(np.float32))}
    st8 = opt.init_opt_state(p, adamw8)
    stf = opt.init_opt_state(p, adamwf)
    p8, pf = p, p
    for i in range(5):
        g = {"w": jnp.asarray(np.random.RandomState(i + 10).randn(512)
                              .astype(np.float32))}
        p8, st8, _ = opt.apply_updates(p8, g, st8, adamw8)
        pf, stf, _ = opt.apply_updates(pf, g, stf, adamwf)
    diff = np.abs(np.asarray(p8["w"]) - np.asarray(pf["w"])).max()
    scale = np.abs(np.asarray(pf["w"]) - np.asarray(p["w"])).max()
    assert diff < 0.15 * scale, (diff, scale)


def test_train_loop_with_fault_recovery(cluster):
    from repro.data.pipeline import StagedDataset
    from repro.train import loop as tl
    cfg, plan, rt, params, _ = _setup(arch="starcoder2-15b", S=32, B=4)
    adamw = opt.AdamWConfig(lr=1e-3, warmup=2)
    ost = opt.init_opt_state(params, adamw)
    step = jax.jit(ts.make_train_step(cfg, rt, plan.constrain, adamw,
                                      ce_chunk=8))
    shape = ShapeConfig("t", 32, 4, "train")
    data = StagedDataset(cluster, cfg, shape, n_shards=2, seqs_per_shard=8)
    lc = tl.LoopConfig(steps=8, ckpt_every=2)
    state = tl.run(step, params, ost, data.batches(8), cluster, lc,
                   fault_at=5)
    assert state.step == 8
    assert state.recovered_at == [5]
    assert np.isfinite(state.losses).all()
