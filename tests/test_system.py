"""End-to-end behaviour tests for the paper's system (Fig. 7/8 stack)."""
import numpy as np


def test_fig8_sequence_end_to_end(cluster):
    """The paper's Fig. 8 lifecycle: stage-in -> run -> retain -> in-situ
    reuse -> drain, asserted on the scheduler event log."""
    from repro.core.workflow import JobSpec
    cluster.external.put("input_data", {"x": np.arange(100.0)})

    def sim(ctx):
        d = ctx.read("input_data")
        return {"fields": {"u": d["x"] * 2.0}}

    def analyze(ctx):
        f = ctx.read("fields")
        return {"report": {"mean": np.array([f["u"].mean()])}}

    cluster.workflows.run([
        JobSpec("sim", sim, inputs=("input_data",), retain=("fields",)),
        JobSpec("analyze", analyze, inputs=("fields",), after=("sim",),
                drain=("report",)),
    ])
    kinds = [k for _, k, _ in cluster.workflows.events]
    i_stage = kinds.index("stage_in")
    i_insitu = kinds.index("in_situ")
    i_drain = kinds.index("drain")
    assert i_stage < i_insitu < i_drain
    # drained output eventually lands on the external store
    for _ in range(100):
        if cluster.external.exists("report"):
            break
        import time; time.sleep(0.02)
    from repro.core.object_store import as_tree
    rep = as_tree(cluster.external.get("report"))
    assert abs(float(rep["mean"][0]) - 99.0) < 1e-6


def test_data_affinity_placement(cluster):
    from repro.core.workflow import JobSpec
    cluster.stores["node2"].put("big_input", {"x": np.zeros(16)})
    placed = {}

    def job(ctx):
        placed["nodes"] = ctx.nodes
        return {}

    cluster.workflows.run([JobSpec("j", job, inputs=("big_input",))])
    assert placed["nodes"][0] == "node2"  # lands where the data lives


def test_cleanup_reclaims_unretained(cluster):
    """cleanup() is the catalog's refcount/lease GC now: unretained
    bytes are reclaimed, but the record (lineage) survives."""
    from repro.core.workflow import JobSpec

    def job(ctx):
        return {"scratch": {"x": np.ones(4)}}

    res = cluster.workflows.run([JobSpec("j", job)])
    wf = res.workflow_id
    rec = cluster.catalog.record("scratch", wf)
    assert cluster.view.locate(rec["object"], rec["version"])
    cluster.workflows.cleanup()
    rec = cluster.catalog.record("scratch", wf)
    assert rec["reclaimed"]
    assert not cluster.view.locate(rec["object"], rec["version"])
    # lineage outlives the bytes
    chain = cluster.catalog.lineage("scratch", wf)
    assert chain and chain[0]["lineage"]["job"] == "j"


def test_failure_recovery_end_to_end(cluster):
    from repro.core.resilience import FailureRecovery
    state = {"w": np.random.RandomState(0).randn(8, 8).astype(np.float32)}
    cluster.checkpointer.save(3, state)
    cluster.checkpointer.wait_async()
    for nid in cluster.node_ids:
        cluster.heartbeat.beat(nid, 3)
    cluster.kill_node("node1")
    # node1's heartbeat is gone with its pmem -> detected dead
    rec = cluster.recovery.check_and_recover()
    assert rec is not None
    tree, manifest, dead = rec
    assert "node1" in dead
    np.testing.assert_array_equal(tree["w"], state["w"])
