"""Model substrate: per-arch forward/prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer as T

S = 24
B = 2


def _inputs(cfg, key=1):
    text_len = S - cfg.prefix_len
    tokens = jax.random.randint(jax.random.PRNGKey(key), (B, text_len), 0,
                                cfg.vocab_size)
    kw = {}
    if cfg.enc_dec:
        kw["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, S, cfg.d_model), jnp.float32) * 0.1
    if cfg.prefix_len:
        kw["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.prefix_len, cfg.d_model),
            jnp.float32) * 0.1
    return tokens, kw


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_forward_prefill_decode_consistent(arch):
    cfg = registry.get_smoke_config(arch)
    rt = T.ModelRuntime(tp=1, attn_impl="naive", max_seq=32, remat=False)
    params, specs = T.init_params(jax.random.PRNGKey(0), cfg, rt)
    tokens, kw = _inputs(cfg)
    hidden, _, _ = T.forward(params, cfg, rt, tokens, **kw)
    full_logits = T.lm_head(params, cfg, hidden)
    assert bool(jnp.isfinite(full_logits).all())
    logits_pre, cache = T.prefill(params, cfg, rt, tokens[:, :-1], **kw)
    logits_dec, _ = T.decode_step(params, cfg, rt, cache, tokens[:, -1],
                                  jnp.int32(S - 1))
    assert float(jnp.max(jnp.abs(logits_pre - full_logits[:, -2]))) < 0.05
    assert float(jnp.max(jnp.abs(logits_dec - full_logits[:, -1]))) < 0.05


@pytest.mark.parametrize("arch", ["gemma2-9b", "qwen2-72b", "mamba2-1.3b",
                                  "recurrentgemma-9b"])
def test_blockwise_matches_naive(arch):
    cfg = registry.get_smoke_config(arch)
    rt1 = T.ModelRuntime(tp=1, attn_impl="naive", max_seq=32, remat=False)
    rt2 = T.ModelRuntime(tp=1, attn_impl="blockwise", max_seq=32,
                         remat=False)
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, rt1)
    tokens, kw = _inputs(cfg)
    h1, _, _ = T.forward(params, cfg, rt1, tokens, **kw)
    h2, _, _ = T.forward(params, cfg, rt2, tokens, **kw)
    assert float(jnp.max(jnp.abs(h1.astype(jnp.float32) -
                                 h2.astype(jnp.float32)))) < 0.1


def test_padded_heads_equivalent():
    """TP head padding must not change the function: run the padded layout
    and the exact layout with the same underlying weights."""
    from repro.models.attention import make_head_layout
    cfg = registry.get_smoke_config("deepseek-coder-33b")  # 6 heads, kv 2
    rt1 = T.ModelRuntime(tp=1, attn_impl="naive", max_seq=32, remat=False)
    rt4 = T.ModelRuntime(tp=4, attn_impl="naive", max_seq=32, remat=False)
    l1 = rt1.head_layout(cfg)   # group 3 (exact)
    l4 = rt4.head_layout(cfg)   # group padded to 4 -> 8 q heads
    assert l1.group == 3 and l4.group == 4 and l4.q_heads == 8
    params4, _ = T.init_params(jax.random.PRNGKey(0), cfg, rt4)

    def depad(p4):
        """Strip padded q-head rows (group-major layout)."""
        import copy
        p1 = jax.tree.map(lambda x: x, p4)
        g4, g1, kh = l4.group, l1.group, l4.kv_heads
        keep = np.concatenate([np.arange(k * g4, k * g4 + g1)
                               for k in range(kh)])
        for grp in ("group0",):
            lp = p1[grp]["p0"]["mixer"]
            lp["wq"] = lp["wq"][:, :, keep]
            lp["wo"] = lp["wo"][:, keep]
        return p1

    params1 = depad(params4)
    tokens, kw = _inputs(cfg)
    h4, _, _ = T.forward(params4, cfg, rt4, tokens, **kw)
    h1, _, _ = T.forward(params1, cfg, rt1, tokens, **kw)
    assert float(jnp.max(jnp.abs(h4.astype(jnp.float32) -
                                 h1.astype(jnp.float32)))) < 1e-2


def test_local_attention_matches_masked_blockwise():
    from repro.models.attention import blockwise_attention, local_attention, \
        naive_attention
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 64, 2, 16), jnp.float32)
    o1 = local_attention(q, k, v, window=16, bq=16)
    o2 = naive_attention(q, k, v, causal=True, window=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5,
                               rtol=1e-4)


def test_causal_future_independence():
    """Changing future tokens must not change past hidden states (covers
    attention, RG-LRU, and SSD causality at once)."""
    for arch in ["gemma2-9b", "mamba2-1.3b", "recurrentgemma-9b"]:
        cfg = registry.get_smoke_config(arch)
        rt = T.ModelRuntime(tp=1, attn_impl="naive", max_seq=32, remat=False)
        params, _ = T.init_params(jax.random.PRNGKey(0), cfg, rt)
        tokens, kw = _inputs(cfg)
        t2 = tokens.at[:, -4:].set((tokens[:, -4:] + 7) % cfg.vocab_size)
        h1, _, _ = T.forward(params, cfg, rt, tokens, **kw)
        h2, _, _ = T.forward(params, cfg, rt, t2, **kw)
        cut = S - 4
        diff = float(jnp.max(jnp.abs(
            h1[:, :cut].astype(jnp.float32) -
            h2[:, :cut].astype(jnp.float32))))
        assert diff == 0.0, (arch, diff)
