"""Core B-APM substrate: pmem, object store, data scheduler, tiering."""
import time

import numpy as np
import pytest


def test_pmem_pool_byte_access(cluster):
    pool = cluster.pools["node0"]
    r = pool.create("raw/test.bin", 4096)
    data = np.arange(256, dtype=np.float32)
    r.write(128, data)
    r.flush()
    back = r.read(128, data.nbytes, dtype=np.float32, shape=(256,))
    np.testing.assert_array_equal(back, data)
    # byte-granular partial read (no block alignment needed)
    part = r.read(128 + 16, 8, dtype=np.float32, shape=(2,))
    np.testing.assert_array_equal(part, data[4:6])


def test_pmem_capacity_enforced(cluster):
    pool = cluster.pools["node0"]
    with pytest.raises(MemoryError):
        pool.create("huge.bin", pool.capacity_bytes + 1)


def test_object_store_roundtrip_and_crc(cluster):
    st = cluster.stores["node0"]
    tree = {"a": {"b": np.random.randn(16, 4).astype(np.float32)},
            "c": np.arange(10, dtype=np.int32)}
    st.put("obj1", tree)
    out = st.get("obj1", verify=True)
    np.testing.assert_array_equal(out["a"]["b"], tree["a"]["b"])
    np.testing.assert_array_equal(out["c"], tree["c"])
    # corruption detection: flip a byte in the data region
    region = st.pool.open("objects/obj1@v0.data")
    region._mm[3] ^= 0xFF
    with pytest.raises(IOError):
        st.get("obj1", verify=True)


def test_object_store_byte_range_read(cluster):
    st = cluster.stores["node0"]
    arr = np.arange(64, dtype=np.float32).reshape(16, 4)
    st.put("ranged", {"x": arr})
    sl = st.read_leaf_slice("ranged", "x", 4, 3)
    np.testing.assert_array_equal(sl, arr[4:7])


def test_data_scheduler_channels(cluster):
    cluster.external.put("ext_obj", {"x": np.ones(128, np.float32)})
    f = cluster.scheduler.stage_in("node1", "ext_obj", "staged")
    f.result()
    assert cluster.stores["node1"].exists("staged")
    f = cluster.scheduler.replicate("node1", "staged", "node2")
    f.result()
    assert cluster.stores["node2"].exists("replica/node1/staged")
    f = cluster.scheduler.drain("node1", "staged", "drained_out",
                                delete_after=True)
    f.result()
    assert cluster.external.exists("drained_out")
    assert not cluster.stores["node1"].exists("staged")
    assert cluster.scheduler.stats["node1"]["staged_in"] > 0


def test_distributed_store_union_view(cluster):
    cluster.stores["node3"].put("only_on_3", {"x": np.zeros(4)})
    assert cluster.view.locate("only_on_3") == ["node3"]
    out = cluster.view.get("only_on_3")
    assert out["x"].shape == (4,)


def test_staged_dataset_prefetch(cluster):
    from repro.configs import ShapeConfig, get_smoke_config
    from repro.data.pipeline import StagedDataset
    cfg = get_smoke_config("qwen2-72b")
    shape = ShapeConfig("t", 32, 4, "train")
    ds = StagedDataset(cluster, cfg, shape, n_shards=3, seqs_per_shard=8)
    batches = list(ds.batches(5))
    assert len(batches) == 5
    for b in batches:
        assert b["tokens"].shape == (4, 32)
        assert b["labels"].shape == (4, 32)
        assert (b["tokens"] < cfg.vocab_size).all()
