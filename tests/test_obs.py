"""Telemetry plane: metrics aliases, flight-recorder crash persistence,
and end-to-end trace propagation (PR 8)."""
import tempfile
from pathlib import Path

import pytest

from repro.core.pmem import PMemPool
from repro.obs import report as obs_report
from repro.obs.metrics import Counter, Histogram, Registry, StatsView
from repro.obs.recorder import EVT_BEGIN, EVT_END, EVT_POINT, \
    FlightRecorder
from repro.obs.trace import build_traces, connected_to_root, span_names


# ---- metrics / StatsView aliases -------------------------------------

def test_registry_counters_and_histograms():
    reg = Registry()
    c = reg.counter("x")
    assert reg.counter("x") is c  # create-or-get
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = reg.gauge("depth")
    g.set(3)
    g.dec()
    assert g.value == 2
    h = reg.histogram("lat")
    for v in (0.001, 0.002, 0.004, 0.1):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4
    assert s["min"] <= 0.001 and s["max"] >= 0.1
    assert s["p50"] <= s["p99"]
    snap = reg.snapshot()
    assert snap["counters"]["x"] == 5
    assert snap["histograms"]["lat"]["count"] == 4


def test_statsview_is_dict_shaped():
    counters = {"a": Counter("a"), "b": Counter("b")}
    view = StatsView(counters)
    counters["a"].inc(3)
    assert view["a"] == 3 and view["b"] == 0
    view["b"] += 2  # __getitem__ + __setitem__ round-trip
    assert counters["b"].value == 2
    assert view == {"a": 3, "b": 2}          # dict equality both ways
    assert dict(view) == {"a": 3, "b": 2}
    assert set(view) == {"a", "b"} and len(view) == 2


def test_legacy_stats_surfaces_are_registry_backed(cluster):
    c = cluster
    # TieredIO.stats reads through to tiered.* counters
    assert c.tiered.stats["saves"] == 0
    c.tiered.obs.registry.counter("tiered.saves").inc()
    assert c.tiered.stats["saves"] == 1
    # DLMCache int attributes read through to dlm.* counters
    assert c.dlm.hits == c.tiered.obs.registry.counter("dlm.hits").value


# ---- flight recorder -------------------------------------------------

def _mkpool(tmp=None):
    root = Path(tmp or tempfile.mkdtemp(prefix="repro_obs_"))
    return PMemPool(root, "node0"), root


def test_ring_wraparound_keeps_newest_events():
    pool, _ = _mkpool()
    rec = FlightRecorder(pool, slots=8, slot_bytes=128)
    for i in range(25):
        assert rec.record(EVT_POINT, f"ev{i}", attrs={"i": i})
    events = FlightRecorder.replay(pool)
    assert [e["seq"] for e in events] == list(range(17, 25))
    assert [e["attrs"]["i"] for e in events] == list(range(17, 25))


def test_recorder_reopen_adopts_committed_ring():
    pool, root = _mkpool()
    rec = FlightRecorder(pool, slots=16, slot_bytes=128)
    for i in range(5):
        rec.record(EVT_POINT, f"a{i}")
    # fresh process: different default geometry args must NOT reformat
    rec2 = FlightRecorder(PMemPool(root, "node0"))
    assert rec2.slots == 16 and rec2.committed == 5
    rec2.record(EVT_POINT, "after-restart")
    events = FlightRecorder.replay(pool)
    assert len(events) == 6
    assert events[-1]["name"] == "after-restart"


def test_record_on_dead_pool_is_counted_drop():
    pool, _ = _mkpool()
    rec = FlightRecorder(pool, slots=8, slot_bytes=128)
    assert rec.record(EVT_POINT, "alive")
    pool.fail()
    assert rec.record(EVT_POINT, "dead") is False
    assert rec.drops == 1
    assert rec.committed == 1  # the failed append committed nothing


def test_torn_tail_replay_is_committed_prefix(pmem_sanitizer):
    """Every crash image the sanitizer can enumerate (stores lost /
    persisted / final store torn) replays to a clean PREFIX of the
    committed event stream — the committed-tail discipline, proven by
    enumeration exactly like MetaLog's crash tests."""
    pool, _ = _mkpool()
    rec = FlightRecorder(pool, slots=8, slot_bytes=128)
    for i in range(6):
        rec.record(EVT_POINT, f"ev{i}", attrs={"i": i})
    full = [e["attrs"]["i"] for e in FlightRecorder.replay(pool)]
    assert full == list(range(6))
    spool, _ = _mkpool()
    n_images = 0
    for label, img in pmem_sanitizer.crash_images("flightring"):
        n_images += 1
        pmem_sanitizer.materialize(img, spool, "obs/flightring")
        got = [e["attrs"]["i"]
               for e in FlightRecorder.replay(spool)]
        assert got == full[:len(got)], label  # prefix, never torn/gappy
    assert n_images > 0


# ---- end-to-end trace propagation ------------------------------------

def _replay_cluster(c):
    events = []
    for nid, pool in c.pools.items():
        for ev in FlightRecorder.replay(pool):
            ev["node"] = nid
            events.append(ev)
    return events


def test_save_async_yields_one_connected_span_tree(cluster):
    c = cluster
    state = {"w": b"\x01" * 512}
    t = c.tiered.save_async(0, state, drain=True)
    t.result()
    c.tiered.quiesce()
    c.checkpointer.wait_async()
    traces = build_traces(_replay_cluster(c))
    ckpt_traces = [
        (tid, tr) for tid, tr in traces.items()
        if tid and any(tr["spans"][r]["name"] == "ckpt.save"
                       for r in tr["roots"])]
    assert len(ckpt_traces) == 1  # ONE save -> ONE trace
    tid, tr = ckpt_traces[0]
    names = span_names(tr)
    assert "ckpt.replicate" in names and "ckpt.drain" in names
    assert "sched.replicate" in names and "sched.drain" in names
    # every span in the trace hangs off the single ckpt.save root
    assert len(tr["roots"]) == 1
    for sid in tr["spans"]:
        assert connected_to_root(tr, sid)
    # the ack point events attached to their transfer spans
    acked = [ev["name"] for sp in tr["spans"].values()
             for ev in sp["events"]]
    assert "ckpt.ack.replica" in acked and "ckpt.ack.drain" in acked
    # ... and the trace id was persisted into the durable ack records,
    # so the correlation survives process death
    rec = c.checkpointer.ack_record(0)
    for nid in rec["ring"]:
        assert rec["acks"][nid]["replica"]["trace"] == tid
        assert rec["acks"][nid]["drain"]["trace"] == tid


def test_repair_sweep_is_traced(cluster):
    c = cluster
    c.tiered.save_async(0, {"w": b"\x02" * 256}).result()
    c.tiered.quiesce()
    c.checkpointer.wait_async()
    c.kill_node("node1")
    c.repair(["node1"])
    traces = build_traces(_replay_cluster(c))
    sweeps = [tr for tid, tr in traces.items()
              if tid and any(tr["spans"][r]["name"] == "repair.sweep"
                             for r in tr["roots"])]
    assert sweeps
    reg = c.tiered.obs.registry
    assert reg.counter("repair.checkpoint").value >= 1


def test_postcrash_report_recovers_timeline(cluster, capsys):
    """Kill a node mid-flight, then diagnose from the surviving rings
    alone via the report CLI — the ISSUE's acceptance scenario."""
    c = cluster
    c.tiered.save_async(0, {"w": b"\x03" * 512}, drain=True).result()
    c.tiered.quiesce()
    c.checkpointer.wait_async()
    t = c.tiered.save_async(1, {"w": b"\x04" * 512}, drain=True)
    c.kill_node("node2")  # crash while step 1's fan-out is in flight
    try:
        t.result()
    except Exception:
        pass
    c.tiered.quiesce()
    rc = obs_report.main([str(c.root / "pmem")])
    out = capsys.readouterr().out
    assert rc == 0
    assert "ckpt.save" in out
    assert "last event per ring:" in out
    # no clean shutdown happened: the rings ARE the record
    assert "no metrics snapshot found" in out
    # the dead node's ring is gone; survivors still reconstruct step 0
    events = _replay_cluster(c)
    assert {"node0", "node1", "node3"} <= {e["node"] for e in events}
    traces = build_traces(events)
    saves = [tr for tid, tr in traces.items()
             if tid and any(tr["spans"][r]["name"] == "ckpt.save"
                            for r in tr["roots"])]
    assert len(saves) >= 1


def test_clean_shutdown_persists_metrics_snapshot():
    from repro.core.cluster import SimCluster
    root = Path(tempfile.mkdtemp(prefix="repro_obs_"))
    c = SimCluster(root, n_nodes=2)
    c.tiered.save_async(0, {"w": b"\x05" * 128}).result()
    c.tiered.quiesce()
    c.checkpointer.wait_async()
    c.shutdown()
    snap = c.pools["node0"].get_json("obs/metrics.json")
    assert snap["counters"]["tiered.saves"] == 1
    assert "ckpt.save_commit_s" in snap["histograms"]


def test_workflow_jobs_share_one_trace(cluster):
    from repro.core.workflow import JobSpec
    c = cluster

    def produce(ctx):
        return {"out_a": {"x": b"\x06" * 64}}

    def consume(ctx):
        ctx.read("out_a")
        return {}

    c.workflows.run([JobSpec("p", produce),
                     JobSpec("q", consume, after=["p"],
                             inputs=["out_a"])])
    traces = build_traces(_replay_cluster(c))
    wf_traces = [tr for tid, tr in traces.items()
                 if tid and "wf.job" in span_names(tr)]
    assert wf_traces
    jobs = [sp["attrs"].get("job") for tr in wf_traces
            for sp in tr["spans"].values() if sp["name"] == "wf.job"]
    # both DAG jobs landed in a single workflow trace
    assert any({"p", "q"} <= set(
        sp["attrs"].get("job") for sp in tr["spans"].values()
        if sp["name"] == "wf.job") for tr in wf_traces), jobs


def test_telemetry_off_records_nothing(tmp_path):
    from repro.core.cluster import SimCluster
    c = SimCluster(tmp_path, n_nodes=2, telemetry=False)
    c.tiered.save_async(0, {"w": b"\x07" * 128}).result()
    c.tiered.quiesce()
    c.checkpointer.wait_async()
    assert c.tiered.stats["saves"] == 1  # DRAM metrics still work
    for pool in c.pools.values():
        assert FlightRecorder.replay(pool) == []
    c.shutdown()
