"""Replica-acknowledged checkpoint durability: SaveTicket.durability()
states, ack-ranked recovery (no store reads for ack-unrecoverable
steps), the recovery matrix (death inside the commit->ack window, delta
chains via buddy replicas, ack-map survival without node0), stale
metadata resolution, and the DLM/SLM cache-accounting fixes."""
import tempfile
from concurrent.futures import Future
from pathlib import Path

import numpy as np
import pytest


def _tree(seed=0):
    r = np.random.RandomState(seed)
    return {"w": r.randn(16, 8).astype(np.float32),
            "b": r.randn(8).astype(np.float32)}


# ---------------------------------------------------------------------------
# durability states + ack map
# ---------------------------------------------------------------------------

def test_durability_progression_to_replicated(cluster):
    t = cluster.tiered.save_async(1, _tree(1))
    t.result(timeout=30)
    cluster.tiered.quiesce()  # replicas placed, acks recorded
    assert t.durability() == "REPLICATED"
    acks = cluster.checkpointer.acks(1)
    ring = cluster.node_ids
    for nid in ring:
        rec = acks[nid]["replica"]
        assert rec["target"] == cluster.checkpointer.buddy_of(nid, ring)
        assert "ts" in rec


def test_durability_progression_to_drained(cluster):
    t = cluster.tiered.save_async(1, _tree(2), drain=True)
    t.result(timeout=30)
    cluster.tiered.quiesce()
    assert t.durability() == "DRAINED"
    acks = cluster.checkpointer.acks(1)
    for nid in cluster.node_ids:
        assert acks[nid]["drain"]["external"] == f"ckpt_step1_{nid}"


def test_durability_stays_local_without_replication():
    from repro.core.cluster import SimCluster
    root = Path(tempfile.mkdtemp(prefix="repro_test_"))
    c = SimCluster(root, n_nodes=4, buddy=False)
    try:
        t = c.tiered.save_async(1, _tree(3))
        t.result(timeout=30)
        c.tiered.quiesce()
        assert t.durability() == "LOCAL"
    finally:
        c.shutdown()


def test_durability_failed_commit(cluster):
    def boom(*a, **k):
        raise MemoryError("pmem full")
    cluster.checkpointer.save = boom
    t = cluster.tiered.save_async(1, _tree(0))
    with pytest.raises(MemoryError):
        t.result(timeout=30)
    assert t.durability() == "FAILED"
    cluster.tiered.quiesce()


def test_failed_drain_keeps_step_replicated_not_drained(cluster):
    def boom(name, tree):
        raise IOError("external store died mid-drain")
    cluster.external.put = boom
    t = cluster.tiered.save_async(1, _tree(4), drain=True)
    t.result(timeout=30)
    errors = t.wait_post_commit(timeout=30)
    assert errors and all("mid-drain" in str(e) for e in errors)
    # replicas acked, drains not: durability honestly reports REPLICATED
    assert t.durability() == "REPLICATED"
    acks = cluster.checkpointer.acks(1)
    assert all("drain" not in acks.get(n, {}) for n in cluster.node_ids)


# ---------------------------------------------------------------------------
# ack-ranked recovery (the acceptance criterion: no store reads for
# steps the ack map already rules out)
# ---------------------------------------------------------------------------

def _record_store_reads(cluster):
    """Wrap every store's object-read/probe entry points, recording the
    object names touched. Metadata (pool JSON) reads stay unrecorded —
    the ack ranking is ALLOWED to read manifests."""
    reads = []

    def wrap(st):
        orig_get, orig_exists = st.get_with_manifest, st.exists

        def get_with_manifest(name, *a, **k):
            reads.append(name)
            return orig_get(name, *a, **k)

        def exists(name, *a, **k):
            reads.append(name)
            return orig_exists(name, *a, **k)
        st.get_with_manifest, st.exists = get_with_manifest, exists

    for st in cluster.stores.values():
        wrap(st)
    return reads


def test_ack_skip_needs_no_store_reads(cluster):
    """A step whose ack map shows the lost node unreplicated must be
    skipped purely on metadata — not a single object-store read."""
    c = cluster
    c.tiered.save_async(1, _tree(1)).result(timeout=30)
    c.tiered.quiesce()  # step 1 fully replicated + acked
    # step 2: commit succeeds, but the node dies before any replica ack
    # (emulated by a fabric that fails every replicate)

    def dead_replicate(src, obj, dst, **kw):
        f = Future()
        f.set_exception(IOError("fabric down"))
        return f
    c.scheduler.replicate = dead_replicate
    man2 = c.tiered.save_async(2, _tree(2)).result(timeout=30)
    c.tiered.quiesce()
    victim = c.node_ids[-1]
    c.kill_node(victim)

    reads = _record_store_reads(c)
    out, man = c.checkpointer.restore_latest_recoverable(
        lost_nodes=[victim])
    assert man["step"] == 1
    np.testing.assert_array_equal(out["w"], _tree(1)["w"])
    assert c.checkpointer.last_restore_stats == \
        {"skipped_by_ack": 1, "probed": 1}
    slot2_obj = f"ckpt/slot{man2['slot']}"
    assert not any(slot2_obj in name for name in reads), \
        f"store reads touched the skipped step: {reads}"


def test_probe_all_still_works_without_acks(cluster):
    """use_acks=False preserves the old probe-everything walk (the
    benchmark's baseline) and lands on the same answer."""
    c = cluster
    c.tiered.save_async(1, _tree(1)).result(timeout=30)
    c.tiered.quiesce()
    c.checkpointer.buddy = False  # step 2 gets no replicas, no acks
    c.tiered.save_async(2, _tree(2)).result(timeout=30)
    c.tiered.quiesce()
    victim = c.node_ids[-1]
    c.kill_node(victim)
    out, man = c.checkpointer.restore_latest_recoverable(
        lost_nodes=[victim], use_acks=False)
    assert man["step"] == 1
    assert c.checkpointer.last_restore_stats["probed"] == 2
    assert c.checkpointer.last_restore_stats["skipped_by_ack"] == 0


def test_replica_on_another_dead_node_is_skipped(cluster):
    """An acked replica is useless if its TARGET died too: the ack
    ranking must rule the step out without probing."""
    c = cluster
    c.tiered.save_async(1, _tree(1)).result(timeout=30)
    c.tiered.quiesce()
    ring = c.node_ids
    victim = ring[-1]
    buddy = c.checkpointer.buddy_of(victim, ring)  # holds victim's replica
    with pytest.raises(IOError):
        c.checkpointer.restore_latest_recoverable(
            lost_nodes=[victim, buddy])
    assert c.checkpointer.last_restore_stats["skipped_by_ack"] == 1
    assert c.checkpointer.last_restore_stats["probed"] == 0


def test_delta_chain_restore_via_buddy_replica(cluster_delta):
    """Recovery matrix: a delta checkpoint restored for a lost node must
    decode against the BASE's buddy replica as well."""
    c = cluster_delta
    base = _tree(5)
    c.checkpointer.save(1, base)
    t2 = {k: v + np.float32(1e-3) for k, v in base.items()}
    c.checkpointer.save(2, t2, base_step=1)
    c.checkpointer.wait_async()  # replicas + acks for both steps
    victim = c.node_ids[-1]
    c.kill_node(victim)
    out, man = c.checkpointer.restore_latest_recoverable(
        lost_nodes=[victim])
    assert man["step"] == 2 and man["delta_base"] == 1
    assert np.abs(out["w"] - t2["w"]).max() < 1e-4
    assert c.checkpointer.last_restore_stats == \
        {"skipped_by_ack": 0, "probed": 1}


def test_delta_durability_capped_by_unreplicated_base(cluster_delta):
    """A delta step is only as durable as its base chain: full replica
    acks on the delta slot must not report REPLICATED when the base
    never replicated, and the ack ranking must skip the whole chain."""
    c = cluster_delta

    def dead_replicate(src, obj, dst, **kw):
        f = Future()
        f.set_exception(IOError("fabric down"))
        return f
    orig = c.scheduler.replicate
    c.scheduler.replicate = dead_replicate  # base replication dies
    base = _tree(9)
    c.tiered.save_async(1, base).result(timeout=30)
    c.tiered.quiesce()
    c.scheduler.replicate = orig  # fabric back for the delta save
    t2 = c.tiered.save_async(
        2, {k: v + np.float32(1e-3) for k, v in base.items()}, base_step=1)
    t2.result(timeout=30)
    c.tiered.quiesce()
    # delta slot fully acked, but the chain is only locally durable
    assert set(c.checkpointer.acks(2)) == set(c.node_ids)
    assert t2.durability() == "LOCAL"
    # ...and recovery rules out BOTH steps on metadata alone
    victim = c.node_ids[-1]
    c.kill_node(victim)
    with pytest.raises(IOError):
        c.checkpointer.restore_latest_recoverable(lost_nodes=[victim])
    assert c.checkpointer.last_restore_stats == \
        {"skipped_by_ack": 2, "probed": 0}


def test_ack_map_survives_node0_loss(cluster):
    """Acks are replicated with the manifests: losing node0 (the old
    single meta store) must not forget which steps are durable."""
    c = cluster
    t = c.tiered.save_async(1, _tree(6))
    t.result(timeout=30)
    c.tiered.quiesce()
    c.kill_node("node0")
    acks = c.checkpointer.acks(1)
    assert set(acks) == set(c.node_ids)  # all four acks still known
    assert t.durability() == "REPLICATED"
    out, man = c.checkpointer.restore_latest_recoverable(
        lost_nodes=["node0"])
    assert man["step"] == 1
    np.testing.assert_array_equal(out["w"], _tree(6)["w"])
    assert c.checkpointer.last_restore_stats == \
        {"skipped_by_ack": 0, "probed": 1}


# ---------------------------------------------------------------------------
# satellite: stale metadata resolution
# ---------------------------------------------------------------------------

def test_stale_latest_on_rejoined_node_is_outvoted(cluster):
    """A rejoined node0 carrying an old ckpt/latest.json must not shadow
    the newer replicated pointer (fixed-node-order bug)."""
    c = cluster
    c.checkpointer.save(1, _tree(1))
    c.checkpointer.save(2, _tree(2))
    c.checkpointer.wait_async()
    # node0 "rejoins" with a stale pointer from before its outage
    c.pools["node0"].put_json("ckpt/latest.json", {"step": 1})
    assert c.checkpointer.latest_step() == 2
    out, man = c.checkpointer.restore()
    assert man["step"] == 2


# ---------------------------------------------------------------------------
# satellite: raise_if_failed clears the raised error
# ---------------------------------------------------------------------------

def test_raise_if_failed_clears_after_raise(cluster):
    c = cluster
    orig = c.checkpointer.save

    def boom(*a, **k):
        raise MemoryError("pmem full")
    c.checkpointer.save = boom
    t = c.tiered.save_async(1, _tree(0))
    with pytest.raises(MemoryError):
        t.result(timeout=30)
    with pytest.raises(MemoryError):
        c.tiered.raise_if_failed()
    # the error was popped: after recovery the engine is clean...
    c.checkpointer.save = orig
    c.tiered.raise_if_failed()  # must NOT re-raise the stale error
    # ...and the next checkpoint boundary works normally
    c.tiered.save_async(2, _tree(2)).result(timeout=30)
    c.tiered.raise_if_failed()


# ---------------------------------------------------------------------------
# satellite: DLM cache accounting
# ---------------------------------------------------------------------------

def _obj(nbytes, seed=0):
    return {"x": np.full(nbytes // 4, seed, np.float32)}


def test_dlm_running_total_stays_exact(cluster):
    from repro.core.tiering import DLMCache
    cache = DLMCache(cluster.stores["node0"], capacity_bytes=4096)
    for i in range(8):
        cache.put(f"o{i}", _obj(1024, i))
        assert cache.used_bytes() == sum(cache._sizes.values())
        assert cache.used_bytes() <= cache.capacity
    assert cache.evictions > 0
    cache.put("o7", _obj(2048, 99))  # replace with a bigger body
    assert cache.used_bytes() == sum(cache._sizes.values())
    cache.evict_cold()
    assert cache.used_bytes() == 0


def test_dlm_oversized_put_bypasses_dram(cluster):
    from repro.core.tiering import DLMCache
    st = cluster.stores["node0"]
    cache = DLMCache(st, capacity_bytes=1024)
    cache.put("small", _obj(512, 1))
    cache.put("huge", _obj(4096, 2))  # > capacity: must not be admitted
    assert not cache.contains("huge")
    assert cache.bypasses == 1
    assert cache.used_bytes() <= cache.capacity
    assert st.exists("dlm/huge")  # ...but it IS durable (write-through)
    # the resident small object survived (no pointless full eviction)
    assert cache.contains("small")
    # demand read of the oversized object serves it uncached
    out = cache.get("huge")
    np.testing.assert_array_equal(out["x"], _obj(4096, 2)["x"])
    assert not cache.contains("huge")
    assert cache.used_bytes() <= cache.capacity


# ---------------------------------------------------------------------------
# satellite: SLM offload version guard
# ---------------------------------------------------------------------------

def test_slm_roundtrip_and_isolation(cluster):
    from repro.core.tiering import SLMTier
    st = cluster.stores["node0"]
    a = SLMTier(st, "opt")
    tree = {"m": np.arange(8, dtype=np.float32),
            "v": np.ones(4, np.float32), "p": np.zeros(2, np.float32)}
    resident, handle = a.offload(tree, ["m", "v"])
    out = a.fetch(resident, handle)
    np.testing.assert_array_equal(out["m"], tree["m"])
    np.testing.assert_array_equal(out["v"], tree["v"])


def test_slm_offload_survives_process_restart(cluster):
    """The point of B-APM offload: a FRESH tier instance (new process)
    must recover the leaves via the persisted head pointer."""
    from repro.core.tiering import SLMTier
    st = cluster.stores["node0"]
    a = SLMTier(st, "opt")
    tree = {"m": np.arange(8, dtype=np.float32)}
    resident, handle = a.offload(tree, ["m"])
    b = SLMTier(st, "opt")  # restarted process, no in-memory version
    out = b.fetch(resident, handle)
    np.testing.assert_array_equal(out["m"], tree["m"])


def test_slm_fetch_before_offload_fails_loudly(cluster):
    from repro.core.tiering import SLMTier
    t = SLMTier(cluster.stores["node0"], "opt")
    with pytest.raises(RuntimeError):
        t.fetch({}, [])


def test_slm_racing_offload_detected(cluster):
    """Another tier instance overwriting our versioned object (or a
    version-tag mismatch) must fail fetch, not silently merge."""
    from repro.core.tiering import SLMTier
    st = cluster.stores["node0"]
    a = SLMTier(st, "opt")
    tree_a = {"m": np.arange(8, dtype=np.float32)}
    resident, handle = a.offload(tree_a, ["m"])
    # a racing writer clobbers a's object at the SAME store version with
    # a different tag — exactly the silent-merge hazard
    st.put("slm/opt", {"m": np.zeros(8, np.float32)},
           version=a._version, meta={"v": 12345})
    with pytest.raises(IOError):
        a.fetch(resident, handle)


def test_slm_two_instances_stay_isolated(cluster):
    from repro.core.tiering import SLMTier
    st = cluster.stores["node0"]
    a, b = SLMTier(st, "opt"), SLMTier(st, "opt")
    tree_a = {"m": np.full(8, 1.0, np.float32)}
    tree_b = {"m": np.full(8, 2.0, np.float32)}
    res_a, h_a = a.offload(tree_a, ["m"])
    res_b, h_b = b.offload(tree_b, ["m"])
    out_b = b.fetch(res_b, h_b)
    np.testing.assert_array_equal(out_b["m"], tree_b["m"])
    # a's fetch either returns a's own (isolated) leaves or raises —
    # never b's data merged silently
    try:
        out_a = a.fetch(res_a, h_a)
        np.testing.assert_array_equal(out_a["m"], tree_a["m"])
    except IOError:
        pass


# ---------------------------------------------------------------------------
# multi-node DLM: prefetch/fetch fall back to buddy replicas
# ---------------------------------------------------------------------------

def test_dlm_prefetch_falls_back_to_buddy_replica(cluster):
    c = cluster
    t = _tree(7)
    c.tiered.offload("serve/sess", t).result(timeout=30)
    c.tiered.quiesce()  # the buddy replica of dlm/serve/sess is placed
    assert c.tiered.evict_cold() >= 1  # DRAM empty; pmem is the only copy
    c.kill_node("node0")  # the DLM home node dies
    res = c.tiered.prefetch(["serve/sess"]).result(timeout=30)
    assert res == {"hits": 0, "loads": 1, "missing": 0}
    out = c.tiered.fetch("serve/sess")
    np.testing.assert_array_equal(out["w"], t["w"])


def test_dlm_replica_lands_on_survivor_when_static_buddy_dead(cluster):
    """Offload must pick the replica target from the LIVE ring: with the
    home's static buddy dead, the replica lands on a survivor and reads
    still work after the home dies too."""
    c = cluster
    c.kill_node("node1")  # node0's static ring buddy
    t = _tree(8)
    c.tiered.offload("serve/sess2", t).result(timeout=30)
    c.tiered.quiesce()
    assert c.stores["node2"].exists("replica/node0/dlm/serve/sess2")
    c.tiered.evict_cold()
    c.kill_node("node0")
    out = c.tiered.fetch("serve/sess2")
    np.testing.assert_array_equal(out["w"], t["w"])


def test_dlm_missing_everywhere_still_advisory(cluster):
    c = cluster
    c.kill_node("node0")
    res = c.tiered.prefetch(["serve/nope"]).result(timeout=30)
    assert res == {"hits": 0, "loads": 0, "missing": 1}
    c.tiered.join()  # nothing fatal recorded


# ---------------------------------------------------------------------------
# drain-tier recovery: external drained copy as the last resort,
# consulted only via recorded drain acks (never probed blindly)
# ---------------------------------------------------------------------------

def test_restore_falls_back_to_drained_copy(cluster):
    """Shard owner AND its ring buddy die: the replica is gone with the
    buddy, but the acknowledged drain makes the step recoverable from
    the external store."""
    c = cluster
    t = _tree(10)
    c.tiered.save_async(1, t, drain=True).result(timeout=30)
    c.tiered.quiesce()  # replicas AND drains acked
    # node2's replica lives on node3 — kill both
    c.kill_node("node2")
    c.kill_node("node3")
    tree, man = c.checkpointer.restore_latest_recoverable(
        lost_nodes=["node2", "node3"])
    assert man["step"] == 1
    np.testing.assert_array_equal(tree["w"], t["w"])
    np.testing.assert_array_equal(tree["b"], t["b"])


def test_undrained_step_skipped_on_metadata_alone(cluster):
    """A step that is neither replica- nor drain-recoverable for the
    lost pair must be skipped without any store reads, landing on the
    older drained step."""
    c = cluster
    c.tiered.save_async(1, _tree(11), drain=True).result(timeout=30)
    c.tiered.quiesce()
    # step 2: replication disabled and external dead -> LOCAL only
    c.checkpointer.buddy = False

    def boom(name, tree):
        raise IOError("external down")
    put, c.external.put = c.external.put, boom
    c.tiered.save_async(2, _tree(12), drain=True).result(timeout=30)
    c.tiered.quiesce()  # drain errors collected, no acks recorded
    c.external.put = put
    c.kill_node("node2")
    c.kill_node("node3")
    tree, man = c.checkpointer.restore_latest_recoverable(
        lost_nodes=["node2", "node3"])
    assert man["step"] == 1
    assert c.checkpointer.last_restore_stats["skipped_by_ack"] == 1
    np.testing.assert_array_equal(tree["w"], _tree(11)["w"])


def test_drain_ack_alone_marks_step_plausible(cluster):
    """With replication disabled entirely, an acked drain still makes a
    lost node's step plausible (and restorable) from the external tier."""
    c = cluster
    c.checkpointer.buddy = False
    c.tiered.save_async(1, _tree(13), drain=True).result(timeout=30)
    c.tiered.quiesce()
    c.kill_node("node1")
    assert c.checkpointer._acks_plausible(1, ["node1"])
    tree, man = c.checkpointer.restore_latest_recoverable(
        lost_nodes=["node1"])
    assert man["step"] == 1
    np.testing.assert_array_equal(tree["w"], _tree(13)["w"])
