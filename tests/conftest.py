import importlib.util
import tempfile
import threading
from pathlib import Path

import pytest

# NOTE: no XLA_FLAGS here by design — smoke tests must see the real (1)
# device count. Multi-device distributed tests run in subprocesses
# (tests/test_distributed.py) with their own device-count env.

_HAVE_PYTEST_TIMEOUT = importlib.util.find_spec("pytest_timeout") is not None


def pytest_addoption(parser):
    parser.addoption(
        "--pmem-sanitize", action="store_true", default=False,
        help="run every test under the pmem persistence-order sanitizer "
             "(repro.analysis.sanitizer): committed-tail discipline and "
             "dirty-region drops become test failures")
    if not _HAVE_PYTEST_TIMEOUT:
        # Fallback for environments without the pytest-timeout plugin
        # (requirements-dev installs it in CI): register the ini options
        # so pytest.ini parses cleanly; the SIGALRM fixture below
        # enforces the per-test budget.
        parser.addini("timeout", "per-test timeout in seconds (fallback "
                                 "shim; install pytest-timeout for the "
                                 "real plugin)")
        parser.addini("timeout_method", "ignored by the fallback shim "
                                        "(SIGALRM only)")


if not _HAVE_PYTEST_TIMEOUT:
    @pytest.fixture(autouse=True)
    def _fallback_timeout(request):
        import signal
        raw = request.config.getini("timeout")
        secs = int(float(raw)) if raw else 0
        if (secs <= 0 or not hasattr(signal, "SIGALRM")
                or threading.current_thread()
                is not threading.main_thread()):
            yield
            return

        def _expire(signum, frame):
            raise TimeoutError(
                f"test exceeded the {secs}s per-test timeout "
                f"(fallback SIGALRM enforcement)")
        old = signal.signal(signal.SIGALRM, _expire)
        signal.alarm(secs)
        try:
            yield
        finally:
            signal.alarm(0)
            signal.signal(signal.SIGALRM, old)


@pytest.fixture(autouse=True)
def _pmem_sanitize(request):
    """With ``--pmem-sanitize``: every test runs under the persistence-
    order sanitizer. Installed before (and torn down after) the other
    function-scope fixtures, so cluster shutdown happens inside the
    shimmed window and teardown-dirty regions are caught. Violations
    surface as a teardown error for the offending test."""
    if not request.config.getoption("--pmem-sanitize"):
        yield None
        return
    from repro.analysis.sanitizer import PMemSanitizer
    san = PMemSanitizer().install()
    try:
        yield san
    finally:
        san.uninstall()
    san.raise_violations()


@pytest.fixture()
def pmem_sanitizer():
    """Explicit capture-mode sanitizer for crash-state enumeration tests
    (records written bytes so ``crash_images()`` works)."""
    from repro.analysis.sanitizer import PMemSanitizer
    san = PMemSanitizer(capture=True).install()
    try:
        yield san
    finally:
        san.uninstall()
    san.raise_violations()


@pytest.fixture()
def cluster():
    from repro.core.cluster import SimCluster
    root = Path(tempfile.mkdtemp(prefix="repro_test_"))
    c = SimCluster(root, n_nodes=4)
    yield c
    c.shutdown()


@pytest.fixture()
def cluster_slow_external():
    """Cluster whose external tier is throttled to 1 MB/s — async paths
    must hide it; blocking ones would visibly stall."""
    from repro.core.cluster import SimCluster
    root = Path(tempfile.mkdtemp(prefix="repro_test_"))
    c = SimCluster(root, n_nodes=2, external_bandwidth=1e6)
    yield c
    c.shutdown()


@pytest.fixture()
def cluster_delta():
    from repro.core.cluster import SimCluster
    root = Path(tempfile.mkdtemp(prefix="repro_test_"))
    c = SimCluster(root, n_nodes=4, delta=True)
    yield c
    c.shutdown()
