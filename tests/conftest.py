import tempfile
from pathlib import Path

import pytest

# NOTE: no XLA_FLAGS here by design — smoke tests must see the real (1)
# device count. Multi-device distributed tests run in subprocesses
# (tests/test_distributed.py) with their own device-count env.


@pytest.fixture()
def cluster():
    from repro.core.cluster import SimCluster
    root = Path(tempfile.mkdtemp(prefix="repro_test_"))
    c = SimCluster(root, n_nodes=4)
    yield c
    c.shutdown()


@pytest.fixture()
def cluster_slow_external():
    """Cluster whose external tier is throttled to 1 MB/s — async paths
    must hide it; blocking ones would visibly stall."""
    from repro.core.cluster import SimCluster
    root = Path(tempfile.mkdtemp(prefix="repro_test_"))
    c = SimCluster(root, n_nodes=2, external_bandwidth=1e6)
    yield c
    c.shutdown()


@pytest.fixture()
def cluster_delta():
    from repro.core.cluster import SimCluster
    root = Path(tempfile.mkdtemp(prefix="repro_test_"))
    c = SimCluster(root, n_nodes=4, delta=True)
    yield c
    c.shutdown()
