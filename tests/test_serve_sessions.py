"""Multi-tenant serve tier: sessions as leased exchange datasets.

Covers the SessionManager lifecycle (spill -> lease handoff -> gc
safety -> lease-release eviction -> end), cross-process adoption,
metadata-only recoverability, the replica read path after a home-node
death (with a store-read audit proving zero blind probes), the
wire-codec + replica + byte-range `peek` composition, and the two
engine-level bug regressions (jitted prefill routing, spill-ticket
host-copy ownership).
"""
import tempfile
import time
from concurrent.futures import Future
from pathlib import Path

import numpy as np
import pytest


@pytest.fixture()
def cluster_codec():
    """4-node cluster with the delta-int8 wire codec on every
    replicate/drain/repair transfer."""
    from repro.core.cluster import SimCluster
    root = Path(tempfile.mkdtemp(prefix="repro_test_"))
    c = SimCluster(root, n_nodes=4, wire_codec=True)
    yield c
    c.shutdown()


def _state(seed=0, n=64):
    r = np.random.RandomState(seed)
    return {"cache": {"k": r.randn(2, n).astype(np.float32),
                      "v": r.randn(2, n).astype(np.float32)},
            "pos": np.int32(7 + seed)}


class FakeEngine:
    """export_state/install_state contract double — the manager never
    looks at the math, only at the state tree handoff."""

    def __init__(self, label="e0"):
        self.label = label
        self.cache = None
        self._state = None

    def export_state(self, release=False):
        assert self._state is not None, "no session state resident"
        out = {"cache": dict(self._state["cache"]),
               "pos": np.int32(self._state["pos"])}
        if release:
            self._state = None
        return out

    def install_state(self, obj):
        self._state = {"cache": {k: np.asarray(v)
                                 for k, v in obj["cache"].items()},
                       "pos": int(obj["pos"])}

    def seed(self, tree):
        self.install_state(tree)
        return self

    @property
    def pos(self):
        return self._state["pos"]


def _record_store_reads(c):
    """Audit every object-store DATA read (get_with_manifest / exists /
    get_leaf) across the cluster; returns the list the wrappers append
    to. Metadata (pool JSON) reads are not data probes and don't count."""
    reads = []
    for nid, st in c.stores.items():
        for meth in ("get_with_manifest", "exists", "get_leaf"):
            orig = getattr(st, meth)

            def wrapped(name, *a, _orig=orig, _nid=nid, **kw):
                reads.append((_nid, name))
                return _orig(name, *a, **kw)

            setattr(st, meth, wrapped)
    return reads


# ---------------------------------------------------------------------------
# lifecycle: spill publishes a leased dataset with lineage
# ---------------------------------------------------------------------------

def test_spill_publishes_versioned_dataset_with_lineage(cluster):
    sm = cluster.sessions
    sm.publish_prefix("sys", _state(1))
    eng = FakeEngine().seed(_state(2))
    sm.start("chat", eng, prefix="sys")
    # the fork actually installed the prefix state
    assert eng.pos == int(_state(1)["pos"])
    rec = sm.spill("chat")
    assert rec["version"] == 1 and rec["digest"]
    assert ["prefix/sys", "serve", 1] in rec["lineage"]["inputs"]
    rec2 = sm.spill("chat")
    assert rec2["version"] == 2
    assert ["sess/chat", "serve", 1] in rec2["lineage"]["inputs"]
    # the whole derivation chain is queryable from the catalog
    chain = cluster.catalog.lineage("sess/chat", "serve")
    names = [r.get("name") for r in chain if "name" in r]
    assert "prefix/sys" in names


def test_gc_never_reclaims_live_leased_session(cluster):
    sm = cluster.sessions
    eng = FakeEngine().seed(_state(3))
    sm.start("live", eng)
    sm.spill("live")
    cluster.tiered.quiesce()
    assert cluster.catalog.gc() == []  # leased + retained: untouchable
    # superseded version IS reclaimed once a newer spill supersedes it
    sm.spill("live")
    cluster.tiered.quiesce()
    assert cluster.catalog.gc() == [("serve", "sess/live", 1)]
    # ... but the record survives reclaim (lineage outlives bytes)
    assert cluster.catalog.record("sess/live", "serve", 1)["reclaimed"]
    # end(): every version unretained -> bytes reclaimed next sweep
    sm.end("live")
    cluster.tiered.quiesce()
    assert ("serve", "sess/live", 2) in cluster.catalog.gc()


def test_eviction_releases_lease_instead_of_deleting(cluster):
    sm = cluster.sessions
    eng = FakeEngine().seed(_state(4))
    sm.start("cold", eng)
    sm.suspend("cold")
    # bound sessions are never eviction candidates
    eng2 = FakeEngine().seed(_state(5))
    sm.start("hot", eng2)
    assert sm.choose_evictions(0.0) == ["cold"]
    assert sm.evict_cold(0.0) == ["cold"]
    assert sm._sessions["cold"].lease is None
    # bytes stayed durable: resume re-acquires the lease and reads back
    sm.resume("cold", FakeEngine("e1"))
    assert sm._sessions["cold"].lease is not None
    cluster.tiered.quiesce()
    # still leased again -> gc still can't touch it
    assert ("serve", "sess/cold", 1) not in cluster.catalog.gc()


def test_resume_rejects_double_bind_and_unknown(cluster):
    sm = cluster.sessions
    eng = FakeEngine().seed(_state(6))
    sm.start("s", eng)
    with pytest.raises(RuntimeError):
        sm.resume("s", FakeEngine())
    with pytest.raises(KeyError):
        sm.resume("nope", FakeEngine())


# ---------------------------------------------------------------------------
# fleet: cross-process adoption + replica resume with zero blind probes
# ---------------------------------------------------------------------------

def test_adoption_resumes_session_published_elsewhere(cluster):
    from repro.serve.sessions import SessionManager
    sm = cluster.sessions
    eng = FakeEngine().seed(_state(7))
    sm.start("shared", eng)
    sm.suspend("shared")
    # "another process": a fresh manager over the same catalog
    sm2 = SessionManager(cluster.tiered, cluster.catalog, obs=cluster.obs)
    eng2 = FakeEngine("e2")
    sm2.resume("shared", eng2)
    assert eng2.pos == int(_state(7)["pos"])
    # the persisted trace id reconnected the lifetime span tree
    rec = cluster.catalog.record("sess/shared", "serve")
    assert rec["annotations"]["session"] == "shared"
    assert sm2._sessions["shared"].span.trace == \
        rec["annotations"]["trace"]


def test_resume_from_acked_replica_zero_probes_after_home_death(cluster):
    sm = cluster.sessions
    eng = FakeEngine().seed(_state(8))
    sm.start("surv", eng)
    sm.suspend("surv")
    cluster.tiered.quiesce()
    rec = cluster.catalog.record("sess/surv", "serve")
    home = rec["home"]
    buddy = rec["acks"]["replica"]["targets"][0]
    # metadata-only recoverability BEFORE touching any bytes
    reads = _record_store_reads(cluster)
    assert "surv" in sm.recoverable_sessions([home])
    assert reads == [], f"recoverable_sessions probed stores: {reads}"
    cluster.kill_node(home)
    # DLM may hold a DRAM copy from the spill — drop it so the resume
    # exercises the replica read path
    sm.evict_cold(0.0)
    cluster.catalog.cache and cluster.catalog.cache.drop(
        f"exch/serve/sess/surv@v{rec['version']}")
    del reads[:]
    eng2 = FakeEngine("e2")
    sm.resume("surv", eng2)
    assert eng2.pos == int(_state(8)["pos"])
    # every byte off a LIVE node came from the ACKED buddy replica — no
    # blind fan-out (the one failed touch of the dead home is the read
    # path learning the pool is gone, not a probe of a live store)
    data_reads = [(n, o) for n, o in reads
                  if not o.endswith(".json") and n != home]
    assert data_reads, "resume never touched pmem?"
    for nid, obj in data_reads:
        assert obj.startswith("replica/"), (nid, obj)
        assert nid == buddy, (nid, obj, buddy)


# ---------------------------------------------------------------------------
# satellite: peek on a WIRE-ENCODED spill off an acked replica after the
# home node dies (codec + replica fallback + byte-range composition)
# ---------------------------------------------------------------------------

def test_peek_session_wire_codec_replica_after_home_death(cluster_codec):
    from repro.serve.engine import ServeEngine
    c = cluster_codec
    eng = ServeEngine.__new__(ServeEngine)  # no model needed for spill
    eng.tiered, eng.store = c.tiered, None
    state = _state(9, n=256)
    eng.cache, eng.pos = state["cache"], int(state["pos"])
    eng.spill("wired")  # replicate rides the delta-int8 wire codec
    c.tiered.quiesce()
    c.tiered.evict_cold(0.0)  # drop DRAM residency: read pmem bytes
    c.kill_node("node0")  # the DLM home — only the replica survives
    reads = _record_store_reads(c)
    np.testing.assert_array_equal(eng.peek_session("wired", "cache/k"),
                                  state["cache"]["k"])
    assert int(eng.peek_session("wired", "pos")) == int(state["pos"])
    data_reads = [(n, o) for n, o in reads
                  if not o.endswith(".json") and n != "node0"]
    assert data_reads, "peek never touched pmem?"
    for nid, obj in data_reads:
        assert obj.startswith("replica/"), (nid, obj)


def test_manager_peek_wire_codec_replica_after_home_death(cluster_codec):
    c = cluster_codec
    sm = c.sessions
    state = _state(10, n=256)
    eng = FakeEngine().seed(state)
    sm.start("wired2", eng)
    sm.suspend("wired2")
    c.tiered.quiesce()
    rec = c.catalog.record("sess/wired2", "serve")
    c.kill_node(rec["home"])
    np.testing.assert_array_equal(sm.peek("wired2", "cache/v"),
                                  state["cache"]["v"])
    assert int(sm.peek("wired2", "pos")) == int(state["pos"])
    assert c.catalog.stats["replica_reads"] >= 2


# ---------------------------------------------------------------------------
# failed async suspend parks the host copy (nothing is ever lost)
# ---------------------------------------------------------------------------

def test_failed_async_suspend_parks_state_and_resume_recovers(cluster):
    sm = cluster.sessions
    eng = FakeEngine().seed(_state(11))
    sm.start("flaky", eng)
    orig = cluster.catalog.publish

    def boom(*a, **kw):
        raise IOError("injected publish failure")

    cluster.catalog.publish = boom
    try:
        fut = sm.suspend("flaky", wait=False)
        with pytest.raises(IOError):
            fut.result(timeout=30)
        sm.join()
        assert sm._sessions["flaky"].pending_state is not None
        # resume installs straight from the parked DRAM copy
        eng2 = FakeEngine("e2")
        sm.resume("flaky", eng2)
        assert eng2.pos == int(_state(11)["pos"])
    finally:
        cluster.catalog.publish = orig
    # next successful spill clears the parked copy
    sm.spill("flaky")
    assert sm._sessions["flaky"].pending_state is None


def test_engine_spill_ticket_owns_host_copy_on_failure():
    """Satellite regression: spill(wait=False) used to free self.cache
    before the async offload was durable — a failed future silently
    lost the session. The ticket now parks the host copy and names the
    session in the error."""
    from repro.serve.engine import ServeEngine, SpillTicket

    class _FailingTiered:
        obs = None

        def offload(self, name, obj, replicate=True):
            fut = Future()
            fut.set_exception(IOError("pmem died mid-offload"))
            return fut

    eng = ServeEngine.__new__(ServeEngine)
    eng.tiered, eng.store = _FailingTiered(), None
    eng.failed_spills = {}
    state = _state(12)
    eng.cache, eng.pos = state["cache"], int(state["pos"])
    ticket = eng.spill("doomed", wait=False)
    assert isinstance(ticket, SpillTicket)
    assert eng.cache is None  # DRAM freed as before ...
    with pytest.raises(RuntimeError, match="doomed"):
        ticket.result(timeout=30)
    # ... but the host copy survived, owned by the ticket -> engine
    assert "doomed" in eng.failed_spills
    eng.restore_failed_spill("doomed")
    np.testing.assert_array_equal(np.asarray(eng.cache["k"]),
                                  state["cache"]["k"])
    assert eng.pos == int(state["pos"])


# ---------------------------------------------------------------------------
# satellite regression: prefill must route through the jitted partial
# ---------------------------------------------------------------------------

def test_prefill_routes_through_jitted_path():
    import jax
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.serve import engine as engine_mod

    cfg = registry.get_smoke_config("qwen2-72b")
    rt = T.ModelRuntime(tp=1, attn_impl="naive", max_seq=64, remat=False)
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, rt)
    eng = engine_mod.ServeEngine(cfg, rt, params)
    toks = np.arange(8, dtype=np.int32)[None, :] % cfg.vocab_size
    first = eng.prefill(toks)  # traces + compiles self._prefill
    assert first.shape == (1,)

    def _unjitted_call(*a, **kw):  # pragma: no cover - must not run
        raise AssertionError("prefill bypassed the jitted path")

    orig = engine_mod.tfm.prefill
    engine_mod.tfm.prefill = _unjitted_call
    try:
        # same shapes: a jitted prefill hits the compile cache and never
        # re-enters the python fn; the old unjitted call would blow up
        again = eng.prefill(toks + 1)
    finally:
        engine_mod.tfm.prefill = orig
    assert again.shape == (1,)


# ---------------------------------------------------------------------------
# telemetry: gauge/histograms/span tree per session lifetime
# ---------------------------------------------------------------------------

def test_session_telemetry_surfaces(cluster):
    sm = cluster.sessions
    eng = FakeEngine().seed(_state(13))
    sm.start("obs1", eng)
    assert cluster.obs.registry.gauge("serve.sessions_active").value == 1
    sm.suspend("obs1")
    assert cluster.obs.registry.gauge("serve.sessions_active").value == 0
    sm.resume("obs1", FakeEngine("e2"))
    snap = cluster.obs.snapshot()
    assert snap["counters"]["serve.spills"] >= 1
    assert snap["counters"]["serve.resumes"] >= 1
    assert snap["histograms"]["serve.resume_ms"]["count"] >= 1
    # spill-to-ack probe fires once the buddy ack lands
    cluster.tiered.quiesce()
    deadline = time.time() + 10
    while time.time() < deadline:
        snap = cluster.obs.snapshot()
        if snap["histograms"].get("serve.spill_to_ack_s",
                                  {}).get("count", 0) >= 1:
            break
        time.sleep(0.02)
    assert snap["histograms"]["serve.spill_to_ack_s"]["count"] >= 1
    sm.end("obs1")
    assert cluster.obs.registry.gauge("serve.sessions_active").value == 0
