"""Distributed checkpointing: crash consistency, buddy recovery, elastic
resharding, delta encoding."""
import numpy as np
import pytest


def _tree(seed=0):
    r = np.random.RandomState(seed)
    return {"layer": {"w": r.randn(8, 8).astype(np.float32),
                      "b": r.randn(8).astype(np.float32)},
            "emb": r.randn(16, 4).astype(np.float32),
            "odd": r.randn(7, 3).astype(np.float32)}  # non-divisible dim0


def test_roundtrip(cluster):
    t = _tree()
    cluster.checkpointer.save(1, t)
    cluster.checkpointer.wait_async()
    out, man = cluster.checkpointer.restore()
    assert man["step"] == 1
    for path in ("layer", "emb", "odd"):
        pass
    np.testing.assert_array_equal(out["layer"]["w"], t["layer"]["w"])
    np.testing.assert_array_equal(out["odd"], t["odd"])


def test_two_slots_keep_previous(cluster):
    t1, t2 = _tree(1), _tree(2)
    cluster.checkpointer.save(1, t1)
    cluster.checkpointer.save(2, t2)
    cluster.checkpointer.wait_async()
    out1, _ = cluster.checkpointer.restore(1)
    out2, _ = cluster.checkpointer.restore(2)
    np.testing.assert_array_equal(out1["emb"], t1["emb"])
    np.testing.assert_array_equal(out2["emb"], t2["emb"])


def test_crash_consistency_partial_write(cluster):
    """A crash mid-write (data written, manifest NOT committed) must leave
    the previous checkpoint restorable."""
    t1 = _tree(1)
    man1 = cluster.checkpointer.save(1, t1)
    cluster.checkpointer.wait_async()
    # simulate a crash during step-2 save: write node data into the
    # shadow slot (the one the next save would use) without a manifest
    t2 = _tree(2)
    from repro.core.object_store import _flatten
    leaves = dict(_flatten(t2))
    shadow = (man1["slot"] + 1) % cluster.checkpointer.slots
    cluster.stores["node0"].put(f"ckpt/slot{shadow}", leaves)
    assert cluster.checkpointer.latest_step() == 1
    out, man = cluster.checkpointer.restore()
    assert man["step"] == 1
    np.testing.assert_array_equal(out["emb"], t1["emb"])


def test_buddy_recovery_any_single_node(cluster):
    t = _tree(3)
    cluster.checkpointer.save(4, t)
    cluster.checkpointer.wait_async()
    for victim in cluster.node_ids:
        out, _ = cluster.checkpointer.restore(4, lost_nodes=[victim])
        np.testing.assert_array_equal(out["layer"]["w"], t["layer"]["w"])
        np.testing.assert_array_equal(out["odd"], t["odd"])


def test_elastic_shard_reads(cluster):
    t = _tree(4)
    cluster.checkpointer.save(1, t)
    cluster.checkpointer.wait_async()
    # arbitrary row ranges crossing node boundaries (16 rows over 4 nodes)
    for start, n in [(0, 16), (3, 6), (7, 2), (12, 4)]:
        sl = cluster.checkpointer.restore_shard(1, "emb", start, n)
        np.testing.assert_array_equal(sl, t["emb"][start:start + n])


def test_delta_checkpoint_roundtrip(cluster_delta):
    c = cluster_delta
    t1 = _tree(5)
    c.checkpointer.save(1, t1)
    t2 = {k: (jax_like_update(v) if not isinstance(v, dict) else
              {kk: jax_like_update(vv) for kk, vv in v.items()})
          for k, v in t1.items()}
    c.checkpointer.save(2, t2, base_step=1)
    c.checkpointer.wait_async()
    out, man = c.checkpointer.restore(2)
    assert man["delta_base"] == 1
    # int8 delta: error bounded by per-tile scale (small updates -> tiny)
    assert np.abs(out["emb"] - t2["emb"]).max() < 1e-4
    assert np.abs(out["layer"]["w"] - t2["layer"]["w"]).max() < 1e-4


def jax_like_update(v):
    return v + np.float32(1e-3) * np.sign(v)


def test_restore_with_different_node_count(cluster):
    """Elastic restart: a 2-node view re-cuts shards via byte-range reads."""
    from repro.core.checkpoint import DistributedCheckpointer
    t = _tree(6)
    cluster.checkpointer.save(1, t)
    cluster.checkpointer.wait_async()
    # new logical topology reading the same pools
    sub = {nid: cluster.stores[nid] for nid in cluster.node_ids}
    elastic = DistributedCheckpointer(sub)
    rows = elastic.restore_shard(1, "layer/w", 2, 5)
    np.testing.assert_array_equal(rows, t["layer"]["w"][2:7])
