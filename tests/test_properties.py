"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

SET = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# codec: quantization error is bounded by the per-tile scale, roundtrip of
# identical tensors is exact zero-delta
# ---------------------------------------------------------------------------

@settings(**SET)
@given(st.integers(1, 5000), st.integers(0, 2 ** 31 - 1),
       st.floats(1e-4, 10.0))
def test_codec_error_bound(n, seed, spread):
    from repro.kernels.ckpt_codec.ref import decode_ref, encode_ref
    r = np.random.RandomState(seed % 100000)
    base = (r.randn(n) * spread).astype(np.float32)
    new = base + (r.randn(n) * spread * 0.01).astype(np.float32)
    pad = (-n) % 1024
    bp = np.pad(base, (0, pad)).reshape(-1, 1024)
    np_ = np.pad(new, (0, pad)).reshape(-1, 1024)
    q, s = encode_ref(np_, bp)
    dec = decode_ref(q, s, bp)
    err = np.abs(dec - np_)
    assert (err <= s + 1e-7).all()


@settings(**SET)
@given(st.integers(1, 3000), st.integers(0, 2 ** 31 - 1))
def test_codec_identity_is_exact(n, seed):
    from repro.kernels.ckpt_codec.ref import decode_ref, encode_ref
    r = np.random.RandomState(seed % 100000)
    base = r.randn(((n + 1023) // 1024) * 1024).astype(np.float32) \
        .reshape(-1, 1024)
    q, s = encode_ref(base, base)
    assert (q == 0).all()
    dec = decode_ref(q, s, base)
    np.testing.assert_array_equal(dec, base)


# ---------------------------------------------------------------------------
# attention: causal masking means future tokens never leak
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 4),
       st.sampled_from([0, 8]))
def test_attention_causality(seed, heads, window):
    from repro.models.attention import naive_attention
    r = np.random.RandomState(seed % 100000)
    B, S, Dh = 1, 16, 8
    q = jnp.asarray(r.randn(B, S, 1, heads * Dh).reshape(B, S, heads, Dh)
                    .astype(np.float32))
    k = jnp.asarray(r.randn(B, S, 1, Dh).astype(np.float32))
    v = jnp.asarray(r.randn(B, S, 1, Dh).astype(np.float32))
    o1 = naive_attention(q, k, v, causal=True, window=window)
    k2 = k.at[:, -1].set(k[:, -1] + 100.0)
    v2 = v.at[:, -1].set(v[:, -1] - 100.0)
    o2 = naive_attention(q, k2, v2, causal=True, window=window)
    np.testing.assert_array_equal(np.asarray(o1[:, :-1]),
                                  np.asarray(o2[:, :-1]))


# ---------------------------------------------------------------------------
# RG-LRU: |a| < 1 -> bounded state for bounded inputs
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(8, 64))
def test_rglru_stability(seed, s):
    """Worst-case gain of h_t = a h + sqrt(1-a^2) x for constant x is
    sqrt((1+a)/(1-a)); the scan must never exceed it."""
    from repro.models.rglru import rglru_scan
    r = np.random.RandomState(seed % 100000)
    log_a = jnp.asarray(-np.abs(r.randn(1, s, 8)).astype(np.float32) - 1e-4)
    x = jnp.asarray(np.clip(r.randn(1, s, 8), -3, 3).astype(np.float32))
    h = rglru_scan(log_a, x)
    a_max = float(jnp.exp(log_a.max()))
    gain = np.sqrt((1 + a_max) / (1 - a_max))
    assert float(jnp.max(jnp.abs(h))) <= 3.0 * gain + 1e-3
    assert bool(jnp.isfinite(h).all())


# ---------------------------------------------------------------------------
# sharding: _fit_pspec never assigns a non-dividing axis
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(1, 64), min_size=1, max_size=3))
def test_fit_pspec_divisibility(dims):
    import os
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import _axes_size, _fit_pspec
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))  # single-device mesh
    ps = _fit_pspec(P(*(["model"] * len(dims))), tuple(dims), mesh)
    for entry, d in zip(ps, dims):
        assert d % _axes_size(entry, mesh) == 0


# ---------------------------------------------------------------------------
# MoE router: top-k gates are normalized and selected experts are distinct
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_router_normalized(seed):
    from repro.configs import registry
    from repro.models.layers import ParamBuilder
    from repro.models.moe import init_moe, make_moe_layout, router_probs
    cfg = registry.get_smoke_config("grok-1-314b")
    pb = ParamBuilder(jax.random.PRNGKey(seed % 100000))
    init_moe(pb, cfg, make_moe_layout(cfg, 1))
    x = jax.random.normal(jax.random.PRNGKey(1), (10, cfg.d_model))
    gates, ids, probs = router_probs(pb.params, x, cfg)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    assert (np.asarray(ids[..., 0]) != np.asarray(ids[..., 1])).all()


# ---------------------------------------------------------------------------
# object store: put/get is the identity for arbitrary small pytrees
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.lists(st.tuples(st.integers(1, 8), st.integers(1, 8)), min_size=1,
                max_size=4),
       st.sampled_from([np.float32, np.int32, np.float16]))
def test_object_store_identity(seed, shapes, dtype):
    import tempfile
    from pathlib import Path
    from repro.core.object_store import PMemObjectStore
    from repro.core.pmem import PMemPool
    pool = PMemPool(Path(tempfile.mkdtemp()), "n0")
    store = PMemObjectStore(pool)
    r = np.random.RandomState(seed % 100000)
    tree = {f"k{i}": (r.randn(*s) * 10).astype(dtype)
            for i, s in enumerate(shapes)}
    store.put("t", tree)
    out = store.get("t", verify=True)
    for k in tree:
        np.testing.assert_array_equal(out[k], tree[k])
