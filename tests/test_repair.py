"""Ack-driven replica repair: restoring the replication factor after a
node loss for checkpoint shards, DLM objects and catalog datasets — and
surviving the SECOND loss that write-time replication alone would not.
Plus the lease satellites: release tombstones (no resurrection from a
stale pool copy) and the clock-skew margin in gc()'s expiry check."""
import time

import numpy as np
import pytest

from repro.core.dataset_exchange import DatasetCatalog, ack_targets


def _tree(seed=0, n=64):
    return {"x": np.random.RandomState(seed).randn(n).astype(np.float32)}


def _record_store_reads(cluster):
    """Wrap every store's object-read/probe entry points, recording the
    object names touched. Pool JSON (ack records, catalog records,
    journals) stays unrecorded — metadata reads are always allowed."""
    reads = []

    def wrap(st):
        orig_get, orig_exists = st.get_with_manifest, st.exists

        def get_with_manifest(name, *a, **k):
            reads.append(name)
            return orig_get(name, *a, **k)

        def exists(name, *a, **k):
            reads.append(name)
            return orig_exists(name, *a, **k)
        st.get_with_manifest, st.exists = get_with_manifest, exists

    for st in cluster.stores.values():
        wrap(st)
    return reads


def _ckpt_copies(cluster, step, lost):
    """Surviving acked copy-holder sets per shard owner at ``step``."""
    acks = cluster.checkpointer.acks(step)
    rec = cluster.checkpointer._meta_get_json(
        f"ckpt/manifest_step{step}.json")
    out = {}
    for nid in rec.get("nodes") or cluster.node_ids:
        holders = set(ack_targets(acks.get(nid, {}).get("replica")))
        holders.add(nid)
        out[nid] = holders - set(lost)
    return out


# ---------------------------------------------------------------------------
# checkpoint repair: one loss + repair -> >= 2 copies -> second loss OK
# ---------------------------------------------------------------------------

def test_checkpoint_repair_restores_replication_factor(cluster):
    c = cluster
    t = _tree(1)
    c.tiered.save_async(1, t).result(timeout=30)
    c.tiered.quiesce()  # replicas placed + acked
    victim = "node1"
    c.kill_node(victim)
    # before repair: the victim's shard AND the shard that buddied to
    # the victim are both down to a single copy
    assert any(len(h) == 1 for h in
               _ckpt_copies(c, 1, [victim]).values())
    report = c.repair([victim])
    assert report["checkpoint"] == 2  # victim's shard + its buddy's
    assert not report["errors"] and not report["unrepairable"]
    # the acceptance criterion: every shard again has >= 2 acked copies
    for nid, holders in _ckpt_copies(c, 1, [victim]).items():
        assert len(holders) >= 2, (nid, holders)


def test_second_loss_of_new_buddy_still_restores(cluster):
    """Kill a node, repair, then kill the NEW buddy: the original
    surviving copy (still in the pruned-and-extended targets list) must
    carry the restore — decided and served via acks, no walking back."""
    c = cluster
    t = _tree(2)
    c.tiered.save_async(1, t).result(timeout=30)
    c.tiered.quiesce()
    victim = "node1"
    c.kill_node(victim)
    c.repair([victim])
    # the victim's shard survived on its old buddy; repair added a new
    # target on top — kill the new one
    rec = c.checkpointer.acks(1)[victim]["replica"]
    new_buddy = rec["target"]
    survivors = [x for x in rec["targets"] if x != new_buddy]
    assert survivors, "repair should have kept the original holder"
    c.kill_node(new_buddy)
    out, man = c.checkpointer.restore_latest_recoverable(
        lost_nodes=[victim, new_buddy])
    assert man["step"] == 1
    np.testing.assert_array_equal(out["x"], t["x"])
    # no step was ruled out, none probed blindly
    assert c.checkpointer.last_restore_stats == \
        {"skipped_by_ack": 0, "probed": 1}


def test_unreplicated_step_is_not_repairs_business(cluster):
    """An object that never acked a replica promised nothing: repair
    must not invent copies for it (nor error on it)."""
    c = cluster
    c.checkpointer.buddy = False
    c.tiered.save_async(1, _tree(3)).result(timeout=30)
    c.tiered.quiesce()
    report = c.repair(["node1"])
    assert report["checkpoint"] == 0
    assert not report["errors"]


def test_repair_scan_reads_only_the_copies_it_makes(cluster, monkeypatch):
    """Zero blind probes: every object-store access during repair is the
    source of a raw-path copy actually made — the scan itself decides
    from ack records and catalog metadata alone, and the copies stream
    region bytes without ever materializing a tree (so the tree-read
    entry points are never touched at all)."""
    from repro.core import data_scheduler as ds
    c = cluster
    c.tiered.save_async(1, _tree(4)).result(timeout=30)
    c.tiered.offload("serve/sess", _tree(5)).result(timeout=30)
    c.catalog.publish("ds", _tree(6), workflow="w")
    c.tiered.quiesce()
    c.kill_node("node1")
    c.tiered.quiesce()
    reads = _record_store_reads(c)
    copies = []
    orig_copy = ds.copy_object

    def copy_object(src, dst, name, *a, **k):
        copies.append(name)
        return orig_copy(src, dst, name, *a, **k)
    monkeypatch.setattr(ds, "copy_object", copy_object)
    report = c.tiered.repair(["node1"])
    assert report["repaired"] and not report["errors"]
    # exactly one raw-path source copy per repaired object and nothing
    # else: the scan never probes the store, and no repair copy ever
    # deserializes a tree (get_with_manifest/exists untouched)
    assert len(copies) == len(report["repaired"]), (copies, report)
    assert reads == [], f"tree reads/probes during repair: {reads}"
    copied_prefixes = ("ckpt/slot", "replica/", "dlm/", "wf/")
    for name in copies:
        assert name.startswith(copied_prefixes), \
            f"unexpected copy source during repair: {name}"


def test_repair_skips_slot_reused_steps_on_metadata(cluster):
    """A step whose shadow slot a newer step reused must be skipped on
    metadata alone (superseded), not re-replicated with wrong bytes."""
    c = cluster  # slots=2: step 1's slot is reused by step 3
    for s in (1, 2, 3):
        c.tiered.save_async(s, _tree(s)).result(timeout=30)
    c.tiered.quiesce()
    c.kill_node("node1")
    report = c.repair(["node1"])
    assert report["superseded"] >= 1  # step 1 ruled out by slot reuse
    assert not report["errors"]
    for step in (2, 3):
        for nid, holders in _ckpt_copies(c, step, ["node1"]).items():
            assert len(holders) >= 2, (step, nid, holders)


# ---------------------------------------------------------------------------
# DLM objects: offload acks, write-back re-acks, repair, second loss
# ---------------------------------------------------------------------------

def test_offload_records_dlm_ack(cluster):
    c = cluster
    c.tiered.offload("serve/sess", _tree(7)).result(timeout=30)
    c.tiered.quiesce()
    rec = c.tiered.dlm_acks.objects()["dlm/serve/sess"]
    assert rec["home"] == "node0"
    assert rec["targets"] == ["node1"]  # the live-ring buddy, acked


def test_dlm_repair_survives_loss_of_new_buddy(cluster):
    """Home dies -> repair copies the surviving replica to a fresh
    node -> THAT node dies too -> reads still come from the original
    holder, which the targets list still records."""
    c = cluster
    t = _tree(8)
    c.tiered.offload("serve/sess", t).result(timeout=30)
    c.tiered.quiesce()
    c.kill_node("node0")  # the DLM home
    report = c.repair(["node0"])
    surface, obj, survivor, _new = report["repaired"][0]
    assert (surface, obj, survivor) == ("dlm", "dlm/serve/sess", "node1")
    rec = c.tiered.dlm_acks.objects()["dlm/serve/sess"]
    assert len(rec["targets"]) == 2 and "node1" in rec["targets"]
    new = [x for x in rec["targets"] if x != "node1"][0]
    c.kill_node(new)
    c.tiered.evict_cold()  # nothing cached: the read must hit pmem
    out = c.tiered.fetch("serve/sess")
    np.testing.assert_array_equal(out["x"], t["x"])


def test_dirty_writeback_refreshes_replica(cluster):
    """A mutated DLM object written back by eviction must re-replicate:
    after the home dies, the replica serves the NEW bytes, not the ones
    from the original offload."""
    c = cluster
    c.tiered.offload("serve/sess", _tree(9)).result(timeout=30)
    c.tiered.quiesce()
    t2 = _tree(10)
    c.dlm.put("serve/sess", t2)       # mutate in DRAM (dirty)
    assert c.tiered.evict_cold() >= 1  # write-back fires the hook
    c.tiered.quiesce()                 # replica + ack land
    c.kill_node("node0")
    out = c.tiered.fetch("serve/sess")
    np.testing.assert_array_equal(out["x"], t2["x"])


def test_writeback_ack_replaces_stale_targets(cluster):
    """A dead buddy that missed the mutation must LEAVE the ack record
    when the write-back re-replicates: were it still acked, it could
    rejoin with pre-mutation pmem and serve stale bytes (and fool a
    later repair into counting it as a healthy copy)."""
    c = cluster
    c.tiered.offload("serve/sess", _tree(20)).result(timeout=30)
    c.tiered.quiesce()
    assert c.tiered.dlm_acks.targets("dlm/serve/sess") == ["node1"]
    c.kill_node("node1")  # buddy dies holding the OLD bytes; no repair
    t2 = _tree(21)
    c.dlm.put("serve/sess", t2)        # mutate
    assert c.tiered.evict_cold() >= 1  # write-back -> replica on node2
    c.tiered.quiesce()
    # the stale dead target is gone, only the fresh copy is acked
    assert c.tiered.dlm_acks.targets("dlm/serve/sess") == ["node2"]
    c.kill_node("node0")
    out = c.tiered.fetch("serve/sess")
    np.testing.assert_array_equal(out["x"], t2["x"])


def test_offload_replicate_false_objects_stay_node_local(cluster):
    c = cluster
    c.tiered.offload("serve/tmp", _tree(11), replicate=False) \
        .result(timeout=30)
    c.tiered.evict_cold()
    c.tiered.quiesce()
    assert "dlm/serve/tmp" not in c.tiered.dlm_acks.objects()
    assert not c.stores["node1"].exists("replica/node0/dlm/serve/tmp")


# ---------------------------------------------------------------------------
# datasets: repair + resume with no replays across TWO losses
# ---------------------------------------------------------------------------

def test_dataset_repair_restores_replication_factor(cluster):
    c = cluster
    c.catalog.publish("ds", _tree(12), workflow="w")
    c.tiered.quiesce()
    rec = c.catalog.record("ds", "w")
    home, target = rec["home"], rec["acks"]["replica"]["target"]
    c.kill_node(home)
    report = c.repair([home])
    assert report["dataset"] == 1
    rec = c.catalog.record("ds", "w")
    targets = ack_targets(rec["acks"]["replica"])
    assert target in targets and len(targets) == 2
    # second loss: the NEW buddy dies; recoverable + readable via the
    # original holder, decided from the record alone
    new = [x for x in targets if x != target][0]
    c.kill_node(new)
    reads = _record_store_reads(c)
    assert c.catalog.recoverable("ds", "w", lost_nodes=[home, new])
    assert reads == []  # metadata-only decision
    np.testing.assert_array_equal(c.catalog.get("ds", "w")["x"],
                                  _tree(12)["x"])


def _pinned_jobs(cluster, calls):
    cluster.stores["node0"].put("seed_a", _tree(1))
    cluster.stores["node2"].put("seed_b", _tree(2))
    cluster.external.put("seed_a", _tree(1))
    cluster.external.put("seed_b", _tree(2))

    def mk(tag, out, inputs):
        def fn(ctx):
            calls[tag] += 1
            for i in inputs:
                ctx.read(i)
            return {out: _tree(hash(tag) % 100)}
        return fn

    from repro.core.workflow import JobSpec
    return [
        JobSpec("pa", mk("pa", "da", ("seed_a",)), inputs=("seed_a",),
                retain=("da",)),
        JobSpec("pb", mk("pb", "db", ("seed_b",)), inputs=("seed_b",),
                retain=("db",)),
        JobSpec("sink", mk("sink", "dc", ("da", "db")),
                inputs=("da", "db"), after=("pa", "pb"), retain=("dc",)),
    ]


def test_resume_repairs_then_second_loss_replays_nothing(cluster):
    """The acceptance scenario end to end: run, lose a node, resume
    (repair wired in, zero replays), lose the NEW buddy of a repaired
    dataset, resume again — still zero replays, decided on acks."""
    c = cluster
    calls = {"pa": 0, "pb": 0, "sink": 0}
    jobs = _pinned_jobs(c, calls)
    c.workflows.run(jobs, workflow="wfT")
    c.tiered.quiesce()
    victim = c.catalog.record("db", "wfT")["home"]
    c.kill_node(victim)
    res = c.workflows.resume(jobs, "wfT", lost_nodes=[victim])
    assert calls == {"pa": 1, "pb": 1, "sink": 1}  # nothing re-invoked
    assert res.repair_report["dataset"] >= 1
    # every retained dataset has >= 2 surviving acked copies again
    survivors = []
    for name in ("da", "db", "dc"):
        rec = c.catalog.record(name, "wfT")
        holders = set(ack_targets(rec["acks"]["replica"]))
        holders.add(rec["home"])
        holders -= {victim}
        assert len(holders) >= 2, (name, holders)
        survivors.append((name, rec, holders))
    # second loss: kill a NEW buddy that repair added for db
    rec = c.catalog.record("db", "wfT")
    targets = [t for t in ack_targets(rec["acks"]["replica"])
               if t != victim]
    second = targets[-1]
    c.kill_node(second)
    res2 = c.workflows.resume(jobs, "wfT", lost_nodes=[victim, second])
    assert calls == {"pa": 1, "pb": 1, "sink": 1}  # STILL no replays
    assert set(res2.skipped) == {"pa", "pb", "sink"}
    assert res2.replayed == []


def test_failure_recovery_runs_repair(cluster):
    """check_and_recover restores state AND the replication factor."""
    c = cluster
    state = _tree(13)
    c.tiered.save_async(3, state).result(timeout=30)
    c.tiered.quiesce()
    for nid in c.node_ids:
        c.heartbeat.beat(nid, 3)
    c.kill_node("node1")
    tree, manifest, dead = c.recovery.check_and_recover()
    assert dead == ["node1"]
    np.testing.assert_array_equal(tree["x"], state["x"])
    assert c.recovery.last_repair_report["checkpoint"] == 2
    for nid, holders in _ckpt_copies(c, 3, dead).items():
        assert len(holders) >= 2


# ---------------------------------------------------------------------------
# satellite: lease release tombstones (no resurrection) + skewed-clock gc
# ---------------------------------------------------------------------------

def test_released_lease_does_not_resurrect_from_stale_pool(cluster):
    """A pool that missed the release write holds the lease live; the
    merge (in a FRESH catalog — cold record cache, as after a process
    restart) must let the release tombstone win, and gc must reclaim."""
    cat = cluster.catalog
    cat.publish("ds", _tree(14), workflow="w", retained=False)
    lease = cat.acquire("ds", workflow="w", owner="consumer",
                        ttl_s=3600.0)
    # snapshot the record WITH the live lease (the stale pool copy)
    stale = dict(cluster.stores["node2"].pool.get_json("exch/w/ds@v1.json"))
    cat.release(lease)
    # node2 "was down" for the release write and rejoins with the stale
    # copy still holding the lease
    cluster.stores["node2"].pool.put_json("exch/w/ds@v1.json", stale)
    fresh = DatasetCatalog(cluster.stores)  # cold cache: must merge
    assert fresh.refcount("ds", "w") == 0
    assert fresh.gc() == [("w", "ds", 1)]


def test_release_tombstone_pruned_after_expiry(cluster):
    cat = cluster.catalog
    cat.publish("ds", _tree(15), workflow="w", retained=True)
    lease = cat.acquire("ds", workflow="w", owner="c", ttl_s=30.0)
    cat.release(lease)
    cat.gc()  # unexpired tombstone survives the sweep (still guarding)
    rec = cat.record("ds", "w")
    assert rec["leases"][lease.lease_id]["released"]
    # once safely past expiry + skew, the tombstone is pruned: any
    # stale live copy is expired by then, so nothing can resurrect
    cat.gc(now=time.time() + 30.0 + cat.clock_skew_s + 1.0)
    assert cat.record("ds", "w")["leases"] == {}


def test_gc_skew_margin_defers_reclaim(cluster):
    """A lease just past ITS producer's expiry must survive gc on a
    consumer whose clock may be ahead — until the skew margin passes."""
    cat = DatasetCatalog(cluster.stores, clock_skew_s=5.0)
    cat.publish("ds", _tree(16), workflow="w", retained=False)
    cat.acquire("ds", workflow="w", owner="c", ttl_s=10.0)
    t0 = time.time()
    # locally "expired", but within the skew margin: NOT reclaimed
    assert cat.gc(now=t0 + 11.0) == []
    assert not cat.record("ds", "w")["reclaimed"]
    # past expiry + margin: reclaimed
    assert cat.gc(now=t0 + 16.0) == [("w", "ds", 1)]


def test_gc_skew_configurable_per_call(cluster):
    cat = DatasetCatalog(cluster.stores, clock_skew_s=60.0)
    cat.publish("ds", _tree(17), workflow="w", retained=False)
    cat.acquire("ds", workflow="w", owner="c", ttl_s=10.0)
    t0 = time.time()
    assert cat.gc(now=t0 + 20.0) == []          # default margin holds
    assert cat.gc(now=t0 + 20.0, skew_s=0.0) == \
        [("w", "ds", 1)]                        # explicit override
