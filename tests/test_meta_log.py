"""MetaLog: the append-only replicated metadata log (ROADMAP item 3).

Covers the log primitive itself (append/replay/compaction/reseed, torn
tails, crash windows), the pmem grow/rename plumbing it rides on, the
torn-JSON tolerance of the legacy read paths it replaced, and the
single-writer lease race the catalog's log serialises.
"""
import json
import random
import threading

import pytest

from repro.core.dataset_exchange import DatasetCatalog
from repro.core.meta_log import (HDR_SIZE, KIND_EVENT, MIN_CAPACITY,
                                 MetaLog, _pack_entry)


def _fold_kv(state, ev):
    """Reference reducer: the kind of keyed upsert/delete every ported
    surface is a variant of."""
    op = ev["op"]
    if op == "set":
        state[ev["k"]] = {"v": ev["v"], "ts": ev["ts"]}
    elif op == "incr":
        rec = dict(state.get(ev["k"]) or {"v": 0})
        rec["v"] = rec.get("v", 0) + ev["n"]
        rec["ts"] = ev["ts"]
        state[ev["k"]] = rec
    elif op == "del":
        state.pop(ev["k"], None)


def _log(cluster, name="test/log", **kw):
    return MetaLog(cluster.stores, cluster.node_ids, name,
                   fold=_fold_kv, **kw)


# ---- the log primitive -----------------------------------------------

def test_append_then_fresh_replay_roundtrip(cluster):
    log = _log(cluster)
    for i in range(20):
        log.append({"op": "set", "k": f"k{i % 5}", "v": i})
    head = log.state()
    assert set(head) == {f"k{i}" for i in range(5)}
    assert head["k4"]["v"] == 19
    # a brand-new instance (cold replay from the pool copies) agrees
    assert _log(cluster).state() == head


def test_replay_unions_entries_across_node_loss(cluster):
    log = _log(cluster)
    log.append({"op": "set", "k": "a", "v": 1})
    cluster.kill_node("node3")
    log.append({"op": "set", "k": "b", "v": 2})
    cluster.kill_node("node0")
    log.append({"op": "set", "k": "c", "v": 3})
    head = log.state()
    replayed = _log(cluster).state()
    assert replayed == head
    assert {k: r["v"] for k, r in replayed.items()} == \
        {"a": 1, "b": 2, "c": 3}


def test_rejoined_pool_is_reseeded_and_self_sufficient(cluster):
    log = _log(cluster)
    log.append({"op": "set", "k": "a", "v": 1})
    # node2's pmem goes unreachable (transient): it misses appends
    cluster.pools["node2"]._dead = True
    log.append({"op": "set", "k": "b", "v": 2})
    log.append({"op": "incr", "k": "a", "n": 10})
    # rejoin: the next append must reseed node2 with a full snapshot
    cluster.pools["node2"]._dead = False
    log.append({"op": "set", "k": "c", "v": 3})
    assert log.stats["reseeds"] >= 1
    # node2's copy ALONE now replays the complete state
    solo = MetaLog({"node2": cluster.stores["node2"]}, ["node2"],
                   "test/log", fold=_fold_kv)
    head = log.state()
    assert solo.state() == head
    assert head["a"]["v"] == 11


def test_torn_append_past_committed_tail_is_invisible(cluster):
    log = _log(cluster)
    log.append({"op": "set", "k": "a", "v": 1})
    head = dict(log.state())
    # simulate a torn append on every copy: entry bytes land but the
    # crash hits before the committed tail advances
    import numpy as np
    torn = _pack_entry(99, KIND_EVENT,
                       json.dumps({"op": "set", "k": "zz"}).encode()[:7])
    for nid in cluster.node_ids:
        pool = cluster.pools[nid]
        region = pool.open("test/log")
        tail = int.from_bytes(bytes(region.read(8, 8)), "little")
        region.write(tail, np.frombuffer(torn, dtype=np.uint8))
        region.flush()
    assert _log(cluster).state() == head


def test_compaction_bounds_replay_bytes(cluster):
    log = _log(cluster)
    for i in range(200):
        log.append({"op": "set", "k": f"k{i % 10}", "v": i})
    head = json.loads(json.dumps(log.state()))
    log.compact()
    fresh = _log(cluster)
    assert fresh.state() == head
    # replay after compaction reads ~one snapshot body plus headers,
    # NOT one body per replica (the acceptance bound: < 2x snapshot)
    assert fresh.stats["replay_bytes"] < 2 * log.stats["snapshot_bytes"]


def test_mid_compaction_crash_leaves_log_replayable(cluster):
    log = _log(cluster)
    for i in range(30):
        log.append({"op": "set", "k": f"k{i % 3}", "v": i})
    head = json.loads(json.dumps(log.state()))
    # crash in the worst window: snapshot written + acked on every pool
    # but NOT yet renamed over the live log
    log.compact(_crash_after_snapshot=True)
    for nid in cluster.node_ids:
        assert cluster.pools[nid].exists("test/log.cnew")  # orphan ack
        assert cluster.pools[nid].exists("test/log")       # old log intact
    fresh = _log(cluster)
    assert fresh.state() == head
    # the restarted writer keeps appending and compacts cleanly later
    fresh.append({"op": "set", "k": "post", "v": 1})
    fresh.compact()
    assert _log(cluster).state()["post"]["v"] == 1


def test_append_after_every_pool_dead_raises(cluster):
    log = _log(cluster)
    log.append({"op": "set", "k": "a", "v": 1})
    for nid in cluster.node_ids:
        cluster.pools[nid]._dead = True
    with pytest.raises(IOError):
        log.append({"op": "set", "k": "b", "v": 2})


def test_auto_compaction_threshold(cluster):
    log = _log(cluster, compact_entries=16)
    for i in range(40):
        log.append({"op": "set", "k": "k", "v": i})
    assert log.stats["compactions"] >= 2
    assert _log(cluster).state()["k"]["v"] == 39


# ---- satellite 3: replay == the old read-merge-rewrite state ---------

def test_property_replay_matches_sequential_fold(cluster):
    """Property-style: a pseudo-random op sequence with interleaved
    compactions and node loss replays to EXACTLY the state the old
    read-merge-rewrite path maintained (here: the same reducer applied
    sequentially to a plain dict — what the single-writer JSON merge
    returned)."""
    rng = random.Random(1805_10041)
    log = _log(cluster, name="prop/log", compact_entries=64)
    reference: dict = {}
    killed = []
    for step in range(300):
        r = rng.random()
        if r < 0.05 and len(killed) < 2:
            nid = rng.choice([n for n in cluster.node_ids
                              if n not in killed])
            killed.append(nid)
            cluster.kill_node(nid)
            continue
        if r < 0.10:
            log.compact()
            continue
        k = f"k{rng.randrange(12)}"
        if r < 0.70:
            ev = {"op": "set", "k": k, "v": rng.randrange(1000),
                  "ts": float(step)}
        elif r < 0.90:
            ev = {"op": "incr", "k": k, "n": rng.randrange(5),
                  "ts": float(step)}
        else:
            ev = {"op": "del", "k": k, "ts": float(step)}
        log.append(ev)
        _fold_kv(reference, ev)
    assert log.state() == reference
    fresh = MetaLog(cluster.stores, cluster.node_ids, "prop/log",
                    fold=_fold_kv)
    assert fresh.state() == reference


def test_legacy_base_seeds_cold_replay(cluster):
    """Pre-log state (the old replicated JSON) is the replay base until
    the first snapshot supersedes it."""
    legacy = {"old": {"v": 7, "ts": 1.0}}
    log = MetaLog(cluster.stores, cluster.node_ids, "mig/log",
                  fold=_fold_kv, base=lambda: dict(legacy))
    log.append({"op": "set", "k": "new", "v": 1})
    head = log.state()
    assert head["old"]["v"] == 7 and head["new"]["v"] == 1
    log.compact()
    # post-compaction the snapshot carries the migrated state; the base
    # loader is no longer consulted
    fresh = MetaLog(cluster.stores, cluster.node_ids, "mig/log",
                    fold=_fold_kv,
                    base=lambda: pytest.fail("base read after snapshot"))
    assert fresh.state() == head


# ---- pmem plumbing the log rides on ----------------------------------

def test_pmem_extend_grows_and_preserves(cluster):
    pool = cluster.pools["node0"]
    region = pool.create("grow.bin", MIN_CAPACITY)
    import numpy as np
    region.write(0, np.arange(64, dtype=np.uint8))
    region = pool.extend("grow.bin", MIN_CAPACITY * 4)
    assert region.nbytes == MIN_CAPACITY * 4
    assert bytes(region.read(0, 64)) == bytes(range(64))
    # extend is "grow to at least": a smaller target is a no-op
    assert pool.extend("grow.bin", MIN_CAPACITY).nbytes == \
        MIN_CAPACITY * 4


def test_pmem_rename_atomic_swap_evicts_handles(cluster):
    pool = cluster.pools["node0"]
    import numpy as np
    a = pool.create("swap/a.bin", 4096)
    a.write(0, np.full(8, 1, dtype=np.uint8))
    a.flush()
    b = pool.create("swap/b.bin", 4096)
    b.write(0, np.full(8, 2, dtype=np.uint8))
    b.flush()  # rename is a commit point: flush before it (sanitizer)
    pool.rename("swap/b.bin", "swap/a.bin")
    assert not pool.exists("swap/b.bin")
    # a reopened handle sees the NEW bytes, not a stale cached mmap
    assert bytes(pool.open("swap/a.bin").read(0, 8)) == bytes([2] * 8)


# ---- satellite 1: torn-JSON tolerance of the legacy read paths -------

def test_put_json_leaves_no_tmp_and_ignores_stale_tmp(cluster):
    pool = cluster.pools["node0"]
    # a tmp file a crashed writer left behind must not shadow the commit
    pool.put_json("meta/rec.json", {"v": 1})
    tmp = pool._path("meta/rec.json.tmp")
    tmp.write_text('{"v": 99')  # torn, pre-rename crash remnant
    pool.put_json("meta/rec.json", {"v": 2})
    assert pool.get_json("meta/rec.json") == {"v": 2}
    assert not tmp.exists()  # the rename consumed the fresh tmp


def test_catalog_merge_tolerates_torn_legacy_copy(cluster):
    """Regression: one pool holding half a JSON record (a torn legacy
    write, pre-``put_json``-atomicity) must not poison the cross-pool
    merge — the readable copies win."""
    rec = {"workflow": "w", "name": "ds", "version": 1, "object": "o",
           "nbytes": 4, "home": "node1", "placement": ["node1"],
           "ts": 5.0, "leases": {}, "retained": True,
           "reclaimed": False, "acks": {}}
    rname = "exch/w/ds@v1.json"
    for nid in cluster.node_ids:
        cluster.pools[nid].put_json(rname, rec)
    # tear node0's copy mid-byte (bypassing put_json's atomic rename)
    path = cluster.pools["node0"]._path(rname)
    path.write_text(path.read_text()[: len(path.read_text()) // 2])
    cat = DatasetCatalog(cluster.stores)
    merged = cat.record("ds", "w")
    assert merged["nbytes"] == 4 and merged["home"] == "node1"


def test_checkpoint_ack_read_tolerates_torn_legacy_copy(cluster):
    legacy = {"step": 3, "ts": 1.0, "acks": {"node0": {}},
              "ring": {}, "delta_base": None}
    name = "ckpt/acks_step3.json"
    for nid in cluster.node_ids:
        cluster.pools[nid].put_json(name, legacy)
    path = cluster.pools["node2"]._path(name)
    path.write_text(path.read_text()[:10])
    rec = cluster.checkpointer.ack_record(3)
    assert rec is not None and rec["step"] == 3


# ---- satellite 2: concurrent acquire/release loses no lease event ----

def test_concurrent_acquire_release_loses_no_lease_events(cluster):
    """The catalog's single writer serialises lease events through the
    log: racing acquire/release threads must balance exactly — no lost
    update (the old read-merge-rewrite could drop a concurrent lease),
    refcount 0 at the end, and the record still acquirable."""
    cat = cluster.catalog
    rec = cat.publish("ds", b"\x00" * 64, workflow="w", node="node0")
    errors = []

    def worker(i):
        try:
            for _ in range(10):
                lease = cat.acquire("ds", workflow="w",
                                    owner=f"t{i}", ttl_s=60.0)
                cat.release(lease)
        except Exception as e:  # noqa: BLE001 — surfaced below
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    head = cat.record("ds", "w", rec["version"])
    live = [l for l in head["leases"].values()
            if not l.get("released")]
    assert cat.refcount("ds", "w", rec["version"]) == 0
    assert not live
    # every acquire/release pair survived as events: 40 distinct leases
    assert len(head["leases"]) == 40
    # the record is still healthy: a fresh acquire works
    lease = cat.acquire("ds", workflow="w", owner="after")
    assert cat.refcount("ds", "w", rec["version"]) == 1
    cat.release(lease)


def test_log_backed_record_replays_identically_in_fresh_catalog(cluster):
    """The catalog state a fresh process replays from the log equals the
    live writer's head state (acks, leases, tombstones)."""
    cat = cluster.catalog
    cat.publish("ds", b"\x01" * 32, workflow="w", node="node0")
    lease = cat.acquire("ds", workflow="w", owner="me", ttl_s=60.0)
    cat.release(lease)
    cat.unretain("ds", "w")
    # join publish's async replica fan-out: its ack lands in the record
    # log off-thread, and a head read racing it would differ from the
    # fresh replay below by exactly that ack
    cluster.tiered.quiesce()
    head = cat.record("ds", "w")
    fresh = DatasetCatalog(cluster.stores).record("ds", "w")
    assert fresh == head
    assert fresh["retained"] is False
    assert head["leases"][lease.lease_id]["released"] is True
