"""Quickstart: train a reduced-config model end-to-end on CPU with the
full pmem systemware stack (staged data, async node-local checkpoints).

    PYTHONPATH=src python examples/quickstart.py [--arch gemma2-9b]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train as train_cli  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()
    train_cli.main(["--arch", args.arch, "--smoke",
                    "--steps", str(args.steps), "--seq", "64",
                    "--batch", "8", "--ckpt-every", "10"])


if __name__ == "__main__":
    main()
