"""The paper's Fig. 8 workflow, end to end, over the Persistent Dataset
Exchange: data-prep -> {train, corpus-stats} (concurrent branches) ->
eval, sharing intermediates through node-local B-APM (zero external
round-trips between stages). Every intermediate is a catalog dataset —
versioned, lineage-stamped, replica-acked — so after killing a node the
workflow resumes WITHOUT re-running jobs whose outputs survive on
replicas.

    PYTHONPATH=src python examples/workflow_pipeline.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ParallelConfig, ShapeConfig, registry  # noqa: E402
from repro.core.cluster import SimCluster  # noqa: E402
from repro.core.workflow import JobSpec  # noqa: E402
from repro.data.pipeline import make_batch, synthetic_shard  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.train import optimizer as opt  # noqa: E402
from repro.train import train_step as ts  # noqa: E402


def main():
    cfg = registry.get_smoke_config("qwen2-72b")
    shape = ShapeConfig("wf", 48, 4, "train")
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = shd.Plan(mesh, cfg, shape, ParallelConfig(attn_impl="naive"))
    rt = plan.runtime()
    adamw = opt.AdamWConfig(lr=1e-3, warmup=5)
    step = jax.jit(ts.make_train_step(cfg, rt, plan.constrain, adamw,
                                      ce_chunk=16))
    loss_fn = jax.jit(
        lambda p, b: ts.make_loss_fn(cfg, rt, plan.constrain, 16)(p, b)[0])

    cluster = SimCluster(Path(tempfile.mkdtemp()), n_nodes=4)
    # raw corpus starts on the external filesystem (Fig. 8 step 1a)
    cluster.external.put("raw_corpus",
                         synthetic_shard(0, 64, shape.seq_len, cfg.vocab_size))

    def prep(ctx):
        raw = ctx.read("raw_corpus")
        rng = np.random.default_rng(0)
        return {"train_set": raw,
                "eval_batch": make_batch(raw, cfg, shape, rng)}

    def train(ctx):
        shard = ctx.read("train_set")
        rng = np.random.default_rng(1)
        params, _ = T.init_params(jax.random.PRNGKey(0), cfg, rt)
        ost = opt.init_opt_state(params, adamw)
        losses = []
        for _ in range(15):
            params, ost, m = step(params, ost,
                                  make_batch(shard, cfg, shape, rng))
            losses.append(float(m["loss"]))
        print(f"  [train] loss {losses[0]:.3f} -> {losses[-1]:.3f}")
        return {"model": jax.tree.map(np.asarray, params)}

    def stats(ctx):
        # independent analysis branch: runs CONCURRENTLY with train
        shard = ctx.read("train_set")
        tok = np.asarray(shard["tokens"] if isinstance(shard, dict)
                         and "tokens" in shard else shard)
        return {"corpus_stats": {"mean": np.array([float(tok.mean())]),
                                 "max": np.array([float(tok.max())])}}

    def evaluate(ctx):
        params = jax.tree.map(jax.numpy.asarray, ctx.read("model"))
        batch = ctx.read("eval_batch")
        loss = float(loss_fn(params, batch))
        print(f"  [eval] in-situ eval loss {loss:.3f}")
        return {"eval_report": {"loss": np.array([loss])}}

    jobs = [
        JobSpec("prep", prep, inputs=("raw_corpus",),
                retain=("train_set", "eval_batch")),
        JobSpec("train", train, inputs=("train_set",), after=("prep",),
                retain=("model",)),
        JobSpec("stats", stats, inputs=("train_set",), after=("prep",),
                retain=("corpus_stats",)),
        JobSpec("eval", evaluate, inputs=("model", "eval_batch"),
                after=("train",), drain=("eval_report",), retain=("eval_report",)),
    ]
    res = cluster.workflows.run(jobs, workflow="pipeline")

    print("\nworkflow event log (paper Fig. 8 sequence, concurrent):")
    for ts_, kind, detail in cluster.workflows.events:
        print(f"  {kind:9s} {detail}")

    print("\nlineage of eval_report (catalog records, digests persisted):")
    for rec in cluster.catalog.lineage("eval_report", "pipeline"):
        if "external" in rec:
            print(f"  <- external:{rec['external']}")
        else:
            print(f"  {rec['name']}@v{rec['version']} "
                  f"produced by {rec['lineage']['job']} "
                  f"on {rec['home']} digest={rec['digest']}")

    # node loss: every retained dataset has an acked replica, so resume
    # replays NOTHING — consumers read the surviving replica copies
    cluster.tiered.quiesce()  # replica acks land
    victim = cluster.catalog.record("model", "pipeline")["home"]
    cluster.kill_node(victim)
    res2 = cluster.workflows.resume(jobs, "pipeline",
                                    lost_nodes=[victim])
    print(f"\nafter killing {victim}: resume skipped "
          f"{sorted(res2.skipped)} (outputs ack-recoverable), "
          f"replayed {res2.replayed}")
    cluster.tiered.evict_cold(0.0)  # drop DRAM residency: force pmem path
    cluster.catalog.get("model", "pipeline")  # home dead -> replica read
    print(f"  model served from replica "
          f"({cluster.catalog.stats['replica_reads']} replica reads)")

    cluster.workflows.cleanup(keep=())
    cluster.shutdown()


if __name__ == "__main__":
    main()
