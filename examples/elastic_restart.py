"""Elastic restart demo: train on 4 nodes, lose a node, resume from buddy
replicas on the survivors, then grow again — all from node-local pmem.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import elastic  # noqa: E402
from repro.launch import train as train_cli  # noqa: E402


def main():
    print("== phase A: node failure mid-training, buddy recovery ==")
    train_cli.main(["--arch", "starcoder2-15b", "--smoke", "--steps", "12",
                    "--seq", "48", "--batch", "4", "--ckpt-every", "3",
                    "--fault-at", "8"])
    print("\n== phase B: shrink the cluster between runs (4 -> 2 nodes) ==")
    elastic.main(["--arch", "gemma2-9b", "--steps", "5",
                  "--nodes-before", "4", "--nodes-after", "2"])


if __name__ == "__main__":
    main()
