"""Fleet serving on persistent memory: two workers share a warm prefix
dataset, a node dies mid-traffic, and every session resumes from its
acked replica — bit-exactly, with zero blind object-store probes.

The flow (paper §V-A cross-application sharing, applied to serving):

  1. worker A prefills a shared system prompt ONCE and publishes the KV
     state as catalog dataset ``prefix/system`` — a named, versioned,
     replicated Dataset the whole fleet forks from;
  2. workers A and B each start a user session forked from that prefix
     (the fork is recorded in the session's lineage) and decode some
     traffic;
  3. both sessions are suspended: each becomes a leased version of
     dataset ``sess/<name>`` — home pmem write + buddy replica acked
     through the exchange channel;
  4. a node is killed mid-traffic. ``recoverable_sessions`` answers
     from catalog records alone which sessions survive (all of them);
  5. worker B resumes BOTH sessions — including the one worker A
     created (cross-worker adoption from the catalog record) — off the
     dead node's acked replicas. A store-read audit shows zero blind
     probes, and the continuation matches an uninterrupted reference
     run bit-exactly.

    PYTHONPATH=src python examples/serve_longcontext.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.core.cluster import SimCluster  # noqa: E402
from repro.core.dataset_exchange import ack_targets  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.serve.engine import ServeEngine  # noqa: E402
from repro.serve.sessions import SessionManager  # noqa: E402


def _audit_store_reads(cluster):
    reads = []
    for nid, st in cluster.stores.items():
        for meth in ("get_with_manifest", "exists", "get_leaf"):
            orig = getattr(st, meth)

            def wrapped(name, *a, _orig=orig, _nid=nid, **kw):
                reads.append((_nid, name))
                return _orig(name, *a, **kw)

            setattr(st, meth, wrapped)
    return reads


def main():
    cfg = registry.get_smoke_config("qwen2-72b")
    rt = T.ModelRuntime(tp=1, attn_impl="naive", max_seq=128, remat=False)
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, rt)
    cluster = SimCluster(Path(tempfile.mkdtemp()), n_nodes=3)

    # two fleet workers: each has its own engine + session manager, but
    # they share the SAME catalog (in production: separate processes on
    # separate hosts over the same replicated pmem catalog records)
    eng_a = ServeEngine(cfg, rt, params, tiered=cluster.tiered,
                        label="workerA")
    eng_b = ServeEngine(cfg, rt, params, tiered=cluster.tiered,
                        label="workerB")
    sm_a = cluster.sessions
    sm_b = SessionManager(cluster.tiered, cluster.catalog,
                          owner="workerB", obs=cluster.obs)

    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab_size, (1, 24)).astype(np.int32)
    users = {"alice": int(rng.integers(0, cfg.vocab_size)),
             "bob": int(rng.integers(0, cfg.vocab_size))}

    # 1. shared warm prefix: prefilled once, published for the fleet
    first = eng_a.prefill(system)
    rec = sm_a.publish_prefix("system", eng_a)
    print(f"prefix/system published: v{rec['version']}, "
          f"{rec['nbytes']} bytes, home {rec['home']}")

    # 2. fork one session per user (worker A and worker B), decode a bit
    outs = {}
    for (user, tok), (sm, eng) in zip(
            users.items(), ((sm_a, eng_a), (sm_b, eng_b))):
        sm.start(user, eng, prefix="system")
        outs[user] = eng.decode(np.array([tok], np.int32), 6)
        sm.suspend(user)   # 3. leased dataset sess/<user>, replica acked
    cluster.tiered.quiesce()
    for user in users:
        r = cluster.catalog.record(f"sess/{user}", "serve")
        print(f"sess/{user}: v{r['version']} home {r['home']} "
              f"replicas {ack_targets(r['acks'].get('replica'))}")

    # 4. a node dies mid-traffic — pick one that homes a session
    victim = cluster.catalog.record("sess/alice", "serve")["home"]
    survivors = sm_b.recoverable_sessions([victim])
    print(f"killing {victim}; catalog says recoverable: {survivors} "
          f"(zero store probes)")
    assert survivors == sorted(users), survivors
    cluster.kill_node(victim)

    # a scheduler can still inspect the cold session at O(leaf) cost:
    # one byte-range read of the cursor off the acked replica
    print(f"peek sess/alice pos = {int(sm_b.peek('alice', 'pos'))}")

    # 5. worker B resumes BOTH sessions (alice was worker A's!) off the
    # replicas, under a store-read audit
    reads = _audit_store_reads(cluster)
    for user in users:
        if cluster.catalog.cache is not None:
            r = cluster.catalog.record(f"sess/{user}", "serve")
            cluster.catalog.cache.drop(
                f"exch/serve/sess/{user}@v{r['version']}")
        sm_b.resume(user, eng_b)
        more = eng_b.decode(outs[user][:, -1], 6)
        outs[user] = np.concatenate([outs[user], more[:, 1:]], axis=1)
        sm_b.suspend(user)
    blind = [(n, o) for n, o in reads
             if not o.endswith(".json") and n != victim
             and not o.startswith(("replica/", "wf/serve/"))]
    assert not blind, f"blind probes: {blind}"
    print(f"both sessions resumed on worker B "
          f"({len(reads)} audited reads, 0 blind probes)")

    # 6. reference: an uninterrupted engine produces the identical tokens
    ref = ServeEngine(cfg, rt, params)
    for user, tok in users.items():
        ref.prefill(system)
        full = ref.decode(np.array([tok], np.int32), 12)
        assert (full == outs[user]).all(), f"{user} diverged!"
    print("bit-exact continuation across fork + node loss + "
          "cross-worker resume — OK")
    cluster.shutdown()


if __name__ == "__main__":
    main()
