"""Serving with persistent-memory session state: prefill, decode, spill
the KV cache to B-APM, 'restart', resume the session bit-exactly.

    PYTHONPATH=src python examples/serve_longcontext.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.core.cluster import SimCluster  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.serve.engine import ServeEngine  # noqa: E402


def main():
    cfg = registry.get_smoke_config("recurrentgemma-9b")  # sub-quadratic
    rt = T.ModelRuntime(tp=1, attn_impl="naive", max_seq=128, remat=False)
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, rt)
    cluster = SimCluster(Path(tempfile.mkdtemp()), n_nodes=1)
    store = cluster.stores["node0"]

    eng = ServeEngine(cfg, rt, params, store=store)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32)
    first = eng.prefill(prompts)
    out = eng.decode(first, 8)
    print("generated:", out[:, 1:].tolist())

    eng.spill("session-A")
    print(f"KV/session state spilled to pmem "
          f"({store.pool.used_bytes()} bytes persisted)")

    # 'process restart': a brand-new engine resumes from B-APM
    eng2 = ServeEngine(cfg, rt, params, store=store)
    eng2.resume("session-A")
    more = eng2.decode(out[:, -1], 8)
    print("resumed generation:", more[:, 1:].tolist())

    # check: an uninterrupted engine produces the identical continuation
    ref = ServeEngine(cfg, rt, params)
    f = ref.prefill(prompts)
    full = ref.decode(f, 16)
    assert (full[:, 9:] == more[:, 1:]).all(), "resume diverged!"
    print("bit-exact resume across 'restart' — OK")
    cluster.shutdown()


if __name__ == "__main__":
    main()
