# Developer entry points. `make verify` is the tier-1 gate every PR must
# keep green (same command CI runs).
PY ?= python
# bash, not sh: the timed targets below use the `time` shell builtin
# (dash has none, and /usr/bin/time isn't guaranteed to exist)
SHELL := /bin/bash
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test-fast bench lint

# `time` prefix: suite duration is surfaced wherever verify runs,
# including the GitHub Actions log (CI calls these targets).
verify:
	time $(PY) -m pytest -x -q

test-fast:
	time $(PY) -m pytest -x -q -m "not slow"

bench:
	PYTHONPATH=src:. $(PY) benchmarks/run.py

lint:
	$(PY) -m pyflakes src tests benchmarks 2>/dev/null || \
	$(PY) -m py_compile $$(find src tests benchmarks -name '*.py')
