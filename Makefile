# Developer entry points. `make verify` is the tier-1 gate every PR must
# keep green (same command CI runs).
PY ?= python
# bash, not sh: the timed targets below use the `time` shell builtin
# (dash has none, and /usr/bin/time isn't guaranteed to exist)
SHELL := /bin/bash
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test-fast bench lint hygiene repair-smoke daemon-smoke metalog-smoke analyze sanitize-smoke obs-smoke zerocopy-smoke serve-smoke

# `time` prefix: suite duration is surfaced wherever verify runs,
# including the GitHub Actions log (CI calls these targets).
verify:
	time $(PY) -m pytest -x -q

test-fast:
	time $(PY) -m pytest -x -q -m "not slow"

bench:
	PYTHONPATH=src:. $(PY) benchmarks/run.py

# replica-repair smoke: one node loss + repair() must leave every acked
# checkpoint shard / dataset / DLM object with >= 2 copies, and a second
# loss fully recoverable with zero blind probes (CI runs this).
repair-smoke:
	$(PY) benchmarks/bench_repair.py --smoke

# continuous-repair-daemon smoke: the daemon's single-copy window must
# be shorter than the recovery-point-only baseline, the sweep must make
# zero blind object probes, and drain-only shards must rehydrate back
# into pmem (drain_only == 0). CI runs this.
daemon-smoke:
	$(PY) benchmarks/bench_repair_daemon.py --smoke

# metadata-log smoke: appends must beat whole-map JSON rewrites >= 5x
# at 10k objects, and a post-compaction cold replay must read < 2x the
# snapshot's bytes (replica snapshots skipped by header). CI runs this.
metalog-smoke:
	$(PY) benchmarks/bench_meta_log.py --smoke

# fail on tracked bytecode: .gitignore stops NEW __pycache__/.pyc adds,
# but nothing caught files already committed — CI runs this too.
hygiene:
	@bad=$$(git ls-files | grep -E '(^|/)__pycache__/|\.py[co]$$' || true); \
	if [ -n "$$bad" ]; then \
		echo "tracked bytecode files (remove + commit):"; \
		echo "$$bad"; exit 1; \
	fi

lint:
	$(PY) -m pyflakes src tests benchmarks 2>/dev/null || \
	$(PY) -m py_compile $$(find src tests benchmarks -name '*.py')

# pmemlint: the pmem data-plane invariant lint (persistence ordering,
# metadata-only recovery, lock discipline) vs the checked-in baseline.
# Fails only on NEW findings. CI runs this.
analyze:
	$(PY) -m repro.analysis.lint src/repro

# persistence-order sanitizer smoke: the MetaLog + checkpoint crash
# tests (torn tails, mid-compaction crashes) run under the runtime shim
# that asserts the committed-tail discipline and catches dirty-region
# drops. CI runs this.
sanitize-smoke:
	$(PY) -m pytest -x -q tests/test_meta_log.py tests/test_checkpoint.py \
		tests/test_analysis.py --pmem-sanitize

obs-smoke:
	$(PY) -m pytest -x -q tests/test_obs.py --pmem-sanitize
	$(PY) benchmarks/bench_obs.py --smoke

# zero-copy data-plane smoke: the raw byte-range replicate must beat the
# whole-tree materialization path >= 2x at a 64MB object with ZERO
# _flatten/_unflatten invocations on the pmem->pmem copy, and the wire
# codec must shrink fabric bytes while round-tripping bit-exactly. The
# crash/torn-chunk tests run under the sanitizer. CI runs this.
zerocopy-smoke:
	$(PY) -m pytest -x -q tests/test_zero_copy.py --pmem-sanitize
	$(PY) benchmarks/bench_zero_copy.py --smoke

# serve-tier smoke: 64 Zipf-churning sessions as leased catalog
# datasets; a max_inflight-budgeted repair storm must keep p99 resume
# latency within 2x the quiet baseline, no live-leased session may ever
# be evicted/reclaimed, and post-kill resumes must perform zero blind
# object-store probes (metadata-only recoverability). CI runs this.
serve-smoke:
	$(PY) -m pytest -x -q tests/test_serve_sessions.py
	$(PY) benchmarks/bench_serve.py --smoke
