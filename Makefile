# Developer entry points. `make verify` is the tier-1 gate every PR must
# keep green (same command CI runs).
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test-fast bench lint

verify:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q -m "not slow"

bench:
	PYTHONPATH=src:. $(PY) benchmarks/run.py

lint:
	$(PY) -m pyflakes src tests benchmarks 2>/dev/null || \
	$(PY) -m py_compile $$(find src tests benchmarks -name '*.py')
