"""Multi-tenant serve tier: session churn over leased catalog datasets.

MaxText-microbenchmark style: the three serving phases are measured
SEPARATELY, because they stress different parts of the stack —

  * **prefill** — pure model compute (jitted prefill on a smoke-sized
    transformer; the unjitted path is measured alongside as the cost of
    the bug this PR's satellite fixed);
  * **insert** — SessionManager.suspend: export + catalog publish
    (home pmem write, record, buddy replica submit) + lease handoff;
  * **resume** — SessionManager.resume under Zipf-skewed popularity
    (a few hot sessions dominate, the long tail goes cold), the
    DLM-cache / pmem / replica read path + lease re-acquire.

The storm leg re-runs the resume churn with N>=64 sessions while a
``max_inflight``-budgeted RepairDaemon sweeps a node kill — the serving
SLA question: does background repair blow up tail latency?

``--smoke`` (CI) asserts:
  * storm p99 resume latency <= 2x the storm-free baseline p99;
  * no live-leased session is ever evicted or reclaimed: every gc
    reclaim during churn hits only superseded versions, eviction never
    touches a bound session, and every resume succeeds;
  * the post-kill resume path performs ZERO object-store probes: the
    recoverability answer comes from catalog records alone, and every
    data read lands on the session's recorded home or an ACKED replica
    holder — never a blind fan-out.
"""
from __future__ import annotations

import argparse
import statistics
import time
from collections import Counter

import numpy as np

from repro.core.cluster import SimCluster
from repro.core.dataset_exchange import ack_targets
from repro.core.pmem import scratch_root

#: final telemetry snapshot of the storm leg (run.py --emit-metrics)
LAST_SNAPSHOT = None


def _kv_state(seed: int, kb: int):
    n = max(kb * (1 << 10) // 8, 32)
    r = np.random.RandomState(seed)
    return {"cache": {"k": r.randn(n).astype(np.float32),
                      "v": r.randn(n).astype(np.float32)},
            "pos": np.int32(seed)}


class _KVEngine:
    """export/install contract double: the manager moves state trees,
    the bench doesn't need real attention math for insert/resume."""

    def __init__(self, label="bench"):
        self.label = label
        self.state = None

    def export_state(self, release=False):
        out = {"cache": dict(self.state["cache"]),
               "pos": np.int32(self.state["pos"])}
        if release:
            self.state = None
        return out

    def install_state(self, obj):
        self.state = {"cache": dict(obj["cache"]), "pos": int(obj["pos"])}


def _record_store_reads(c):
    reads = []
    for nid, st in c.stores.items():
        for meth in ("get_with_manifest", "exists", "get_leaf"):
            orig = getattr(st, meth)

            def wrapped(name, *a, _orig=orig, _nid=nid, **k):
                reads.append((_nid, name))
                return _orig(name, *a, **k)

            setattr(st, meth, wrapped)
    return reads


def _zipf_pick(rng, n: int, a: float = 1.3) -> int:
    """Zipf-skewed session index (hot head, cold tail), clamped to n."""
    return min(int(rng.zipf(a)), n) - 1


def _build(tag: str, n_sessions: int, kb: int):
    """Cluster + n_sessions inserted through the manager (half forked
    from a shared warm prefix). Returns (cluster, insert latencies)."""
    c = SimCluster(scratch_root(f"bench_serve_{tag}_"), n_nodes=4)
    sm = c.sessions
    sm.publish_prefix("warm", _kv_state(1, kb))
    eng = _KVEngine()
    lat = []
    for i in range(n_sessions):
        name = f"s{i}"
        if i % 2:
            sm.start(name, eng, prefix="warm")
            eng.state["pos"] = i
        else:
            eng.state = {"cache": _kv_state(i, kb)["cache"], "pos": i}
            sm.start(name, eng)
        t0 = time.perf_counter()
        sm.suspend(name)
        lat.append(time.perf_counter() - t0)
    for nid in c.node_ids:
        c.heartbeat.beat(nid, 1)
    c.tiered.quiesce()  # replica acks recorded before any kill
    return c, lat


def _churn(c, n_sessions: int, ops: int, seed: int = 0):
    """Zipf-skewed resume/mutate/suspend churn. Returns (resume
    latencies, invariant-violation list). Every 16 ops it runs a gc
    sweep + cold eviction and audits the liveness invariants."""
    sm = c.sessions
    rng = np.random.RandomState(seed)
    eng = _KVEngine()
    lat, violations = [], []
    current = {n: sm._sessions[n].version for n in sm.sessions()}
    for op in range(ops):
        name = f"s{_zipf_pick(rng, n_sessions)}"
        t0 = time.perf_counter()
        try:
            sm.resume(name, eng)
        except KeyError as e:
            violations.append(f"live session {name} unreadable: {e}")
            continue
        lat.append(time.perf_counter() - t0)
        eng.state["pos"] += 1
        rec = sm.suspend(name)
        current[name] = rec["version"]
        if op % 16 == 15:
            active = set(sm.active_sessions())
            victims = sm.evict_cold(0.0)
            hit = active.intersection(victims)
            if hit:
                violations.append(f"evicted bound sessions: {hit}")
            for wf, ds, v in c.catalog.gc():
                nm = ds.split("/", 1)[1]
                if v >= current.get(nm, 0):
                    violations.append(
                        f"gc reclaimed LIVE version {ds}@v{v} "
                        f"(current {current.get(nm)})")
    return lat, violations


def _p(lat, q):
    i = max(0, min(len(lat) - 1, int(q * len(lat)) - 1))
    return sorted(lat)[i]


def _prefill_phase(rows, smoke: bool):
    import jax
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.serve.engine import ServeEngine

    cfg = registry.get_smoke_config("qwen2-72b")
    rt = T.ModelRuntime(tp=1, attn_impl="naive", max_seq=64, remat=False)
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg, rt)
    eng = ServeEngine(cfg, rt, params)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (1, 16)).astype(np.int32)
    eng.prefill(toks)  # trace + compile
    reps = 8 if smoke else 32
    jit_lat = []
    for _ in range(reps):
        t0 = time.perf_counter()
        eng.prefill(toks)
        jit_lat.append(time.perf_counter() - t0)
    unjit_lat = []
    for _ in range(max(reps // 2, 4)):
        t0 = time.perf_counter()
        T.prefill(params, cfg, rt, np.asarray(toks))
        unjit_lat.append(time.perf_counter() - t0)
    jit_med, unjit_med = statistics.median(jit_lat), \
        statistics.median(unjit_lat)
    rows.append(("prefill_jitted", jit_med * 1e6, "smoke_cfg_16tok"))
    rows.append(("prefill_unjitted", unjit_med * 1e6,
                 f"slowdown_x={unjit_med / jit_med:.2f}"))


def run(smoke: bool = False):
    global LAST_SNAPSHOT
    n_sessions = 64 if smoke else 256
    ops = 96 if smoke else 512
    kb = 8 if smoke else 64
    rows = []

    # ---- phase 1: prefill (model compute, jitted vs not) -------------
    _prefill_phase(rows, smoke)

    # ---- phase 2+3: insert, then storm-free resume churn -------------
    c, insert_lat = _build("base", n_sessions, kb)
    try:
        rows.append(("insert_suspend_p50",
                     statistics.median(insert_lat) * 1e6,
                     f"n={n_sessions}"))
        rows.append(("insert_suspend_p99", _p(insert_lat, 0.99) * 1e6,
                     ""))
        base_lat, violations = _churn(c, n_sessions, ops)
        assert not violations, violations
        base_p99 = _p(base_lat, 0.99)
        rows.append(("resume_p50_quiet", _p(base_lat, 0.50) * 1e6,
                     f"zipf_ops={ops}"))
        rows.append(("resume_p99_quiet", base_p99 * 1e6, ""))
    finally:
        c.shutdown()

    # ---- storm leg: same churn under a budgeted repair sweep ---------
    # (retried: a p99 over ~100 ops on shared CI hardware is noisy; the
    # claim is about the rate BUDGET, not one lucky scheduler slice)
    for attempt in range(3):
        c, _ = _build(f"storm{attempt}", n_sessions, kb)
        try:
            sm = c.sessions
            homes = Counter(
                c.catalog.record(f"sess/s{i}", "serve")["home"]
                for i in range(n_sessions))
            victim = homes.most_common(1)[0][0]

            # metadata-only recoverability: zero store probes
            reads = _record_store_reads(c)
            survivors = sm.recoverable_sessions([victim])
            assert len(survivors) == n_sessions, \
                f"only {len(survivors)}/{n_sessions} would survive"
            assert not reads, f"recoverable_sessions probed: {reads[:4]}"

            c.start_repair_daemon(poll_s=0.005, max_inflight=2)
            c.kill_node(victim)
            storm_lat, violations = _churn(c, n_sessions, ops, seed=1)
            assert not violations, violations
            storm_p99 = _p(storm_lat, 0.99)
            ok = storm_p99 <= 2.0 * base_p99
            if ok or not smoke or attempt == 2:
                rows.append(("resume_p99_under_storm", storm_p99 * 1e6,
                             f"victim={victim}_budget=2"
                             f"_vs_quiet_x={storm_p99 / base_p99:.2f}"))
                if smoke:
                    assert ok, (f"storm p99 {storm_p99 * 1e3:.2f}ms > 2x "
                                f"quiet p99 {base_p99 * 1e3:.2f}ms")

                # ---- post-kill resume: zero blind probes -------------
                c.recovery.daemon.wait_for([victim], timeout=120)
                dead_homed = [f"s{i}" for i in range(n_sessions)
                              if c.catalog.record(f"sess/s{i}", "serve")
                              ["home"] == victim][:8]
                eng = _KVEngine()
                audit = _record_store_reads(c)
                for name in dead_homed:
                    rec = c.catalog.record(f"sess/{name}", "serve")
                    acked = set(ack_targets(
                        (rec.get("acks") or {}).get("replica")))
                    if c.catalog.cache is not None:
                        c.catalog.cache.drop(
                            f"exch/serve/sess/{name}"
                            f"@v{rec['version']}")
                    del audit[:]
                    sm.resume(name, eng)
                    sm.suspend(name)
                    for nid, obj in audit:
                        if nid == victim or obj.endswith(".json") or \
                                obj.startswith("wf/serve/"):
                            continue  # dead-pool bounce / record / home
                        assert obj.startswith("replica/") and \
                            nid in acked, \
                            f"blind probe: {nid} {obj} (acked={acked})"
                rows.append(("post_kill_resume_audited",
                             float(len(dead_homed)),
                             "zero_blind_probes"))
                LAST_SNAPSHOT = c.obs.snapshot()
                break
        finally:
            c.shutdown()
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale (64 sessions); asserts storm p99 <= "
                         "2x quiet p99, no live-leased session evicted/"
                         "reclaimed, zero post-kill store probes")
    args = ap.parse_args(argv)
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")
    if args.smoke:
        print("smoke ok: storm p99 within 2x quiet, lease invariants "
              "held, post-kill resumes probed nothing blindly")


if __name__ == "__main__":
    main()
