"""Burst-buffer staging: prefetch hides external-filesystem latency.

The staged dataset pre-loads upcoming shards into node pmem (paper Fig. 8
steps 1-3); with prefetch on, per-step stall time collapses to pmem reads.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

from repro.configs import ShapeConfig, get_smoke_config
from repro.core.cluster import SimCluster
from repro.data.pipeline import StagedDataset

EXTERNAL_BW = 40e6


def _run_one(prefetch: int) -> float:
    cfg = get_smoke_config("gemma2-9b")
    shape = ShapeConfig("bench", 512, 8, "train")
    root = Path(tempfile.mkdtemp())
    c = SimCluster(root, n_nodes=2, external_bandwidth=EXTERNAL_BW)
    ds = StagedDataset(c, cfg, shape, n_shards=6, seqs_per_shard=2048,
                       prefetch=prefetch)
    t0 = time.perf_counter()
    n = 0
    for _ in ds.batches(6):
        n += 1
        time.sleep(0.05)  # emulate the compute part of the step
    dt = time.perf_counter() - t0
    c.shutdown()
    return dt / n


def run():
    cold = _run_one(prefetch=0)
    warm = _run_one(prefetch=3)
    return [
        ("staging_no_prefetch_step", cold * 1e6, "stalls_on_external"),
        ("staging_prefetch3_step", warm * 1e6,
         f"speedup={cold / warm:.2f}x"),
    ]
