"""Kernel microbenches (CPU: jnp reference paths timed; Pallas kernels run
in interpret mode for correctness only — wall-clock kernel perf is a TPU
measurement, the roofline analysis covers the TPU story)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run():
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 5)

    from repro.models.attention import blockwise_attention, local_attention
    q = jax.random.normal(ks[0], (1, 1024, 2, 4 * 64)).reshape(1, 1024, 8, 64)
    k = jax.random.normal(ks[1], (1, 1024, 2, 64))
    v = jax.random.normal(ks[2], (1, 1024, 2, 64))
    f1 = jax.jit(lambda q, k, v: blockwise_attention(q, k, v, causal=True))
    rows.append(("attn_blockwise_1k", _time(f1, q, k, v) * 1e6, "jnp_path"))
    f2 = jax.jit(lambda q, k, v: local_attention(q, k, v, window=256))
    t_local = _time(f2, q, k, v)
    rows.append(("attn_local_w256_1k", t_local * 1e6, "static_window_slices"))

    from repro.models.rglru import rglru_scan
    log_a = -jnp.abs(jax.random.normal(ks[3], (2, 2048, 256))) * 0.1
    gated = jax.random.normal(ks[4], (2, 2048, 256))
    f3 = jax.jit(rglru_scan)
    rows.append(("rglru_assoc_scan_2k", _time(f3, log_a, gated) * 1e6,
                 "jnp_path"))

    from repro.models.ssm import ssd_chunked
    x = jax.random.normal(ks[0], (1, 2048, 8, 64))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 2048, 8)))
    a = -jnp.exp(jax.random.normal(ks[2], (8,)) * 0.3)
    bc = jax.random.normal(ks[3], (1, 2048, 1, 128)) * 0.3
    f4 = jax.jit(lambda *a_: ssd_chunked(*a_, 256))
    rows.append(("ssd_chunked_2k", _time(f4, x, dt, a, bc, bc) * 1e6,
                 "jnp_path"))

    from repro.kernels.ckpt_codec.ref import encode_ref
    import numpy as np
    new = np.random.randn(1 << 22).astype(np.float32).reshape(-1, 1024)
    base = new + 0.01 * np.random.randn(*new.shape).astype(np.float32)
    t0 = time.perf_counter()
    encode_ref(new, base)
    rows.append(("ckpt_codec_encode_16MB", (time.perf_counter() - t0) * 1e6,
                 "numpy_host_path"))
    return rows
