"""Replica repair: restoring the replication factor vs riding it out.

Write-time replication (PR 2/3) survives ONE node loss; afterwards every
object the dead node homed or buddied is down to a single copy, and a
second loss destroys data that the ack map called REPLICATED the whole
time. ``TieredIO.repair`` closes the loop: after the first loss it
re-replicates every acked checkpoint shard, dataset and DLM object with
a single surviving copy to a fresh buddy, re-acking when durable.

Measured here, on identical pmem state:

  * **repair makespan** — wall time for the full scan + re-replication
    + re-ack after losing one node (the window of single-copy
    vulnerability);
  * **post-repair second loss** — kill the node holding the victim's
    only original replica: WITH repair, recovery restores the NEWEST
    step (zero steps skipped, zero blind probes) and every dataset
    stays recoverable; WITHOUT repair, the ack ranking rules out every
    step on metadata alone (correct — and catastrophic: data loss) and
    the victim-homed datasets are gone.

``--smoke`` runs a seconds-scale variant and asserts the acceptance
criteria: >= 2 acked surviving copies everywhere after repair, newest
step restored after the second loss with zero blind probes, and zero
recoverable-dataset regressions (CI runs this).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core.cluster import SimCluster
from repro.core.dataset_exchange import ack_targets
from repro.core.pmem import scratch_root


def _state(seed: int, kb: int):
    n = kb * (1 << 10) // 4
    return {"w": np.random.RandomState(seed).randn(max(n, 16))
            .astype(np.float32)}


def _build(tag: str, steps: int, datasets: int, dlm_objs: int, kb: int):
    c = SimCluster(scratch_root(f"bench_repair_{tag}_"), n_nodes=4,
                   slots=steps)
    for s in range(1, steps + 1):
        c.tiered.save_async(s, _state(s, kb)).result()
    for d in range(datasets):
        c.catalog.publish(f"ds{d}", _state(100 + d, kb), workflow="w",
                          node=c.node_ids[d % len(c.node_ids)])
    for k in range(dlm_objs):
        c.tiered.offload(f"serve/sess{k}", _state(200 + k, kb)).result()
    c.tiered.quiesce()  # every replica placed + acked
    return c


def _surviving_copies(c, lost):
    """(surface, object) -> surviving acked copy holders, for every
    acked object on all three surfaces — computed from metadata only."""
    out = {}
    for step in c.checkpointer.available_steps():
        acks = c.checkpointer.acks(step)
        man = c.checkpointer._meta_get_json(
            f"ckpt/manifest_step{step}.json")
        for nid in man.get("nodes") or c.node_ids:
            holders = {nid} | set(ack_targets(
                acks.get(nid, {}).get("replica")))
            out[("ckpt", f"step{step}/{nid}")] = holders - set(lost)
    for rec in c.catalog.records():
        holders = {rec["home"]} | set(ack_targets(
            (rec.get("acks") or {}).get("replica")))
        out[("dataset", rec["name"])] = holders - set(lost)
    for name, rec in c.tiered.dlm_acks.objects().items():
        holders = {rec["home"]} | set(ack_targets(rec))
        out[("dlm", name)] = holders - set(lost)
    return out


def run(smoke: bool = False):
    steps = 3 if smoke else 6
    datasets = 4 if smoke else 8
    dlm_objs = 3 if smoke else 8
    kb = 64 if smoke else 2048
    victim = "node1"
    rows = []

    # ---- with repair -------------------------------------------------
    c = _build("repair", steps, datasets, dlm_objs, kb)
    try:
        second = c.checkpointer.buddy_of(victim)  # holds victim's only
        c.kill_node(victim)                       # original replicas
        t0 = time.perf_counter()
        c.tiered.quiesce()
        report = c.tiered.repair([victim])
        t_repair = time.perf_counter() - t0
        n_repaired = len(report["repaired"])
        assert not report["errors"], report["errors"]
        rows.append(("repair_makespan", t_repair * 1e6,
                     f"objects={n_repaired}_ckpt={report['checkpoint']}"
                     f"_ds={report['dataset']}_dlm={report['dlm']}"))
        copies = _surviving_copies(c, [victim])
        thin = {k: v for k, v in copies.items() if len(v) < 2}
        if smoke:
            assert not thin, f"replication factor not restored: {thin}"
        rows.append(("repair_replication_factor", 2.0 if not thin else 1.0,
                     f"min_copies_over_{len(copies)}_acked_objects"))

        # second loss: the victim's ORIGINAL buddy dies too
        c.kill_node(second)
        lost2 = [victim, second]
        t0 = time.perf_counter()
        _tree, man = c.checkpointer.restore_latest_recoverable(
            lost_nodes=lost2)
        t_restore = time.perf_counter() - t0
        stats = c.checkpointer.last_restore_stats
        rows.append(("repair_2nd_loss_restore", t_restore * 1e6,
                     f"step={man['step']}_skipped={stats['skipped_by_ack']}"
                     f"_probed={stats['probed']}"))
        ds_ok = sum(
            1 for d in range(datasets)
            if c.catalog.recoverable(f"ds{d}", "w", lost_nodes=lost2))
        rows.append(("repair_2nd_loss_datasets_recoverable", float(ds_ok),
                     f"of_{datasets}"))
        if smoke:
            assert man["step"] == steps, \
                f"expected newest step {steps}, restored {man['step']}"
            assert stats["skipped_by_ack"] == 0 and stats["probed"] == 1, \
                f"walked back / probed blindly: {stats}"
            assert ds_ok == datasets, f"{datasets - ds_ok} datasets lost"
            for d in range(datasets):  # the bytes really are there
                c.catalog.get(f"ds{d}", "w")
    finally:
        c.shutdown()

    # ---- without repair: identical state, same two losses ------------
    c = _build("norepair", steps, datasets, dlm_objs, kb)
    try:
        second = c.checkpointer.buddy_of(victim)
        c.kill_node(victim)
        c.tiered.quiesce()
        c.kill_node(second)
        lost2 = [victim, second]
        t0 = time.perf_counter()
        try:
            _tree, man = c.checkpointer.restore_latest_recoverable(
                lost_nodes=lost2)
            outcome = f"step={man['step']}"
            recovered = True
        except IOError:
            outcome = "data_loss"
            recovered = False
        t_sel = time.perf_counter() - t0
        stats = c.checkpointer.last_restore_stats
        rows.append(("norepair_2nd_loss_restore", t_sel * 1e6,
                     f"{outcome}_skipped={stats['skipped_by_ack']}"
                     f"_probed={stats['probed']}"))
        ds_ok = sum(
            1 for d in range(datasets)
            if c.catalog.recoverable(f"ds{d}", "w", lost_nodes=lost2))
        rows.append(("norepair_2nd_loss_datasets_recoverable",
                     float(ds_ok), f"of_{datasets}"))
        if smoke:
            # the baseline really is a re-loss: every step ruled out on
            # metadata alone (zero blind probes even in failure), and
            # the victim-homed datasets are gone for good
            assert not recovered, \
                "baseline unexpectedly recovered — bench setup drifted"
            assert stats["probed"] == 0, stats
            assert ds_ok < datasets
    finally:
        c.shutdown()
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run; asserts the replication "
                         "factor is restored and a 2nd loss stays "
                         "recoverable with zero blind probes")
    args = ap.parse_args(argv)
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")
    if args.smoke:
        print("smoke ok: replication factor restored; 2nd loss "
              "recovered newest step with zero blind probes")


if __name__ == "__main__":
    main()
