"""MetaLog vs JSON read-merge-rewrite: the metadata plane's update cost.

Before the MetaLog port every ack/lease/journal update rewrote the whole
replicated JSON blob — O(state) bytes per update. The log appends one
fixed-header entry per update — O(entry) — which is the access pattern
byte-addressable persistent memory is built for (store + CLWB + SFENCE,
not file rewrites). Measured here, per metadata-state size N:

  * **ack-update throughput** — K incremental ack updates against a
    state of N objects: baseline rewrites the full N-entry JSON map to
    every pool per update; the log appends one entry per update.
  * **recovery-scan latency** — cold replay of the log (snapshot + tail
    entries, the restart path) vs re-reading the merged JSON copies.

``--smoke`` asserts the acceptance criteria (CI runs this): the log
sustains >= 5x the baseline ack-update throughput at N=10000, and a
post-compaction cold replay reads < 2x the snapshot's bytes (the
replicated copies' identical snapshots are skipped by header alone).
"""
from __future__ import annotations

import argparse
import shutil
import sys
import time

from repro.core.meta_log import MetaLog
from repro.core.object_store import PMemObjectStore
from repro.core.pmem import PMemPool, scratch_root

N_NODES = 4
SIZES = (100, 1_000, 10_000)


def _fold_acks(state, ev):
    if ev["op"] == "seed":
        state.update(ev["objects"])
    else:  # "ack": one object's replica ack changed
        state[ev["name"]] = ev["rec"]


def _mk_stores(tag: str):
    root = scratch_root(f"bench_metalog_{tag}_")
    stores = {f"node{i}": PMemObjectStore(
        PMemPool(root, f"node{i}")) for i in range(N_NODES)}
    return root, stores


def _objects(n: int):
    return {f"obj{i}": {"home": f"node{i % N_NODES}",
                        "targets": [f"node{(i + 1) % N_NODES}"],
                        "ts": float(i)} for i in range(n)}


def _bench_size(n: int, updates: int):
    """One state size: (log_us, json_us, replay_us, json_read_us,
    replay_bytes, snapshot_bytes) per-update/per-scan microseconds."""
    nodes = [f"node{i}" for i in range(N_NODES)]
    objects = _objects(n)

    # ---- baseline: read-merge-rewrite of the whole JSON map ----------
    root, stores = _mk_stores(f"json{n}")
    try:
        state = dict(objects)
        for s in stores.values():
            s.pool.put_json("bench/acks.json", state)
        t0 = time.perf_counter()
        for k in range(updates):
            name = f"obj{k % n}"
            state[name] = {**state[name], "targets": ["node0"],
                           "ts": float(k)}
            for s in stores.values():  # the old replication discipline
                s.pool.put_json("bench/acks.json", state)
        json_us = (time.perf_counter() - t0) / updates * 1e6
        t0 = time.perf_counter()
        merged = {}
        for s in stores.values():
            merged.update(s.pool.get_json("bench/acks.json"))
        json_read_us = (time.perf_counter() - t0) * 1e6
        assert len(merged) == n
    finally:
        shutil.rmtree(root, ignore_errors=True)

    # ---- MetaLog: one appended entry per update ----------------------
    root, stores = _mk_stores(f"log{n}")
    try:
        log = MetaLog(stores, nodes, "bench/acks.log", fold=_fold_acks,
                      compact_entries=1 << 30)  # no auto-compaction
        log.append({"op": "seed", "objects": objects})
        log.compact()  # the N-object state becomes the snapshot
        t0 = time.perf_counter()
        for k in range(updates):
            name = f"obj{k % n}"
            log.append({"op": "ack", "name": name,
                        "rec": {**objects[name], "targets": ["node0"],
                                "ts": float(k)}})
        log_us = (time.perf_counter() - t0) / updates * 1e6
        assert len(log.state()) == n
        # recovery scan: a cold deterministic replay from the copies
        log.compact()
        t0 = time.perf_counter()
        replayed = log.replay()
        replay_us = (time.perf_counter() - t0) * 1e6
        assert len(replayed) == n
        return (log_us, json_us, replay_us, json_read_us,
                log.stats["replay_bytes"], log.stats["snapshot_bytes"])
    finally:
        shutil.rmtree(root, ignore_errors=True)


def run(smoke: bool = False):
    updates = 50 if smoke else 200
    rows = []
    for n in SIZES:
        (log_us, json_us, replay_us, json_read_us,
         replay_b, snap_b) = _bench_size(n, updates)
        speedup = json_us / log_us
        rows.append((f"metalog_ack_update_n{n}", log_us,
                     f"json_rewrite={json_us:.0f}us_"
                     f"speedup={speedup:.1f}x"))
        rows.append((f"metalog_recovery_scan_n{n}", replay_us,
                     f"json_read={json_read_us:.0f}us_"
                     f"replay_bytes={replay_b}"))
        if n == SIZES[-1]:
            # acceptance: appends beat whole-map rewrites >= 5x at 10k
            # objects, and compaction keeps the cold replay bounded by
            # the snapshot (not one body per replica)
            if smoke:
                assert speedup >= 5.0, \
                    f"log speedup {speedup:.1f}x < 5x at n={n}"
                assert replay_b < 2 * snap_b, \
                    f"replay read {replay_b}B >= 2x snapshot {snap_b}B"
            rows.append((f"metalog_replay_over_snapshot_n{n}",
                         replay_b / snap_b * 100.0,
                         f"pct_snapshot={snap_b}B"))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run asserting the acceptance "
                         "criteria (CI)")
    args = ap.parse_args(argv)
    try:
        rows = run(smoke=args.smoke)
    except AssertionError as e:
        print(f"SMOKE FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.smoke:
        print("metalog smoke OK: >=5x ack-update throughput at 10k "
              "objects, post-compaction replay < 2x snapshot bytes",
              file=sys.stderr)


if __name__ == "__main__":
    main()
