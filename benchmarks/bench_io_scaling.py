"""Paper Table I analogue: I/O bandwidth scales with node count.

Writes a fixed-size distributed state as node-local pmem checkpoints for
n = 1, 2, 4, 8 nodes and reports aggregate bandwidth; contrast row writes
the same state through the (bandwidth-throttled) external filesystem —
the paper's Fig. 4 vs Fig. 5 comparison.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.cluster import SimCluster

STATE_MB = 64
EXTERNAL_BW = 400e6  # 400 MB/s external PFS per the contrast scenario


def _state(mb: int):
    n = mb * (1 << 20) // 4
    rows = 1 << 12
    return {"w": np.random.RandomState(0).randn(rows, n // rows)
            .astype(np.float32)}


def run():
    rows = []
    state = _state(STATE_MB)
    nbytes = sum(a.nbytes for a in state.values())
    for n_nodes in (1, 2, 4, 8):
        root = Path(tempfile.mkdtemp(prefix="bench_io_"))
        c = SimCluster(root, n_nodes=n_nodes, buddy=False)
        t0 = time.perf_counter()
        c.checkpointer.save(1, state)
        dt = time.perf_counter() - t0
        rows.append((f"pmem_ckpt_{n_nodes}nodes", dt * 1e6 / 1,
                     f"{nbytes / dt / 1e9:.2f}GB/s"))
        c.shutdown()
    # external filesystem path (throttled, single funnel)
    root = Path(tempfile.mkdtemp(prefix="bench_io_ext_"))
    c = SimCluster(root, n_nodes=4, buddy=False,
                   external_bandwidth=EXTERNAL_BW)
    t0 = time.perf_counter()
    c.external.put("ckpt_external", state)
    dt = time.perf_counter() - t0
    rows.append(("external_fs_ckpt", dt * 1e6, f"{nbytes / dt / 1e9:.2f}GB/s"))
    c.shutdown()
    # node->node replicate: whole-tree materialization vs the raw
    # byte-range path (same state, same pools) — the fabric-side
    # counterpart of the Table I rows (bench_zero_copy has the full
    # breakdown incl. the wire codec)
    from repro.core.object_store import copy_object
    from repro.core.pmem import scratch_root
    root = scratch_root("bench_io_copy_")
    c = SimCluster(root, n_nodes=2, buddy=False)
    src, dst = (c.stores[n] for n in c.node_ids)
    src.put("xfer", state)
    t0 = time.perf_counter()
    tree, man = src.get_with_manifest("xfer", verify=True)
    dst.put("xfer", tree, meta=dict(man.get("meta", {})))
    dt_tree = time.perf_counter() - t0
    rows.append(("replicate_whole_tree", dt_tree * 1e6,
                 f"{nbytes / dt_tree / 1e9:.2f}GB/s"))
    dst.delete("xfer")
    t0 = time.perf_counter()
    copy_object(src, dst, "xfer")
    dt_raw = time.perf_counter() - t0
    rows.append(("replicate_raw_byte_range", dt_raw * 1e6,
                 f"{nbytes / dt_raw / 1e9:.2f}GB/s"))
    c.shutdown()
    return rows
