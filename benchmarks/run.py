"""Benchmark harness: one bench per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows. ``--roofline`` additionally
regenerates the dry-run/roofline markdown tables from artifacts/dryrun.

``--host-tuned`` re-execs the harness under the host-tuning preamble the
reference JAX training repos ship in their ``run.sh`` (tcmalloc preload,
quiet TF logging, pinned XLA host device count): opt-in because it
mutates process-wide env and allocator, and a benchmark of the *pmem*
data plane should by default measure the stock environment CI uses.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

_TUNED_MARKER = "REPRO_BENCH_HOST_TUNED"
_TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
)


def _host_tuned_reexec() -> None:
    """Apply the SNIPPETS run.sh preamble and re-exec once: tcmalloc
    (when present — never a hard dependency), no large-alloc warnings,
    quiet TF/XLA logging, one XLA host device (benches are single-
    process; device-count fan-out would skew CPU accounting)."""
    if os.environ.get(_TUNED_MARKER):
        return  # already the tuned process
    env = dict(os.environ)
    env[_TUNED_MARKER] = "1"
    # re-exec runs this file as a script (argv[0]), not as -m
    # benchmarks.run — keep the package importable either way
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = ":".join(
        p for p in (repo, env.get("PYTHONPATH", "")) if p)
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    env.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                   "60000000000")
    env.setdefault("XLA_FLAGS",
                   "--xla_force_host_platform_device_count=1")
    for lib in _TCMALLOC_PATHS:
        if os.path.exists(lib):
            pre = env.get("LD_PRELOAD", "")
            if lib not in pre:
                env["LD_PRELOAD"] = f"{pre}:{lib}".strip(":")
            break
    os.execve(sys.executable,
              [sys.executable] + sys.argv, env)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--emit-metrics", action="store_true",
                    help="dump the obs/zero_copy suites' final telemetry "
                         "snapshots to BENCH_obs.json / "
                         "BENCH_zero_copy.json")
    ap.add_argument("--host-tuned", action="store_true",
                    help="re-exec under the tcmalloc/XLA host-tuning "
                         "preamble (SNIPPETS run.sh) before benching")
    args = ap.parse_args(argv)
    if args.host_tuned:
        _host_tuned_reexec()

    from benchmarks import (bench_checkpoint, bench_io_scaling,
                            bench_kernels, bench_meta_log, bench_obs,
                            bench_repair, bench_repair_daemon,
                            bench_replication, bench_serve,
                            bench_staging, bench_tiered_io,
                            bench_tiering, bench_workflow,
                            bench_zero_copy)
    suites = {
        "io_scaling": bench_io_scaling.run,       # paper Table I
        "checkpoint": bench_checkpoint.run,       # async/delta claims (§V.8)
        "staging": bench_staging.run,             # burst buffer (Fig. 8)
        "tiering": bench_tiering.run,             # SLM/DLM modes (§II-B)
        "tiered_io": bench_tiered_io.run,         # unified engine (Fig. 4+8)
        "replication": bench_replication.run,     # ack-ranked recovery
        "workflow": bench_workflow.run,           # dataset exchange (§V-A)
        "repair": bench_repair.run,               # replication-factor repair
        "repair_daemon": bench_repair_daemon.run,  # single-copy window
        "meta_log": bench_meta_log.run,           # append vs JSON rewrite
        "obs": bench_obs.run,                     # telemetry-plane overhead
        "zero_copy": bench_zero_copy.run,         # byte-range vs tree path
        "serve": bench_serve.run,                 # session churn over leases
        "kernels": bench_kernels.run,
    }
    print("name,us_per_call,derived")
    failed = False
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
        except Exception as e:
            failed = True
            print(f"{name},ERROR,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if args.emit_metrics:
        for mod, out in ((bench_obs, "BENCH_obs.json"),
                         (bench_zero_copy, "BENCH_zero_copy.json"),
                         (bench_serve, "BENCH_serve.json")):
            if mod.LAST_SNAPSHOT is None:
                continue
            with open(out, "w") as f:
                json.dump(mod.LAST_SNAPSHOT, f, indent=2,
                          sort_keys=True, default=str)
            print(f"wrote {out}", file=sys.stderr)
    if args.roofline:
        from benchmarks import roofline
        roofline.main()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
