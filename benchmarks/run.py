"""Benchmark harness: one bench per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows. ``--roofline`` additionally
regenerates the dry-run/roofline markdown tables from artifacts/dryrun.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--emit-metrics", action="store_true",
                    help="dump the obs suite's final telemetry snapshot "
                         "to BENCH_obs.json")
    args = ap.parse_args(argv)

    from benchmarks import (bench_checkpoint, bench_io_scaling,
                            bench_kernels, bench_meta_log, bench_obs,
                            bench_repair, bench_repair_daemon,
                            bench_replication, bench_staging,
                            bench_tiered_io, bench_tiering,
                            bench_workflow)
    suites = {
        "io_scaling": bench_io_scaling.run,       # paper Table I
        "checkpoint": bench_checkpoint.run,       # async/delta claims (§V.8)
        "staging": bench_staging.run,             # burst buffer (Fig. 8)
        "tiering": bench_tiering.run,             # SLM/DLM modes (§II-B)
        "tiered_io": bench_tiered_io.run,         # unified engine (Fig. 4+8)
        "replication": bench_replication.run,     # ack-ranked recovery
        "workflow": bench_workflow.run,           # dataset exchange (§V-A)
        "repair": bench_repair.run,               # replication-factor repair
        "repair_daemon": bench_repair_daemon.run,  # single-copy window
        "meta_log": bench_meta_log.run,           # append vs JSON rewrite
        "obs": bench_obs.run,                     # telemetry-plane overhead
        "kernels": bench_kernels.run,
    }
    print("name,us_per_call,derived")
    failed = False
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
        except Exception as e:
            failed = True
            print(f"{name},ERROR,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if args.emit_metrics and bench_obs.LAST_SNAPSHOT is not None:
        import json
        with open("BENCH_obs.json", "w") as f:
            json.dump(bench_obs.LAST_SNAPSHOT, f, indent=2,
                      sort_keys=True, default=str)
        print("wrote BENCH_obs.json", file=sys.stderr)
    if args.roofline:
        from benchmarks import roofline
        roofline.main()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
