"""Zero-copy byte-range data plane vs tree materialization (ROADMAP 4).

Four comparisons over one large (>=64 MB) pmem object:

  * replicate: legacy whole-tree path (get_with_manifest -> put, the
    pre-zero-copy scheduler body) vs the raw byte-range path
    (``copy_object`` streaming the backing region chunk-by-chunk with
    the source manifest committed verbatim);
  * replicate with the delta-int8 wire codec at the source (encoded
    bytes moved instead of raw — the fabric-bytes lever);
  * drain: whole-tree pickle vs ``export_object`` wire payload
    (serialized exactly once at the external boundary);
  * partial restore: whole-object read vs ``get_leaf``/
    ``read_leaf_slice`` byte ranges (the N->M warm-resize primitive).

``--smoke`` enforces the acceptance bar: the raw path must beat the
whole-tree baseline by >=2x replicate throughput at a >=64 MB object
WITH an audit proving zero tree materializations (``_flatten``/
``_unflatten`` never invoked) on the pmem->pmem copy path, and the
codec/partial paths must round-trip bit-exactly.

Module global ``LAST_SNAPSHOT`` holds the final telemetry snapshot
(``tiered.bytes_raw`` / ``tiered.bytes_encoded`` / ``copy.chunk``);
``benchmarks/run.py --emit-metrics`` dumps it to BENCH_zero_copy.json.
"""
from __future__ import annotations

import time

import numpy as np

import repro.core.object_store as osmod
from repro.core.object_store import (PMemObjectStore, copy_object,
                                     export_object, wire_tree)
from repro.core.pmem import PMemPool, scratch_root
from repro.obs.plane import TelemetryPlane

STATE_MB = 64
SMOKE_SPEEDUP = 2.0
REPS = 3

LAST_SNAPSHOT = None  # set by run(); run.py --emit-metrics dumps it


def _state(mb: int):
    """>=64 MB object: one dominant quantization-friendly float leaf
    (so the codec leg actually encodes), plus small satellites."""
    n = mb * (1 << 20) // 4
    rows = 1 << 12
    r = np.random.RandomState(0)
    return {"emb": r.randint(-100, 100, (rows, n // rows))
            .astype(np.float32),
            "head": {"b": r.randn(256).astype(np.float32)},
            "ids": np.arange(1024, dtype=np.int32)}


def _legacy_copy(src: PMemObjectStore, dst: PMemObjectStore,
                 name: str) -> None:
    """The pre-zero-copy replicate body: materialize the full tree,
    re-put (re-flatten + re-CRC every leaf) on the destination."""
    tree, man = src.get_with_manifest(name, verify=True)
    dst.put(name, tree, meta=dict(man.get("meta", {})))


class _MaterializationAudit:
    """Counts _flatten/_unflatten invocations inside a with-block."""

    def __init__(self):
        self.calls = 0

    def __enter__(self):
        self._orig = (osmod._flatten, osmod._unflatten)

        def flatten(*a, **k):
            self.calls += 1
            return self._orig[0](*a, **k)

        def unflatten(*a, **k):
            self.calls += 1
            return self._orig[1](*a, **k)
        osmod._flatten, osmod._unflatten = flatten, unflatten
        return self

    def __exit__(self, *exc):
        osmod._flatten, osmod._unflatten = self._orig
        return False


def _bench(fn, reps=REPS) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(smoke: bool = False):
    global LAST_SNAPSHOT
    rows = []
    obs = TelemetryPlane(enabled=True)
    root = scratch_root("bench_zc_")
    pools = {n: PMemPool(root, n, capacity_bytes=1 << 34)
             for n in ("src", "dst")}
    st = {n: PMemObjectStore(p) for n, p in pools.items()}
    state = _state(STATE_MB)
    nbytes = sum(a.nbytes for a in
                 (state["emb"], state["head"]["b"], state["ids"]))
    st["src"].put("obj", state, meta={"step": 1})

    # -- replicate: whole-tree baseline vs raw byte-range path --------
    dt_tree = _bench(lambda: _legacy_copy(st["src"], st["dst"], "obj"))
    rows.append(("replicate_whole_tree", dt_tree * 1e6,
                 f"{nbytes / dt_tree / 1e9:.2f}GB/s"))
    audit = _MaterializationAudit()
    with audit:
        dt_raw = _bench(lambda: copy_object(
            st["src"], st["dst"], "obj", expect_meta={"step": 1},
            obs=obs))
    rows.append(("replicate_raw_byte_range", dt_raw * 1e6,
                 f"{nbytes / dt_raw / 1e9:.2f}GB/s"))
    speedup = dt_tree / dt_raw
    rows.append(("replicate_raw_speedup", dt_raw * 1e6,
                 f"{speedup:.2f}x_tree_materializations_{audit.calls}"))
    out = st["dst"].get("obj", verify=True)
    assert np.array_equal(out["emb"], state["emb"])

    # -- replicate with the wire codec at the source ------------------
    st["dst"].delete("obj")
    dt_codec = _bench(lambda: copy_object(
        st["src"], st["dst"], "obj", expect_meta={"step": 1},
        codec=True, obs=obs))
    man = st["dst"].manifest("obj")
    enc = man["meta"]["wire_codec"]["nbytes_encoded"]
    rows.append(("replicate_codec_delta8", dt_codec * 1e6,
                 f"{enc / nbytes:.2f}x_bytes_on_wire"))
    dec = st["dst"].get("obj", verify=True)
    codec_exact = np.array_equal(dec["emb"], state["emb"])
    assert codec_exact

    # -- drain: pickle-the-tree vs wire payload -----------------------
    import pickle
    tree = st["src"].get("obj")
    dt_pkl = _bench(lambda: pickle.dumps(tree))
    rows.append(("drain_pickle_tree", dt_pkl * 1e6,
                 f"{nbytes / dt_pkl / 1e9:.2f}GB/s"))
    dt_wire = _bench(lambda: export_object(
        st["src"], "obj", expect_meta={"step": 1}, obs=obs))
    rows.append(("drain_export_wire", dt_wire * 1e6,
                 f"{nbytes / dt_wire / 1e9:.2f}GB/s"))
    wire = export_object(st["src"], "obj", codec=True)
    assert np.array_equal(wire_tree(wire)["emb"], state["emb"])

    # -- partial restore: whole object vs byte-range leaf/slice -------
    dt_whole = _bench(lambda: st["dst"].get("obj", verify=True))
    rows.append(("restore_whole_object", dt_whole * 1e6, "baseline"))
    dt_leaf = _bench(lambda: st["dst"].get_leaf("obj", "head/b"))
    rows.append(("restore_one_leaf", dt_leaf * 1e6,
                 f"{dt_whole / max(dt_leaf, 1e-9):.0f}x_less_read"))
    dt_slice = _bench(
        lambda: st["dst"].read_leaf_slice("obj", "emb", 128, 64))
    sl = st["dst"].read_leaf_slice("obj", "emb", 128, 64)
    assert np.array_equal(sl, state["emb"][128:192])
    rows.append(("restore_row_slice_64", dt_slice * 1e6,
                 f"{dt_whole / max(dt_slice, 1e-9):.0f}x_less_read"))

    LAST_SNAPSHOT = obs.snapshot()
    if smoke:
        assert audit.calls == 0, \
            f"raw copy path materialized a tree ({audit.calls} calls)"
        assert speedup >= SMOKE_SPEEDUP, \
            (f"raw byte-range replicate only {speedup:.2f}x over the "
             f"whole-tree baseline (need >={SMOKE_SPEEDUP}x at "
             f"{STATE_MB}MB)")
        assert enc < nbytes, "codec moved more bytes than raw"
    return rows


if __name__ == "__main__":
    import sys
    rows = run(smoke="--smoke" in sys.argv)
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
    if "--smoke" in sys.argv:
        print("bench_zero_copy smoke OK", file=sys.stderr)
