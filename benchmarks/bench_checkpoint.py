"""Checkpoint modes: sync-drain vs async-drain vs delta-incremental.

Reproduces the paper's async data-scheduler claim: the training step only
pays for the node-local pmem write; draining to the slow external tier
happens in the background. Delta encoding cuts bytes ~4x on slowly-moving
state.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.cluster import SimCluster

STATE_MB = 32
EXTERNAL_BW = 200e6


def _state(seed=0):
    n = STATE_MB * (1 << 20) // 4
    return {"w": np.random.RandomState(seed).randn(1 << 10, n >> 10)
            .astype(np.float32)}


def run():
    rows = []
    state = _state()
    nbytes = sum(a.nbytes for a in state.values())

    # sync: write external inline (what the paper's Fig. 4 world does)
    root = Path(tempfile.mkdtemp())
    c = SimCluster(root, n_nodes=4, buddy=False,
                   external_bandwidth=EXTERNAL_BW)
    t0 = time.perf_counter()
    c.external.put("sync_ckpt", state)
    rows.append(("ckpt_sync_external", (time.perf_counter() - t0) * 1e6,
                 "blocks_step"))
    # async: local pmem write blocks; drain overlaps
    t0 = time.perf_counter()
    c.checkpointer.save(1, state, drain=True)
    blocked = time.perf_counter() - t0
    t0 = time.perf_counter()
    c.checkpointer.wait_async()
    background = time.perf_counter() - t0
    rows.append(("ckpt_async_local_blocking", blocked * 1e6,
                 f"bg_drain={background * 1e3:.0f}ms"))
    c.shutdown()

    # delta: second step differs slightly -> int8 delta bytes
    root = Path(tempfile.mkdtemp())
    c = SimCluster(root, n_nodes=4, buddy=False, delta=True)
    c.checkpointer.save(1, state)
    state2 = {"w": state["w"] + np.float32(1e-3) *
              np.random.RandomState(1).randn(*state["w"].shape)
              .astype(np.float32)}
    t0 = time.perf_counter()
    c.checkpointer.save(2, state2, base_step=1)
    dt = time.perf_counter() - t0
    delta_bytes = sum(c.pools[n].used_bytes() for n in c.node_ids)
    full_twice = 2 * nbytes
    rows.append(("ckpt_delta_step", dt * 1e6,
                 f"bytes_ratio={delta_bytes / full_twice:.2f}"))
    # verify restore correctness through the delta path
    restored, _ = c.checkpointer.restore(2)
    err = float(np.abs(restored["w"] - state2["w"]).max())
    rows.append(("ckpt_delta_restore_maxerr", 0.0, f"{err:.2e}"))
    c.shutdown()
    return rows
