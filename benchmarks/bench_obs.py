"""Telemetry-plane overhead: the flight recorder + metrics must be
(near) free on the hot checkpoint path.

Two interleaved legs over the same code path: ``SimCluster`` with
``telemetry=True`` (per-node pmem flight-recorder rings + registry
metrics + trace spans) vs ``telemetry=False`` (registry only, no pmem
events). Timed: the full ``save_async(drain=True)`` path — submit,
pmem commit, replicate/drain fan-out, acks — joined per run. The paper's
systemware argument needs observability that does NOT tax the tiers it
observes; ``--smoke`` asserts the on/off overhead stays under 5% and
that ``python -m repro.obs.report`` can replay the recorded rings.

Module global ``LAST_SNAPSHOT`` holds the telemetry leg's final metrics
snapshot (``benchmarks/run.py --emit-metrics`` dumps it to
``BENCH_obs.json``).
"""
from __future__ import annotations

import statistics
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.cluster import SimCluster

STATE_MB = 8
STEPS = 8
REPS = 3            # interleaved reps per leg; medians absorb fs spikes
OVERHEAD_BUDGET = 0.05
SMOKE_RETRIES = 3   # a shared-runner scheduling spike is not a regression

LAST_SNAPSHOT = None  # set by run(); run.py --emit-metrics dumps it


def _state(seed=0):
    n = STATE_MB * (1 << 20) // 4
    return {"w": np.random.RandomState(seed).randn(1 << 9, n >> 9)
            .astype(np.float32)}


def _run_once(telemetry: bool):
    """One full checkpoint+drain run; returns (per-step seconds,
    pmem root, final metrics snapshot)."""
    root = Path(tempfile.mkdtemp(prefix="repro_obs_bench_"))
    c = SimCluster(root, n_nodes=2, telemetry=telemetry)
    state = _state()
    t0 = time.perf_counter()
    for step in range(1, STEPS + 1):
        c.tiered.save_async(step, state, drain=True)
    c.tiered.quiesce()
    c.checkpointer.wait_async()
    dt = (time.perf_counter() - t0) / STEPS
    snap = c.obs.snapshot() if telemetry else None
    c.shutdown()  # persists obs/metrics.json on the telemetry leg
    return dt, root / "pmem", snap


def _measure():
    """Interleaved on/off legs (shared-machine drift hits both)."""
    on, off = [], []
    pmem_root = None
    snap = None
    for _ in range(REPS):
        t_off, _, _ = _run_once(False)
        t_on, pmem_root, snap = _run_once(True)
        off.append(t_off)
        on.append(t_on)
    return statistics.median(off), statistics.median(on), pmem_root, snap


def run():
    global LAST_SNAPSHOT
    t_off, t_on, pmem_root, snap = _measure()
    LAST_SNAPSHOT = snap
    overhead = (t_on - t_off) / t_off
    rows = [
        ("obs_save_drain_step_telemetry_off", t_off * 1e6, "baseline"),
        ("obs_save_drain_step_telemetry_on", t_on * 1e6,
         f"overhead={overhead * 100:+.1f}%"),
    ]
    if snap is not None:
        recorded = sum(r["committed"]
                       for r in snap["recorder"].values())
        drops = sum(r["drops"] for r in snap["recorder"].values())
        rows.append(("obs_events_recorded_per_run", recorded,
                     f"drops={drops}"))
    # the replay CLI must reconstruct the trace from the rings alone
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs.report", str(pmem_root)],
        capture_output=True, text=True)
    replay_ok = proc.returncode == 0 and "ckpt.save" in proc.stdout
    rows.append(("obs_report_replay_ok", float(replay_ok),
                 f"rc={proc.returncode}"))
    return rows


def smoke() -> None:
    """CI gate: telemetry overhead under budget + replayable rings."""
    best = None
    for attempt in range(1, SMOKE_RETRIES + 1):
        t_off, t_on, pmem_root, _ = _measure()
        overhead = (t_on - t_off) / t_off
        best = overhead if best is None else min(best, overhead)
        print(f"attempt {attempt}: off={t_off * 1e3:.1f}ms "
              f"on={t_on * 1e3:.1f}ms overhead={overhead * 100:+.1f}%")
        if overhead < OVERHEAD_BUDGET:
            break
    assert best is not None and best < OVERHEAD_BUDGET, (
        f"telemetry overhead {best * 100:.1f}% exceeds "
        f"{OVERHEAD_BUDGET * 100:.0f}% budget")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs.report", str(pmem_root)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    assert "ckpt.save" in proc.stdout, "replay lost the save trace"
    print("obs smoke OK: overhead within budget, rings replayable")


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        smoke()
    else:
        for row in run():
            print(f"{row[0]},{row[1]:.1f},{row[2]}")
