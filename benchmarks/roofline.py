"""Roofline report generator: artifacts/dryrun/*.json -> markdown tables.

Terms are the HLO-derived per-device times (launch/hlo_analysis.py):
  compute_s    = flops / 197e12        (bf16 peak per chip)
  memory_s     = bytes / 819e9         (HBM)
  collective_s = wire_bytes / 50e9     (ICI per link)
roofline_fraction = (model_flops / peak) / max(term): the score reported
in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

LEVERS = {
    "compute": "cut remat recompute / skip masked attention blocks",
    "memory": "fuse attention (Pallas flash path), fewer f32 intermediates",
    "collective": "sequence-parallel TP (reduce-scatter instead of "
                  "all-reduce), overlap grad reduction",
}


def load(outdir: str = "artifacts/dryrun", tag: str = "") -> List[dict]:
    """Canonical (untagged) cells end with the mesh token; hillclimb
    variants carry a _<tag> suffix."""
    rows = []
    for f in sorted(Path(outdir).glob("*.json")):
        d = json.loads(f.read_text())
        d["_file"] = f.stem
        untagged = f.stem.endswith("16x16")
        if (tag and f.stem.endswith(f"_{tag}")) or (not tag and untagged):
            rows.append(d)
    return rows


def fmt_seconds(s: float) -> str:
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.1f}ms"
    return f"{s * 1e6:.0f}us"


def dryrun_table(rows: List[dict]) -> str:
    out = ["| cell | mesh | kind | fits 16GB | args GB | peak-model GB | "
           "flops/dev | AG | AR | RS | A2A | CP | compile |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d.get("skipped"):
            out.append(f"| {d['arch']}:{d['shape']} | {d['mesh']} | — | "
                       f"skip | — | — | — | — | — | — | — | — | — |")
            continue
        c = d["collectives"]
        m = d["memory"]

        def cnt(k):
            return int(c.get(k, {}).get("count", 0))
        out.append(
            f"| {d['arch']}:{d['shape']} | {d['mesh']} | {d['kind']} | "
            f"{'yes' if m['fits_16GB'] else 'NO'} | "
            f"{m['arg_bytes_exact'] / 1e9:.2f} | "
            f"{m['peak_model'] / 1e9:.2f} | {d['flops_per_device']:.2e} | "
            f"{cnt('all-gather')} | {cnt('all-reduce')} | "
            f"{cnt('reduce-scatter')} | {cnt('all-to-all')} | "
            f"{cnt('collective-permute')} | "
            f"{d['timing']['compile_s']:.0f}s |")
    return "\n".join(out)


def roofline_table(rows: List[dict]) -> str:
    out = ["| cell | mesh | compute | memory | collective | dominant | "
           "useful-FLOPs ratio | roofline frac | lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d.get("skipped"):
            continue
        r = d["roofline"]
        out.append(
            f"| {d['arch']}:{d['shape']} | {d['mesh']} | "
            f"{fmt_seconds(r['compute_s'])} | {fmt_seconds(r['memory_s'])} | "
            f"{fmt_seconds(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['useful_compute_ratio']:.2f} | "
            f"{r['roofline_fraction']:.4f} | {LEVERS[r['dominant']]} |")
    return "\n".join(out)


def pick_hillclimb_cells(rows: List[dict]) -> Dict[str, str]:
    live = [d for d in rows if not d.get("skipped")
            and d["mesh"] == "16x16"]
    worst = min(live, key=lambda d: d["roofline"]["roofline_fraction"])
    coll = max(live, key=lambda d: d["roofline"]["collective_s"] /
               max(d["roofline"]["compute_s"], 1e-9))
    return {"worst_fraction": f"{worst['arch']}:{worst['shape']}",
            "most_collective_bound": f"{coll['arch']}:{coll['shape']}"}


def codec_breakeven_note(wire_bw: float = 50e9,
                         peak_flops: float = 197e12,
                         ops_per_elem: float = 8.0) -> str:
    """Flops-vs-fabric-bytes break-even for the delta-int8 wire codec:
    encoding an f32 leaf moves ~0.25x the bytes (1B quantized + ~1B/256
    tile scales vs 4B raw), at ~``ops_per_elem`` integer ops per
    element for delta+quantize+CRC. The codec pays off on a channel
    whose effective bandwidth is below ``breakeven_bw`` — true for
    every cross-node replicate/drain hop here, false for node-local
    pmem copies (which is why ``wire_codec`` is per-channel opt-in,
    not global)."""
    saved_per_elem = 3.0  # bytes an f32 element sheds on the wire
    encode_s_per_elem = ops_per_elem / peak_flops
    breakeven_bw = saved_per_elem / encode_s_per_elem
    return (f"delta-int8 wire codec: ~0.25x bytes on the wire for f32 "
            f"state; encode cost ~{ops_per_elem:.0f} ops/elem -> "
            f"break-even at {breakeven_bw / 1e12:.0f} TB/s link "
            f"bandwidth, i.e. ALWAYS compute-cheap vs the "
            f"{wire_bw / 1e9:.0f} GB/s fabric; the real ceiling is the "
            f"strict-lossless fallback rate (leaves that fail exact "
            f"re-quantization ship raw — see bench_zero_copy).")


def main():
    rows = load()
    print("## Dry-run table\n")
    print(dryrun_table(rows))
    print("\n## Roofline table\n")
    print(roofline_table(rows))
    print("\n## Hillclimb candidates\n")
    print(json.dumps(pick_hillclimb_cells(rows), indent=1))
    print("\n## Wire-codec break-even\n")
    print(codec_breakeven_note())


if __name__ == "__main__":
    main()
