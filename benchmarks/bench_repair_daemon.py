"""Continuous repair daemon: single-copy window + foreground overhead.

PR 4's repair runs only at recovery points, so after a node loss every
object it homed or buddied sits on ONE pmem copy until the next
``check_and_recover``/``resume`` — and a ``drain_only`` shard stays out
of the fast tier entirely. The ``RepairDaemon`` closes both gaps: a
heartbeat-driven background sweep repairs within ~one poll interval of
the loss, rate-limited below foreground I/O, with drain-tier
rehydration.

Measured here, on identical pmem state:

  * **single-copy window** — wall time from the kill until every acked
    object has >= 2 surviving copies again: daemon (poll-driven) vs the
    recovery-point-only baseline (the same repair, but started only
    when the next recovery point arrives after ``recovery_delay``);
  * **drain rehydration** — a second loss strips a drained shard of all
    pmem copies; the daemon converges to ``drain_only == 0`` with the
    shard staged back + buddy-acked;
  * **foreground overhead** — median offload round-trip before vs
    during a rate-limited repair storm (daemon sweeping a fresh loss).

``--smoke`` (CI) asserts: the daemon's window is SHORTER than the
recovery-point-only baseline, the daemon scan performed zero blind
object probes (every store read was the source of a copy made), and the
accumulated report reaches ``drain_only == 0`` with ``rehydrated >= 1``.
"""
from __future__ import annotations

import argparse
import statistics
import time

import numpy as np

from repro.core.cluster import SimCluster
from repro.core.dataset_exchange import ack_targets
from repro.core.pmem import scratch_root


def _state(seed: int, kb: int):
    n = kb * (1 << 10) // 4
    return {"w": np.random.RandomState(seed).randn(max(n, 16))
            .astype(np.float32)}


def _build(tag: str, steps: int, datasets: int, dlm_objs: int, kb: int):
    c = SimCluster(scratch_root(f"bench_daemon_{tag}_"), n_nodes=4,
                   slots=steps)
    for s in range(1, steps + 1):
        c.tiered.save_async(s, _state(s, kb), drain=True).result()
    for d in range(datasets):
        # keep dataset homes off node1/node2 (the kill targets) so the
        # drain-only convergence below is purely the checkpoint story
        c.catalog.publish(f"ds{d}", _state(100 + d, kb), workflow="w",
                          node=("node0", "node3")[d % 2])
    for k in range(dlm_objs):
        c.tiered.offload(f"serve/sess{k}", _state(200 + k, kb)).result()
    c.tiered.quiesce()  # every replica placed + acked + drained
    for nid in c.node_ids:
        c.heartbeat.beat(nid, steps)
    return c


def _thin_objects(c, lost):
    """(surface, object) entries with < 2 surviving acked copies —
    computed from metadata only (the bench's RF probe)."""
    lost = set(lost)
    thin = []
    seen_slots = set()
    for step in sorted(c.checkpointer.available_steps(), reverse=True):
        acks = c.checkpointer.acks(step)
        man = c.checkpointer._meta_get_json(
            f"ckpt/manifest_step{step}.json")
        if man["slot"] in seen_slots:
            continue  # slot reused: the step is superseded, not thin
        seen_slots.add(man["slot"])
        for nid in man.get("nodes") or c.node_ids:
            holders = {nid} | set(ack_targets(
                acks.get(nid, {}).get("replica")))
            if len(holders - lost) < 2:
                thin.append(("ckpt", f"step{step}/{nid}"))
    for rec in c.catalog.records():
        holders = {rec["home"]} | set(ack_targets(
            (rec.get("acks") or {}).get("replica")))
        if len(holders - lost) < 2:
            thin.append(("dataset", rec["name"]))
    for name, rec in c.tiered.dlm_acks.objects().items():
        holders = {rec["home"]} | set(ack_targets(rec))
        if len(holders - lost) < 2:
            thin.append(("dlm", name))
    return thin


def _record_store_reads(c):
    """Audit every source-object touch. The zero-copy repair path never
    materializes a tree: each copy reads the source MANIFEST (twice —
    once to stream, once as the commit-point freshness recheck) and
    streams the backing region directly, so ``manifest`` is the read to
    count alongside the legacy whole-tree methods."""
    reads = []

    def wrap(st):
        for meth in ("get_with_manifest", "exists", "manifest"):
            orig = getattr(st, meth)

            def wrapped(name, *a, _orig=orig, **k):
                reads.append(name)
                return _orig(name, *a, **k)

            setattr(st, meth, wrapped)
    for st in c.stores.values():
        wrap(st)
    return reads


def run(smoke: bool = False):
    steps = 3 if smoke else 6
    datasets = 4 if smoke else 8
    dlm_objs = 6 if smoke else 12
    kb = 64 if smoke else 1024
    recovery_delay = 0.5 if smoke else 2.0  # time to the next recovery
    victim = "node1"                        # point, baseline only
    rows = []

    # ---- daemon: window from kill to RF restored ---------------------
    c = _build("daemon", steps, datasets, dlm_objs, kb)
    try:
        daemon = c.start_repair_daemon(poll_s=0.005, max_inflight=4)
        reads = _record_store_reads(c)
        t0 = time.perf_counter()
        c.kill_node(victim)
        assert daemon.wait_for([victim], timeout=120)
        w_daemon = time.perf_counter() - t0
        report = daemon.report()
        assert not report["errors"], report["errors"]
        thin = _thin_objects(c, [victim])
        rows.append(("daemon_single_copy_window_s", w_daemon * 1e6,
                     f"repaired={len(report['repaired'])}"
                     f"_thin_after={len(thin)}"))
        if smoke:
            assert not thin, f"RF not restored by daemon: {thin}"
            # zero blind probes: every read is the source of a copy made
            # (two manifest touches per zero-copy transfer: stream +
            # commit freshness recheck)
            assert len(reads) == 2 * len(report["repaired"]), \
                (reads, report)
            for name in reads:
                assert name.startswith(
                    ("ckpt/slot", "replica/", "dlm/", "wf/")), \
                    f"blind probe during daemon scan: {name}"

    finally:
        c.shutdown()

    # ---- rehydration: a double loss strips the drained shards of all
    # pmem copies BEFORE the daemon can intervene (it starts after the
    # kills); the sweep must stage them back from the external drain
    # and converge to drain_only == 0
    c = _build("rehydrate", steps, datasets, dlm_objs, kb)
    try:
        c.kill_node(victim)
        c.kill_node("node2")  # victim's shards: home + ring buddy gone
        t0 = time.perf_counter()
        daemon = c.start_repair_daemon(poll_s=0.005, max_inflight=4)
        assert daemon.wait_for([victim, "node2"], timeout=120)
        w_rehydrate = time.perf_counter() - t0
        report = daemon.report()
        rows.append(("daemon_rehydrated", float(report["rehydrated"]),
                     f"drain_only={report['drain_only']}"
                     f"_sweeps={report['sweeps']}"
                     f"_window_us={w_rehydrate * 1e6:.0f}"))
        if smoke:
            assert report["rehydrated"] >= 1, report
            assert report["drain_only"] == 0, report
            thin = _thin_objects(c, [victim, "node2"])
            assert not thin, f"post-rehydration RF not restored: {thin}"
    finally:
        c.shutdown()

    # ---- baseline: same repair, but only at the next recovery point --
    c = _build("baseline", steps, datasets, dlm_objs, kb)
    try:
        t0 = time.perf_counter()
        c.kill_node(victim)
        time.sleep(recovery_delay)       # window until check_and_recover
        c.tiered.quiesce()
        report = c.tiered.repair([victim])
        w_base = time.perf_counter() - t0
        assert not report["errors"], report["errors"]
        thin = _thin_objects(c, [victim])
        rows.append(("recovery_point_single_copy_window_s", w_base * 1e6,
                     f"delay={recovery_delay}s"
                     f"_repaired={len(report['repaired'])}"
                     f"_thin_after={len(thin)}"))
        if smoke:
            assert not thin
            assert w_daemon < w_base, \
                (f"daemon window {w_daemon:.3f}s not shorter than "
                 f"recovery-point window {w_base:.3f}s")
        rows.append(("daemon_window_shrink_x", w_base / w_daemon, ""))
    finally:
        c.shutdown()

    # ---- foreground overhead under a rate-limited repair storm -------
    c = _build("storm", steps, datasets, dlm_objs, kb)
    try:
        n_ops = 20 if smoke else 50

        def offload_median():
            lat = []
            for i in range(n_ops):
                t0 = time.perf_counter()
                c.tiered.offload("serve/fg", _state(999, kb)).result()
                lat.append(time.perf_counter() - t0)
            return statistics.median(lat)
        quiet = offload_median()
        c.start_repair_daemon(poll_s=0.005, max_inflight=2)
        c.kill_node("node3")  # storm: daemon sweeps while we offload
        storm = offload_median()
        c.recovery.daemon.wait_for(["node3"], timeout=120)
        rows.append(("foreground_offload_quiet", quiet * 1e6, ""))
        rows.append(("foreground_offload_under_storm", storm * 1e6,
                     f"overhead_x={storm / quiet:.2f}"
                     f"_budget={2}"))
    finally:
        c.shutdown()
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run; asserts daemon window < "
                         "recovery-point window, zero blind probes, "
                         "and drain_only==0 after rehydration")
    args = ap.parse_args(argv)
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")
    if args.smoke:
        print("smoke ok: daemon shrank the single-copy window with "
              "zero blind probes; drain-only shards rehydrated to pmem")


if __name__ == "__main__":
    main()
