"""Workflow data plane: in-situ dataset exchange vs external round-trip,
and serial vs concurrent DAG makespan (paper §V-A, Fig. 8).

Two claims measured:

1. **Exchange**: a producer/consumer chain that hands datasets over
   through the pmem-resident catalog (retain -> in-situ read) vs the
   same chain round-tripping every hop through the external filesystem
   (drain -> stage-in), the way separate applications share data
   without a B-APM exchange. The external tier is bandwidth-throttled
   to a parallel-filesystem share; the catalog hop never touches it.

2. **Makespan**: a branching 8-job DAG (source -> 6 independent
   branches -> sink) under the concurrent scheduler (ready jobs
   dispatch onto per-node DataScheduler workers) vs the old serial
   ``ready[0]`` walk (``max_concurrent=1``).

``--smoke`` runs a seconds-scale variant and asserts both speedups —
CI keeps the bench honest without paying full sizes.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core.cluster import SimCluster
from repro.core.pmem import scratch_root
from repro.core.workflow import JobSpec

CHAIN = 4          # producer/consumer hops in the exchange chain
BRANCHES = 6       # parallel middle jobs of the 8-job branching DAG


def _payload(seed: int, size_mb: float):
    n = max(1, int(size_mb * (1 << 20) // 4))
    return {"x": np.random.RandomState(seed).randn(n).astype(np.float32)}


def _chain_insitu(cluster, size_mb: float) -> float:
    """One workflow; each hop retains its output in the catalog and the
    next hop reads it in situ."""
    def mk(i):
        def fn(ctx):
            prev = ctx.read(f"ins_x{i - 1}")["x"] if i else None
            out = _payload(i, size_mb)["x"] if prev is None else prev + 1.0
            return {f"ins_x{i}": {"x": out}}
        return fn

    jobs = [JobSpec(f"hop{i}", mk(i),
                    inputs=(f"ins_x{i - 1}",) if i else (),
                    after=(f"hop{i - 1}",) if i else (),
                    retain=(f"ins_x{i}",))
            for i in range(CHAIN)]
    t0 = time.perf_counter()
    cluster.workflows.run(jobs, workflow="bench_insitu")
    return time.perf_counter() - t0


def _chain_external(cluster, size_mb: float) -> float:
    """Each hop is its OWN workflow (separate applications): the
    producer drains its output to the external store, the consumer
    burst-buffers it back in — the pre-B-APM filesystem round-trip."""
    def mk(i):
        def fn(ctx):
            prev = ctx.read(f"ext_x{i - 1}")["x"] if i else None
            out = _payload(i, size_mb)["x"] if prev is None else prev + 1.0
            return {f"ext_x{i}": {"x": out}}
        return fn

    t0 = time.perf_counter()
    for i in range(CHAIN):
        cluster.workflows.run(
            [JobSpec(f"hop{i}", mk(i),
                     inputs=(f"ext_x{i - 1}",) if i else (),
                     drain=(f"ext_x{i}",))],
            workflow=f"bench_ext{i}")
    return time.perf_counter() - t0


def _branching_jobs(work_s: float):
    def src(ctx):
        return {"b_seed": {"x": np.arange(64.0)}}

    def mk_branch(i):
        def fn(ctx):
            ctx.read("b_seed")
            time.sleep(work_s)  # the branch's compute
            return {f"b_part{i}": {"x": np.full(16, float(i))}}
        return fn

    def sink(ctx):
        total = sum(ctx.read(f"b_part{i}")["x"].sum()
                    for i in range(BRANCHES))
        return {"b_total": {"s": np.array([total])}}

    jobs = [JobSpec("src", src, retain=("b_seed",))]
    jobs += [JobSpec(f"branch{i}", mk_branch(i), inputs=("b_seed",),
                     after=("src",), retain=(f"b_part{i}",))
             for i in range(BRANCHES)]
    jobs.append(JobSpec("sink", sink,
                        inputs=tuple(f"b_part{i}" for i in range(BRANCHES)),
                        after=tuple(f"branch{i}" for i in range(BRANCHES)),
                        retain=("b_total",)))
    return jobs


def _makespan(cluster, work_s: float, workflow: str,
              max_concurrent=None, repeats: int = 2) -> float:
    """Best-of-N makespan (the scheduler's floor, not the host's
    jitter); every repeat re-verifies the sink's reduction."""
    best = float("inf")
    for r in range(repeats):
        t0 = time.perf_counter()
        cluster.workflows.run(_branching_jobs(work_s),
                              workflow=f"{workflow}_{r}",
                              max_concurrent=max_concurrent)
        best = min(best, time.perf_counter() - t0)
        total = cluster.catalog.get("b_total", f"{workflow}_{r}")["s"][0]
        assert float(total) == sum(16.0 * i for i in range(BRANCHES)), total
    return best


def run(smoke: bool = False):
    size_mb = 1.0 if smoke else 8.0
    bandwidth = 30e6 if smoke else 150e6
    work_s = 0.08 if smoke else 0.12

    rows = []
    c = SimCluster(scratch_root("bench_wf_"), n_nodes=4,
                   external_bandwidth=bandwidth)
    try:
        t_ins = _chain_insitu(c, size_mb)
        t_ext = _chain_external(c, size_mb)
        rows.append(("workflow_exchange_in_situ", t_ins * 1e6,
                     f"{CHAIN}_hops_{size_mb}MB_via_pmem_catalog"))
        rows.append(("workflow_exchange_external", t_ext * 1e6,
                     f"{CHAIN}_hops_{size_mb}MB_via_drain+stage_in"))
        rows.append(("workflow_exchange_speedup", t_ext / t_ins,
                     "x_faster_in_situ"))
    finally:
        c.shutdown()

    c = SimCluster(scratch_root("bench_wf_"), n_nodes=4)
    try:
        t_serial = _makespan(c, work_s, "bench_serial", max_concurrent=1)
        t_conc = _makespan(c, work_s, "bench_conc")
        rows.append(("workflow_makespan_serial", t_serial * 1e6,
                     f"{BRANCHES + 2}_jobs_ready0_walk"))
        rows.append(("workflow_makespan_concurrent", t_conc * 1e6,
                     f"{BRANCHES + 2}_jobs_parallel_dispatch"))
        rows.append(("workflow_makespan_speedup", t_serial / t_conc,
                     "x_faster_concurrent"))
    finally:
        c.shutdown()
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run; asserts both speedups")
    args = ap.parse_args(argv)
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    speedups = {}
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")
        if name.endswith("_speedup"):
            speedups[name] = val
    if args.smoke:
        bad = {k: v for k, v in speedups.items() if v <= 1.05}
        if bad:
            print(f"SMOKE FAILURE: expected speedups > 1.05, got {bad}",
                  file=sys.stderr)
            sys.exit(1)
        print("smoke ok: in-situ beats external, concurrent beats serial")


if __name__ == "__main__":
    main()
