"""TieredIO: per-step checkpoint overhead, blocking vs async, and
burst-buffer stage-in hit rate.

The paper's claim (Fig. 4 / Fig. 8): with a node-local B-APM tier and an
async data scheduler, the application step pays neither for the external
tier nor for the pmem write — only for handing the state over. Three
modes are timed over a short synthetic "training" run:

  blocking_external : state pickled straight to the throttled external
                      filesystem inside the step (no B-APM at all);
  blocking_pmem     : node-local shadow-slot write inside the step
                      (B-APM present, but synchronous use of it);
  tiered_async      : ``TieredIO.save_async`` — the step pays only the
                      submit; write + drain overlap the next step.

Plus the Fig. 8 staging path: a consumer walks shards twice with
``TieredIO.stage_in`` pre-loading; the second pass must be all hits.
"""
from __future__ import annotations

import statistics
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.cluster import SimCluster

STATE_MB = 16
EXTERNAL_BW = 100e6
STEPS = 6
COMPUTE_S = 0.02  # emulated per-step compute


def _state(seed=0):
    n = STATE_MB * (1 << 20) // 4
    return {"w": np.random.RandomState(seed).randn(1 << 9, n >> 9)
            .astype(np.float32)}


def _run_mode(mode: str) -> float:
    root = Path(tempfile.mkdtemp())
    c = SimCluster(root, n_nodes=2, buddy=False,
                   external_bandwidth=EXTERNAL_BW)
    state = _state()
    per_step = []
    for step in range(1, STEPS + 1):
        time.sleep(COMPUTE_S)  # the "compute" the I/O should overlap
        t0 = time.perf_counter()
        if mode == "blocking_external":
            c.external.put(f"ckpt{step}", state)
        elif mode == "blocking_pmem":
            c.checkpointer.save(step, state, drain=True)
        elif mode == "tiered_async":
            c.tiered.save_async(step, state, drain=True)
        per_step.append(time.perf_counter() - t0)
    c.tiered.quiesce()
    c.checkpointer.wait_async()
    c.shutdown()
    # median: container-fs fsync latency spikes would dominate a mean
    return statistics.median(per_step)


def run():
    rows = []
    blocking_ext = _run_mode("blocking_external")
    blocking_pmem = _run_mode("blocking_pmem")
    tiered = _run_mode("tiered_async")
    rows.append(("tiered_ckpt_blocking_external_step", blocking_ext * 1e6,
                 "pays_external_bw"))
    rows.append(("tiered_ckpt_blocking_pmem_step", blocking_pmem * 1e6,
                 f"speedup={blocking_ext / blocking_pmem:.1f}x"))
    rows.append(("tiered_ckpt_async_step", tiered * 1e6,
                 f"speedup_vs_blocking_pmem={blocking_pmem / tiered:.1f}x"))

    # ---- burst-buffer stage-in hit rate (Fig. 8) ----
    root = Path(tempfile.mkdtemp())
    c = SimCluster(root, n_nodes=2, external_bandwidth=EXTERNAL_BW)
    shard = {"tokens": np.arange(1 << 18, dtype=np.int32)}
    names = [f"shard{i}" for i in range(4)]
    for n in names:
        c.external.put(n, shard)
    for _ in range(2):  # second epoch: every shard already resident
        for f in c.tiered.stage_in("node0", names):
            f.result()
    rows.append(("tiered_stage_in_hit_rate", c.tiered.stage_in_hit_rate(),
                 f"hits={c.tiered.stats['stage_in_hits']}"
                 f"/loads={c.tiered.stats['stage_in_loads']}"))
    c.shutdown()
    return rows
