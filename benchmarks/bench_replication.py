"""Recovery-candidate selection: ack-ranked vs probe-all.

The paper's remote-B-APM replication makes node-local checkpoints
survivable, but recovery first has to FIND the newest survivable step.
Without acks that means probing: attempt a restore per step, newest
first, paying object reads + CRC verification for every step that turns
out to be unrecoverable. With the manifest ack map, a step whose lost
shard owner has no acknowledged replica is ruled out on metadata alone.

Setup: ``REPLICATED`` fully-acked steps, then ``UNREPLICATED`` steps
whose replication "never finished" (the node died inside the
commit->ack window), then a node loss. Recovery must walk through every
unreplicated step before landing on the newest replicated one; the
benchmark times that selection with acks vs with probe-all, on
identical on-pmem state.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.cluster import SimCluster

REPLICATED = 3     # fully-acked tail of history
UNREPLICATED = 6   # steps that died inside the commit->ack window
STATE_KB = 4096    # probing a dead step then costs real reads + CRC


def _state(seed=0):
    n = STATE_KB * (1 << 10) // 4
    return {"w": np.random.RandomState(seed).randn(16, n // 16)
            .astype(np.float32)}


def run():
    root = Path(tempfile.mkdtemp())
    total = REPLICATED + UNREPLICATED
    # enough shadow slots that every step's data stays live: the walk
    # depth is then bounded by replication state, not slot reuse
    c = SimCluster(root, n_nodes=4, slots=total)
    try:
        for s in range(1, REPLICATED + 1):
            c.tiered.save_async(s, _state(s)).result()
        c.tiered.quiesce()  # replicas placed, acks recorded
        c.checkpointer.buddy = False  # the fabric "dies": no more acks
        for s in range(REPLICATED + 1, total + 1):
            c.tiered.save_async(s, _state(s)).result()
        c.tiered.quiesce()
        victim = c.node_ids[-1]
        c.kill_node(victim)

        rows = []
        timings = {}
        for mode, use_acks in (("acks", True), ("probe_all", False)):
            t0 = time.perf_counter()
            out, man = c.checkpointer.restore_latest_recoverable(
                lost_nodes=[victim], use_acks=use_acks)
            timings[mode] = time.perf_counter() - t0
            stats = c.checkpointer.last_restore_stats
            assert man["step"] == REPLICATED, (mode, man["step"])
            rows.append((f"replication_select_{mode}",
                         timings[mode] * 1e6,
                         f"skipped={stats['skipped_by_ack']}"
                         f"/probed={stats['probed']}"))
        rows.append(("replication_select_speedup",
                     timings["probe_all"] / timings["acks"],
                     f"x_faster_with_acks_over_{UNREPLICATED}_dead_steps"))
        return rows
    finally:
        c.shutdown()
