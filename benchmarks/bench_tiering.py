"""SLM/DLM modes: DLM cache hit vs miss latency; SLM offload round-trip."""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.cluster import SimCluster
from repro.core.tiering import DLMCache, SLMTier


def run():
    rows = []
    root = Path(tempfile.mkdtemp())
    c = SimCluster(root, n_nodes=1)
    store = c.stores["node0"]
    obj = {"x": np.random.RandomState(0).randn(1 << 20).astype(np.float32)}

    cache = DLMCache(store, capacity_bytes=1 << 26)
    cache.put("hot", obj)
    t0 = time.perf_counter()
    for _ in range(20):
        cache.get("hot")
    hit = (time.perf_counter() - t0) / 20
    cache2 = DLMCache(store, capacity_bytes=1 << 26)
    store.put("dlm/cold", obj)
    t0 = time.perf_counter()
    cache2.get("cold")
    miss = time.perf_counter() - t0
    rows.append(("dlm_hit", hit * 1e6, f"miss/hit={miss / max(hit, 1e-9):.0f}x"))
    rows.append(("dlm_miss_pmem", miss * 1e6, "loads_from_pmem"))

    slm = SLMTier(store, "opt")
    tree = {"m": obj["x"], "v": obj["x"], "p": obj["x"][:16]}
    t0 = time.perf_counter()
    resident, handle = slm.offload(tree, ["m", "v"])
    off = time.perf_counter() - t0
    t0 = time.perf_counter()
    slm.fetch(resident, handle)
    fetch = time.perf_counter() - t0
    rows.append(("slm_offload_8MB", off * 1e6, f"fetch={fetch * 1e3:.1f}ms"))
    c.shutdown()
    return rows
