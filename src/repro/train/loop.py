"""Training loop: steps + the paper's systemware hooks.

Per step: train_step (jit) -> heartbeat -> straggler stats. Every
``ckpt_every`` steps the loop hands the (host-fetched) state to the
TieredIO engine via ``save_async`` — even the node-local pmem write now
overlaps the next step's compute; the loop only ever blocks on slot
backpressure (a write two checkpoints old still in flight). In-flight
futures are joined exactly twice: at clean shutdown, and (via
``TieredIO.quiesce``) before a failure restore so the checkpoint index
is stable and errors from dead nodes are swallowed — the paper's §II-A
resume story over the §V-B data scheduler.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.core.cluster import SimCluster
from repro.core.resilience import StragglerDetector


@dataclass
class LoopConfig:
    steps: int = 20
    ckpt_every: int = 5
    delta_ckpt: bool = False     # incremental checkpoints vs last full
    drain_every: int = 0         # 0 = drain only at the end
    heartbeat_node: str = "node0"
    # run the continuous RepairDaemon alongside training: node losses
    # are repaired in the background (rate-limited below foreground
    # I/O) instead of waiting for the fault hook / next recovery point
    repair_daemon: bool = False
    daemon_poll_s: float = 0.02


@dataclass
class LoopState:
    step: int = 0
    losses: List[float] = field(default_factory=list)
    ckpt_seconds: List[float] = field(default_factory=list)
    recovered_at: List[int] = field(default_factory=list)
    # acknowledged durability of the final checkpoint at shutdown
    # ("LOCAL" / "REPLICATED" / "DRAINED"; None if no checkpoint ran) —
    # a run report can now say what a node loss right after exit costs
    final_ckpt_durability: Optional[str] = None


def run(train_step_fn: Callable, params, opt_state,
        batches: Iterator[Dict[str, np.ndarray]], cluster: SimCluster,
        loop_cfg: LoopConfig,
        fault_at: Optional[int] = None) -> LoopState:
    """Drive training with checkpoint/restart. ``fault_at`` kills a node
    after that step (test/demo hook) to exercise recovery."""
    state = LoopState()
    sd = StragglerDetector()
    last_full = None
    last_ticket = None
    dead_nodes: set = set()
    daemon = cluster.start_repair_daemon(poll_s=loop_cfg.daemon_poll_s) \
        if loop_cfg.repair_daemon else None
    try:
        for step, batch in enumerate(batches):
            t0 = time.time()
            params, opt_state, metrics = \
                train_step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            state.losses.append(loss)
            state.step = step + 1
            dt = time.time() - t0
            for nid in cluster.node_ids:
                if nid in dead_nodes:
                    continue  # a forgotten victim must STAY forgotten:
                    # recording it again would re-skew the fleet median
                cluster.heartbeat.beat(nid, step)
                sd.record(nid, dt)
            if (step + 1) % loop_cfg.ckpt_every == 0:
                # fail fast: a checkpoint that failed to COMMIT must
                # surface now, not after hours of unprotected training
                cluster.tiered.raise_if_failed()
                t0 = time.time()
                host_state = {"params": jax.tree.map(np.asarray, params),
                              "opt": jax.tree.map(np.asarray, opt_state)}
                base = last_full if loop_cfg.delta_ckpt else None
                last_ticket = cluster.tiered.save_async(
                    step + 1, host_state, base_step=base,
                    drain=bool(loop_cfg.drain_every))
                if not loop_cfg.delta_ckpt or last_full is None:
                    last_full = step + 1
                # what the step pays: the submit (+ slot backpressure)
                state.ckpt_seconds.append(time.time() - t0)
            if fault_at is not None and step + 1 == fault_at:
                # simulate node loss at a replication-quiescent point:
                # join in-flight saves/replicas BEFORE the kill so the
                # hook deterministically exercises buddy recovery. (A
                # failure landing inside the replication window instead
                # loses the un-replicated tail;
                # restore_latest_recoverable walks back to the newest
                # fully-replicated checkpoint in that case.) Going
                # through recovery.quiesce_inflight records any
                # swallowed errors on the recovery object for forensics.
                cluster.recovery.quiesce_inflight()
                victim = cluster.node_ids[-1]
                # the victim's stale step times must not keep skewing
                # the fleet median the survivors are judged by
                sd.forget(victim)
                dead_nodes.add(victim)
                cluster.kill_node(victim)
                restored, manifest = \
                    cluster.checkpointer.restore_latest_recoverable(
                        lost_nodes=[victim])
                # restore the replication factor before resuming: every
                # acked shard the victim homed or buddied is down to
                # one copy, and the CONTINUED run must survive the next
                # loss too. With the daemon running, the sweep already
                # started in the background — join its ledger; a sweep
                # that cannot converge in time (or no daemon) falls
                # back to an inline repair, because continuing on
                # single copies would break the durability promise.
                if daemon is None or \
                        not daemon.wait_for([victim], timeout=60.0):
                    cluster.tiered.repair([victim])
                params = jax.tree.map(jax.numpy.asarray,
                                      restored["params"])
                opt_state = jax.tree.map(jax.numpy.asarray,
                                         restored["opt"])
                state.recovered_at.append(step + 1)
                fault_at = None
    finally:
        if daemon is not None:
            cluster.stop_repair_daemon()
    # clean shutdown: strict barrier — a run whose checkpoints silently
    # all failed must not report success
    cluster.tiered.join()
    cluster.checkpointer.wait_async()
    if last_ticket is not None:
        # after the barrier this reflects the PERSISTED ack map
        state.final_ckpt_durability = last_ticket.durability()
    return state
