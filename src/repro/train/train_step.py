"""Sharded train step: chunked vocab-parallel CE, microbatch grad
accumulation, remat, AdamW(+ZeRO) update.

Cross-entropy never materializes [B, S, V] logits: a rematted scan over
sequence chunks computes logits for `ce_chunk` positions at a time against
the vocab-sharded unembedding, with the log-sum-exp reduced across the
vocab shards by GSPMD. Padded vocab columns are masked to -inf.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import transformer as tfm
from repro.models.layers import softcap
from repro.train import optimizer as opt

Params = Dict[str, Any]

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def chunked_ce_loss(hidden: jax.Array, out_embed: jax.Array,
                    labels: jax.Array, mask: jax.Array, cfg: ModelConfig,
                    constrain: Callable, chunk: int = 512
                    ) -> Tuple[jax.Array, jax.Array]:
    """hidden [B,S,D]; labels/mask [B,S]. Returns (sum_nll, sum_mask)."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    v = cfg.vocab_size
    vp = out_embed.shape[-1]
    vocab_valid = jnp.arange(vp) < v

    hs = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        h, lbl, msk = xs
        logits = jnp.einsum("bsd,dv->bsv", h, out_embed)
        logits = constrain(logits, "logits")
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
        logits = jnp.where(vocab_valid, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lbl_logit = jnp.take_along_axis(
            logits, lbl[..., None], axis=-1)[..., 0]
        nll = (lse - lbl_logit) * msk
        return (carry[0] + nll.sum(), carry[1] + msk.sum()), None

    (nll_sum, msk_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ls, ms))
    return nll_sum, msk_sum


def make_loss_fn(cfg: ModelConfig, rt: tfm.ModelRuntime,
                 constrain: Callable, ce_chunk: int = 512):
    def loss_fn(params: Params, batch: Dict[str, jax.Array]):
        hidden, _, aux = tfm.forward(
            params, cfg, rt, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            enc_frames=batch.get("enc_frames"))
        nll_sum, msk_sum = chunked_ce_loss(
            hidden, params["out_embed"], batch["labels"], batch["loss_mask"],
            cfg, constrain, ce_chunk)
        loss = nll_sum / jnp.maximum(msk_sum, 1.0) + AUX_WEIGHT * aux
        return loss, {"nll": nll_sum, "ntok": msk_sum, "aux": aux}

    return loss_fn


def make_train_step(cfg: ModelConfig, rt: tfm.ModelRuntime,
                    constrain: Callable, adamw: opt.AdamWConfig,
                    microbatches: int = 1, ce_chunk: int = 512,
                    grad_shardings=None, accum_dtype=jnp.float32):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). batch dims are [global_batch, ...].

    grad_shardings (optional): NamedSharding tree for the f32 gradient
    accumulator — pass the ZeRO (data-sharded) shardings so the accumulator
    is reduce-scattered across DP instead of replicated (ZeRO-2).
    """
    loss_fn = make_loss_fn(cfg, rt, constrain, ce_chunk)
    grad_fn = jax.grad(loss_fn, has_aux=True)

    def shard_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            g, grad_shardings)

    def train_step(params: Params, opt_state: Params,
                   batch: Dict[str, jax.Array]):
        if microbatches == 1:
            grads, metrics = grad_fn(params, batch)
            grads = shard_grads(grads)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                g_acc, m_acc = carry
                g, m = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(accum_dtype), g_acc, g)
                g_acc = shard_grads(g_acc)
                m_acc = jax.tree.map(lambda a, b_: a + b_, m_acc, m)
                return (g_acc, m_acc), None

            g0 = shard_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))
            m0 = {"nll": jnp.zeros((), jnp.float32),
                  "ntok": jnp.zeros((), jnp.float32),
                  "aux": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(acc_step, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        new_params, new_state, gnorm = opt.apply_updates(
            params, grads, opt_state, adamw)
        loss = metrics["nll"] / jnp.maximum(metrics["ntok"], 1.0)
        out_metrics = {"loss": loss, "grad_norm": gnorm,
                       "aux": metrics["aux"],
                       "step": new_state["step"].astype(jnp.float32)}
        return new_params, new_state, out_metrics

    return train_step
