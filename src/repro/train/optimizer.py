"""AdamW with ZeRO-1 moment sharding and optional int8 blockwise moments.

ZeRO-1: moments carry the param's TP sharding *plus* a data-parallel shard
on the first divisible replicated dim. GSPMD then slices gradients into the
moment shards, updates shard-locally, and all-gathers fresh params — the
classic optimizer-state sharding, expressed purely through shardings.

int8 moments (bitsandbytes-style blockwise absmax) cut optimizer state from
8 to ~2.25 bytes/param — required to fit grok-1/arctic optimizer state in
HBM (DESIGN.md §4); enabled via RunConfig.opt_moments_dtype == "int8".
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

QBLOCK = 256  # small block so padded tails stay cheap


# ---------------------------------------------------------------------------
# int8 blockwise moment codec
# ---------------------------------------------------------------------------

def _kept_dims(shape) -> int:
    """Flatten only the trailing (unsharded) dims into quantization blocks
    — sharded dims (layer stacks / expert slots, always leading) stay
    intact so quantize/dequantize never crosses shard boundaries (a global
    reshape of a sharded array forces an all-gather every step)."""
    return max(len(shape) - 2, 0)


def _to_blocks(x: jax.Array):
    k = _kept_dims(x.shape)
    lead = x.shape[:k]
    flat = x.reshape(lead + (-1,))
    pad = (-flat.shape[-1]) % QBLOCK
    if pad:
        widths = [(0, 0)] * len(lead) + [(0, pad)]
        flat = jnp.pad(flat, widths)
    return flat.reshape(lead + (-1, QBLOCK))


def _from_blocks(xb: jax.Array, shape) -> jax.Array:
    k = _kept_dims(shape)
    lead = shape[:k]
    n = 1
    for s in shape[k:]:
        n *= s
    flat = xb.reshape(lead + (-1,))[..., :n]
    return flat.reshape(shape)


def _q8_encode(x: jax.Array) -> Dict[str, jax.Array]:
    """Signed blockwise absmax int8 (first moment: mild dynamic range)."""
    xb = _to_blocks(x)
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-20)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)[..., 0]}


def _q8_decode(st: Dict[str, jax.Array], shape) -> jax.Array:
    xb = st["q"].astype(jnp.float32) * st["scale"][..., None]
    return _from_blocks(xb, shape)


def _q8v_encode(v: jax.Array) -> Dict[str, jax.Array]:
    """Log-space asymmetric int8 for the second moment: v spans orders of
    magnitude (it is g^2-shaped), so linear absmax would zero small
    entries and explode 1/sqrt(v) updates — quantize log2(v) instead
    (multiplicative error ~= 2^(range/255))."""
    xb = _to_blocks(v)
    lv = jnp.log2(jnp.clip(xb, 1e-30, None))
    lo = lv.min(axis=-1, keepdims=True)
    rng = jnp.maximum(lv.max(axis=-1, keepdims=True) - lo, 1e-6)
    q = jnp.clip(jnp.round((lv - lo) / rng * 255.0) - 128, -128,
                 127).astype(jnp.int8)
    return {"q": q, "lo": lo.astype(jnp.float32)[..., 0],
            "rng": rng.astype(jnp.float32)[..., 0]}


def _q8v_decode(st: Dict[str, jax.Array], shape) -> jax.Array:
    t = (st["q"].astype(jnp.float32) + 128.0) / 255.0
    lv = st["lo"][..., None] + t * st["rng"][..., None]
    v = jnp.exp2(lv)
    v = jnp.where(v <= 2e-30, 0.0, v)
    return _from_blocks(v, shape)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "float32"   # float32 | int8
    warmup: int = 100


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup, 1), 1.0)
    return cfg.lr * warm


def init_opt_state(params: Params, cfg: AdamWConfig) -> Params:
    def one(p):
        if cfg.moments_dtype == "int8":
            z = jnp.zeros(p.shape, jnp.float32)
            return {"m": _q8_encode(z), "v": _q8v_encode(z)}
        return {"m": jnp.zeros(p.shape, jnp.float32),
                "v": jnp.zeros(p.shape, jnp.float32)}
    moments = jax.tree.map(one, params)
    return {"moments": moments, "step": jnp.zeros((), jnp.int32)}


def opt_state_specs(param_specs, cfg: AdamWConfig):
    """Logical-axes tree for the optimizer state (ZeRO handled at the
    mesh-mapping layer via 'zero' pseudo-axis on the q/scale blocks)."""
    def one(spec):
        if cfg.moments_dtype == "int8":
            # blocks keep the param's (sharded) leading dims
            lead = tuple(spec[:max(len(spec) - 2, 0)])
            return {"m": {"q": lead + (None, None),
                          "scale": lead + (None,)},
                    "v": {"q": lead + (None, None), "lo": lead + (None,),
                          "rng": lead + (None,)}}
        return {"m": tuple(spec), "v": tuple(spec)}
    moments = jax.tree.map(one, param_specs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return {"moments": moments, "step": ()}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params: Params, grads: Params, state: Params,
                  cfg: AdamWConfig) -> Tuple[Params, Params, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.clip_norm > 0 else 1.0
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def one(p, g, mo):
        g = g.astype(jnp.float32) * scale
        if cfg.moments_dtype == "int8":
            m = _q8_decode(mo["m"], p.shape)
            v = _q8v_decode(mo["v"], p.shape)
        else:
            m, v = mo["m"], mo["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if cfg.moments_dtype == "int8":
            return newp, {"m": _q8_encode(m), "v": _q8v_encode(v)}
        return newp, {"m": m, "v": v}

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mo = tdef.flatten_up_to(state["moments"])
    out = [one(p, g, mo) for p, g, mo in zip(flat_p, flat_g, flat_mo)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_moments = tdef.unflatten([o[1] for o in out])
    return new_params, {"moments": new_moments, "step": step}, gnorm
