"""TelemetryPlane: the one handle the data plane talks to.

Owns the metrics ``Registry`` plus one ``FlightRecorder`` per node
pool. Core modules take ``obs=None`` kwargs; a None plane (or
``enabled=False``) degrades every call to a cheap no-op or a pure
in-DRAM metric update, so the library works stand-alone and the
overhead bench can compare telemetry on/off on the same code path.

Event routing: ``event``/``begin``/``end`` write to the named node's
ring when it is alive, falling back to the home (first) node's ring —
a dying node's last events land *somewhere* durable, which is the whole
point of a flight recorder. Metric snapshots are best-effort JSON
(``obs/metrics.json`` on every live pool, written at clean shutdown);
after a crash the rings are the source of truth and
``python -m repro.obs.report`` replays them.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

from repro.obs.metrics import Counter, Gauge, Histogram, Registry
from repro.obs.recorder import EVT_BEGIN, EVT_END, EVT_POINT, \
    FlightRecorder
from repro.obs.trace import Span, new_id

SNAPSHOT_NAME = "obs/metrics.json"


class TelemetryPlane:
    def __init__(self, pools: Optional[Dict[str, Any]] = None, *,
                 enabled: bool = True,
                 registry: Optional[Registry] = None,
                 slots: Optional[int] = None,
                 slot_bytes: Optional[int] = None):
        self.enabled = enabled
        self.registry = registry if registry is not None else Registry()
        self.recorders: Dict[str, FlightRecorder] = {}
        self._home: Optional[str] = None
        self._ring_kw = {}
        if slots is not None:
            self._ring_kw["slots"] = slots
        if slot_bytes is not None:
            self._ring_kw["slot_bytes"] = slot_bytes
        if pools and enabled:
            for nid in sorted(pools):
                self.attach(nid, pools[nid])

    def attach(self, nid: str, pool) -> None:
        """Create/open the node's flight-recorder ring."""
        self.recorders[nid] = FlightRecorder(pool, **self._ring_kw)
        if self._home is None:
            self._home = nid

    # ---- registry passthrough ---------------------------------------
    def counter(self, name: str) -> Counter:
        return self.registry.counter(name)

    def gauge(self, name: str) -> Gauge:
        return self.registry.gauge(name)

    def histogram(self, name: str) -> Histogram:
        return self.registry.histogram(name)

    # ---- flight-recorder events -------------------------------------
    def _recorder(self, node: Optional[str]) -> Optional[FlightRecorder]:
        if not self.recorders:
            return None
        rec = self.recorders.get(node) if node is not None else None
        if rec is None and self._home is not None:
            rec = self.recorders.get(self._home)
        return rec

    def event(self, name: str, *, node: Optional[str] = None,
              trace: int = 0, span: int = 0, parent: int = 0,
              **attrs) -> None:
        """Point event on the node's ring (no-op when disabled)."""
        if not self.enabled:
            return
        rec = self._recorder(node)
        if rec is not None:
            ok = rec.record(EVT_POINT, name, trace=trace, span=span,
                            parent=parent, attrs=attrs or None)
            if not ok and node is not None and node != self._home:
                home = self._recorder(None)
                if home is not None:
                    home.record(EVT_POINT, name, trace=trace, span=span,
                                parent=parent, attrs=attrs or None)

    def begin(self, name: str, *, node: Optional[str] = None,
              trace: Optional[int] = None, parent: int = 0,
              **attrs) -> Span:
        """Open a span (always returns a handle, even when disabled —
        callers pass it straight back to ``end``)."""
        sp = Span(name=name, trace=trace or new_id(), span=new_id(),
                  parent=parent, node=node, t0=time.time())
        if self.enabled:
            rec = self._recorder(node)
            if rec is not None:
                rec.record(EVT_BEGIN, name, ts=sp.t0, trace=sp.trace,
                           span=sp.span, parent=parent,
                           attrs=attrs or None)
        return sp

    def end(self, span: Optional[Span], *, status: str = "ok",
            **attrs) -> None:
        if span is None:
            return
        t1 = time.time()
        self.registry.histogram(f"span.{span.name}.s") \
            .observe(t1 - span.t0)
        if self.enabled:
            rec = self._recorder(span.node)
            if rec is not None:
                out = {"status": status}
                out.update(attrs)
                rec.record(EVT_END, span.name, ts=t1, trace=span.trace,
                           span=span.span, parent=span.parent,
                           attrs=out)

    # ---- snapshots --------------------------------------------------
    def snapshot(self) -> dict:
        snap = self.registry.snapshot()
        snap["ts"] = time.time()
        snap["recorder"] = {
            nid: {"committed": rec.committed, "drops": rec.drops}
            for nid, rec in sorted(self.recorders.items())}
        return snap

    def persist_snapshot(self) -> int:
        """Write the metrics snapshot to every live pool (clean
        shutdown only — after a crash the rings tell the story).
        Returns the number of pools that took it."""
        snap = self.snapshot()
        wrote = 0
        for rec in self.recorders.values():
            try:
                rec.pool.put_json(SNAPSHOT_NAME, snap)
            except (IOError, OSError):
                continue  # dead pool: the survivors carry the snapshot
            wrote += 1
        return wrote
