"""Pmem-native telemetry plane.

Three layers (ISSUE 8 / ROADMAP "Telemetry plane"):

  * ``metrics``  — process-local registry: counters, gauges,
    bounded-memory histograms; ``StatsView`` read-through aliases keep
    the legacy dict-shaped stats surfaces alive.
  * ``trace``    — correlation IDs + span trees reconstructed from
    recorder events.
  * ``recorder`` — crash-persistent per-node pmem flight recorder
    (fixed-slot ring under MetaLog's committed-tail discipline).

``plane.TelemetryPlane`` ties them together; ``report`` is the
post-crash replay CLI (``python -m repro.obs.report``).
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               Registry, StatsView)
from repro.obs.plane import TelemetryPlane  # noqa: F401
from repro.obs.recorder import FlightRecorder  # noqa: F401
from repro.obs.trace import Span, build_traces, ctx, new_id  # noqa: F401
