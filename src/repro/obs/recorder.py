"""Crash-persistent flight recorder: a fixed-slot pmem ring buffer.

Each node owns one ring (``obs/flightring`` in its PMemPool). Events
are fixed-size binary slots written through ``PMemRegion`` byte-range
writes under the SAME committed-tail discipline ``MetaLog`` uses:

    slot bytes -> flush -> committed TAIL -> flush

so a crash can tear at most the not-yet-committed slot, which replay
never reads. The committed tail is stored as a *virtual byte offset*
(``HDR_SIZE + events_committed * slot_bytes``, monotone, never reduced
modulo the ring) — the persistence-order sanitizer can therefore apply
its MetaLog tail check verbatim: any slot write left unflushed when the
tail advances past it is a violation. Replay decodes the last
``min(committed, slots)`` events; a CRC guards each slot against media
damage, and ring wrap-around simply drops the oldest events.

Telemetry must never take down the data plane: a dead pool (or any
I/O error) turns ``record`` into a counted drop, not an exception.
"""
from __future__ import annotations

import json
import struct
import threading
import time
import zlib
from typing import Any, Dict, List, Optional

import numpy as np

from repro.obs.trace import EVT_BEGIN, EVT_END, EVT_POINT  # noqa: F401

_MAGIC = b"OBSR1\x00"
_VERSION = 1
# magic | version | committed TAIL (virtual byte offset) | slots |
# slot_bytes | epoch  — tail lives at byte 8, like MetaLog's, so the
# runtime sanitizer's committed-tail check covers the ring too.
_HDR = struct.Struct("<6sHQQQQ")
_TAIL_OFF = 8
HDR_SIZE = 64

# Per-slot event header:
# crc32 | seq | ts | trace | span | parent | kind | name_len | attrs_len
_EVT = struct.Struct("<IQdQQQBBH")

DEFAULT_SLOTS = 2048
DEFAULT_SLOT_BYTES = 192


def _u64le(v: int) -> np.ndarray:
    return np.frombuffer(struct.pack("<Q", v), dtype=np.uint8)


class FlightRecorder:
    """Per-node pmem event ring (see module docstring for the layout).

    ``record`` is safe from any thread; the internal lock serializes
    slot allocation and the two-write commit sequence.
    """

    def __init__(self, pool, name: str = "obs/flightring", *,
                 slots: int = DEFAULT_SLOTS,
                 slot_bytes: int = DEFAULT_SLOT_BYTES):
        self.pool = pool
        self.name = name
        self._lock = threading.Lock()
        self.drops = 0
        region = pool.open_or_create(
            name, HDR_SIZE + slots * slot_bytes)
        raw = bytes(region.read(0, _HDR.size))
        magic, ver, tail, h_slots, h_slot_bytes, _epoch = \
            _HDR.unpack(raw)
        if magic == _MAGIC and ver == _VERSION and h_slots:
            # adopt the on-pmem geometry + committed count (reopen
            # after restart: the ring keeps appending where it left off)
            self.slots = int(h_slots)
            self.slot_bytes = int(h_slot_bytes)
            self._seq = max(0, (int(tail) - HDR_SIZE)) // \
                self.slot_bytes
        else:
            self.slots = slots
            self.slot_bytes = slot_bytes
            self._seq = 0
            hdr = _HDR.pack(_MAGIC, _VERSION, HDR_SIZE, slots,
                            slot_bytes, int(time.time()))
            region.write(0, np.frombuffer(hdr.ljust(HDR_SIZE, b"\0"),
                                          dtype=np.uint8))
            region.flush()

    @property
    def committed(self) -> int:
        with self._lock:
            return self._seq

    def record(self, kind: int, name: str, *, ts: Optional[float] = None,
               trace: int = 0, span: int = 0, parent: int = 0,
               attrs: Optional[Dict[str, Any]] = None) -> bool:
        """Append one event; False means it was dropped (dead pool /
        I/O error), with ``self.drops`` incremented."""
        ts = time.time() if ts is None else ts
        nb = name.encode("utf-8")[:64]
        ab = b""
        if attrs:
            ab = json.dumps(attrs, separators=(",", ":"),
                            default=str).encode("utf-8")
        room = self.slot_bytes - _EVT.size - len(nb)
        if len(ab) > room:
            ab = b""  # attrs don't fit the slot: keep the event itself
        with self._lock:
            seq = self._seq
            body = _EVT.pack(0, seq, ts, trace, span, parent, kind,
                             len(nb), len(ab))[4:] + nb + ab
            blob = struct.pack("<I", zlib.crc32(body)) + body
            off = HDR_SIZE + (seq % self.slots) * self.slot_bytes
            new_tail = HDR_SIZE + (seq + 1) * self.slot_bytes
            try:
                region = self.pool.open(self.name)
                # B-APM ring discipline (same as MetaLog._append_pool):
                # slot bytes -> flush -> committed TAIL -> flush. A
                # crash between the flushes loses only this event.
                region.write(off, np.frombuffer(blob, dtype=np.uint8))
                region.flush()
                region.write(_TAIL_OFF, _u64le(new_tail))
                region.flush()
            except (IOError, OSError, ValueError):
                self.drops += 1
                return False
            self._seq = seq + 1
            return True

    # ---- replay (post-crash or live) --------------------------------
    @classmethod
    def replay(cls, pool, name: str = "obs/flightring") -> List[dict]:
        """Decode the committed events still in the ring, oldest first.

        Works on any pool a crash left behind: only slots below the
        committed tail are read, so a torn (pre-commit) slot write is
        invisible; CRC-corrupt slots (media damage) are skipped.
        """
        try:
            if not pool.exists(name):
                return []
            region = pool.open(name)
            raw = bytes(region.read(0, _HDR.size))
        except (IOError, OSError):
            return []
        magic, ver, tail, slots, slot_bytes, _epoch = _HDR.unpack(raw)
        if magic != _MAGIC or ver != _VERSION or not slots \
                or not slot_bytes:
            return []
        committed = max(0, (int(tail) - HDR_SIZE)) // int(slot_bytes)
        lo = max(0, committed - int(slots))
        events: List[dict] = []
        for seq in range(lo, committed):
            off = HDR_SIZE + (seq % int(slots)) * int(slot_bytes)
            try:
                blob = bytes(region.read(off, int(slot_bytes)))
            except (IOError, OSError, ValueError):
                continue
            crc = struct.unpack_from("<I", blob)[0]
            (_, eseq, ts, trace, span, parent, kind, nlen,
             alen) = _EVT.unpack_from(blob)
            end = _EVT.size + nlen + alen
            if eseq != seq or end > int(slot_bytes):
                continue  # stale or damaged slot
            if zlib.crc32(blob[4:end]) != crc:
                continue  # media damage: CRC is authoritative
            attrs: Dict[str, Any] = {}
            if alen:
                try:
                    attrs = json.loads(
                        blob[_EVT.size + nlen:end].decode("utf-8"))
                except (ValueError, UnicodeDecodeError):
                    attrs = {}
            events.append({
                "seq": seq, "ts": ts, "kind": kind,
                "name": blob[_EVT.size:_EVT.size + nlen]
                .decode("utf-8", "replace"),
                "trace": trace, "span": span, "parent": parent,
                "attrs": attrs})
        return events
