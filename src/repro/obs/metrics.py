"""Metrics registry: counters, gauges, bounded-memory histograms.

The process-local half of the telemetry plane (the crash-persistent
half is ``repro.obs.recorder``). Three design rules:

  * every instrument is internally locked, so hot paths (channel worker
    threads, the scheduler's per-node workers, the read pool) update
    them without taking any caller lock — this is what retires the old
    unguarded ``TieredIO.stats["..."] += n`` pattern that pmemlint's
    lockset rule would flag;
  * histograms are fixed-size geometric bucket ladders (64 buckets,
    ratio 2), so memory is bounded no matter how many observations the
    recorder sees — the B-APM telemetry-retention scenario needs
    instruments that never grow;
  * ``StatsView`` wraps a dict of counters in a read-through Mapping so
    legacy surfaces (``TieredIO.stats``, ``DataScheduler.stats``,
    ``last_restore_stats``) keep their dict-shaped API (indexing,
    equality with plain dicts, ``dict(view)``) while the values live in
    the registry.
"""
from __future__ import annotations

import threading
from collections.abc import Mapping
from typing import Dict, Iterator, List, Optional

# One geometric ladder for every histogram: 1e-7 * 2^i, i in [0, 64).
# Covers sub-microsecond latencies up to ~9e11 (also fine for byte
# sizes); fixed width keeps memory bounded.
_H_LO = 1e-7
_H_BUCKETS = 64


class Counter:
    """Monotonic (plus explicit ``set`` for resettable views)."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._v += n

    def set(self, v: int) -> None:
        with self._lock:
            self._v = v

    @property
    def value(self) -> int:
        with self._lock:
            return self._v


class Gauge:
    """Instantaneous level (queue depth, inflight saves, used bytes)."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = v

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._v += n

    def dec(self, n: float = 1) -> None:
        with self._lock:
            self._v -= n

    @property
    def value(self) -> float:
        with self._lock:
            return self._v


class Histogram:
    """Geometric-bucket latency/size histogram, O(1) memory."""

    __slots__ = ("name", "_counts", "_count", "_sum", "_min", "_max",
                 "_lock")

    def __init__(self, name: str):
        self.name = name
        self._counts = [0] * _H_BUCKETS
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = 0.0
        self._lock = threading.Lock()

    @staticmethod
    def _bucket(v: float) -> int:
        if v <= _H_LO:
            return 0
        b = 0
        x = _H_LO
        while x < v and b < _H_BUCKETS - 1:
            x *= 2.0
            b += 1
        return b

    def observe(self, v: float) -> None:
        v = max(0.0, float(v))
        b = self._bucket(v)
        with self._lock:
            self._counts[b] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, q: float) -> float:
        """Approximate: upper edge of the bucket holding quantile q."""
        with self._lock:
            if not self._count:
                return 0.0
            target = q * self._count
            seen = 0
            for b, n in enumerate(self._counts):
                seen += n
                if seen >= target:
                    return min(self._max, _H_LO * (2.0 ** b))
            return self._max

    def summary(self) -> Dict[str, float]:
        with self._lock:
            if not self._count:
                return {"count": 0, "sum": 0.0}
            lo, hi, cnt, tot = self._min, self._max, self._count, \
                self._sum
        return {"count": cnt, "sum": tot, "min": lo, "max": hi,
                "mean": tot / cnt, "p50": self.percentile(0.50),
                "p95": self.percentile(0.95),
                "p99": self.percentile(0.99)}


class Registry:
    """Create-or-get instrument index; one per TelemetryPlane."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name)
            return h

    def snapshot(self) -> dict:
        """Plain-JSON view of every instrument (for ``obs/metrics.json``
        and ``BENCH_obs.json`` artifacts)."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        return {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(hists.items())},
        }


class StatsView(Mapping):
    """Dict-shaped read-through alias over ``{key: Counter}``.

    ``view["saves"]`` reads the counter, ``view["saves"] = 3`` sets it,
    ``view == {"saves": 3}`` and ``dict(view)`` behave like the plain
    dicts these views replaced — existing tests and benchmarks keep
    working unchanged.
    """

    __slots__ = ("_c",)

    def __init__(self, counters: Dict[str, Counter]):
        self._c = counters

    def __getitem__(self, k: str) -> int:
        return self._c[k].value

    def __setitem__(self, k: str, v: int) -> None:
        self._c[k].set(v)

    def __iter__(self) -> Iterator[str]:
        return iter(self._c)

    def __len__(self) -> int:
        return len(self._c)

    def __eq__(self, other) -> bool:
        if isinstance(other, Mapping):
            return dict(self) == dict(other)
        return NotImplemented

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return NotImplemented if eq is NotImplemented else not eq

    def __repr__(self) -> str:
        return f"StatsView({dict(self)!r})"
