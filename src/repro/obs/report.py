"""Post-crash flight-recorder replay CLI.

    python -m repro.obs.report <pmem-root> [--trace HEX] [--json]

``<pmem-root>`` is the cluster's pmem directory (one subdirectory per
node — ``SimCluster`` uses ``<root>/pmem``); a single node directory
works too. Every surviving node's ``obs/flightring`` is replayed
through the sanctioned ``PMemRegion`` read path, the events are merged
into causally-ordered per-trace span timelines, and the most recent
``obs/metrics.json`` snapshot (written at clean shutdown) is dumped if
one survived. After a crash there is no snapshot — the rings themselves
are the diagnosis, including each node's last pre-crash event.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.core.pmem import PMemPool
from repro.obs.plane import SNAPSHOT_NAME
from repro.obs.recorder import EVT_BEGIN, EVT_END, FlightRecorder
from repro.obs.trace import build_traces

_KIND_MARK = {EVT_BEGIN: ">", EVT_END: "<"}


def load_events(root: Path) -> Tuple[List[dict], Optional[dict]]:
    """Replay every node ring under ``root`` (the cluster pmem dir, one
    subdirectory per node); returns (events tagged with their node,
    newest surviving metrics snapshot or None)."""
    events: List[dict] = []
    snapshot: Optional[dict] = None
    snap_ts = -1.0
    if not root.is_dir():
        return events, snapshot
    for sub in sorted(p for p in root.iterdir() if p.is_dir()):
        nid = sub.name
        pool = PMemPool(root, nid)
        for ev in FlightRecorder.replay(pool):
            ev["node"] = nid
            events.append(ev)
        try:
            snap = pool.get_json(SNAPSHOT_NAME)
        except (IOError, OSError, KeyError, ValueError):
            snap = None
        if isinstance(snap, dict):
            ts = float(snap.get("ts", 0.0))
            if ts >= snap_ts:
                snapshot, snap_ts = snap, ts
    return events, snapshot


def _trace_t0(tr: dict) -> float:
    """Earliest timestamp seen in a trace (sort key for the report)."""
    times = [sp["t0"] or sp["t1"] or 0.0 for sp in tr["spans"].values()]
    times += [ev["ts"] for ev in tr["points"]]
    return min(times) if times else 0.0


def _fmt_ts(ts: float) -> str:
    return time.strftime("%H:%M:%S", time.localtime(ts)) + \
        f".{int((ts % 1) * 1e6):06d}"


def _fmt_event(ev: dict) -> str:
    mark = _KIND_MARK.get(ev["kind"], ".")
    ids = ""
    if ev["span"] or ev["parent"]:
        ids = f" span={ev['span']:x}"
        if ev["parent"]:
            ids += f" parent={ev['parent']:x}"
    attrs = ""
    if ev["attrs"]:
        attrs = " " + ",".join(f"{k}={v}"
                               for k, v in sorted(ev["attrs"].items()))
    return (f"  {_fmt_ts(ev['ts'])} {ev['node']:>8} {mark} "
            f"{ev['name']}{ids}{attrs}")


def render(events: List[dict], snapshot: Optional[dict],
           only_trace: Optional[int] = None) -> str:
    out: List[str] = []
    traces = build_traces(events)
    nodes = sorted({ev["node"] for ev in events})
    out.append(f"flight recorder: {len(events)} events from "
               f"{len(nodes)} ring(s) {nodes}")
    for tid in sorted(traces, key=lambda t: _trace_t0(traces[t])):
        if only_trace is not None and tid != only_trace:
            continue
        tr = traces[tid]
        tevents = [ev for ev in events if ev["trace"] == tid]
        tevents.sort(key=lambda e: (e["ts"], e["seq"]))
        label = f"trace {tid:x}" if tid else "untraced events"
        roots = [tr["spans"][r]["name"] for r in tr["roots"]]
        out.append("")
        out.append(f"{label}  spans={len(tr['spans'])} "
                   f"roots={roots}")
        for ev in tevents:
            out.append(_fmt_event(ev))
    # per-node last pre-crash event: the line a post-mortem reads first
    out.append("")
    out.append("last event per ring:")
    for nid in nodes:
        last = max((ev for ev in events if ev["node"] == nid),
                   key=lambda e: e["seq"])
        out.append(_fmt_event(last))
    if snapshot is not None:
        out.append("")
        out.append("metrics snapshot (clean-shutdown survivor):")
        out.append(json.dumps(snapshot, indent=2, sort_keys=True,
                              default=str))
    else:
        out.append("")
        out.append("no metrics snapshot found (crash before clean "
                   "shutdown — the rings above are the record)")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="replay crash-persistent flight-recorder rings "
                    "into a causally-ordered timeline")
    ap.add_argument("root", help="cluster pmem directory "
                                 "(one subdir per node)")
    ap.add_argument("--trace", default=None,
                    help="only show this trace id (hex)")
    ap.add_argument("--json", action="store_true",
                    help="dump raw events as JSON instead of a "
                         "timeline")
    args = ap.parse_args(argv)
    events, snapshot = load_events(Path(args.root))
    if args.json:
        print(json.dumps({"events": events, "snapshot": snapshot},
                         indent=2, default=str))
        return 0
    if not events:
        print(f"no flight-recorder events under {args.root}")
        return 1
    only = int(args.trace, 16) if args.trace else None
    print(render(events, snapshot, only))
    return 0


if __name__ == "__main__":
    sys.exit(main())
