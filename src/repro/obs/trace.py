"""Trace spans and correlation IDs for the data plane.

A *trace* is one end-to-end lifecycle (a ``save_async`` replicate →
drain → ack, a repair sweep's scan → copy → re-ack, one workflow run).
A *span* is one timed operation inside it. IDs are 63-bit random ints
(JSON-safe, nonzero); 0 means "untraced". Spans carry no global state —
the context is threaded explicitly through scheduler ``span=`` kwargs,
checkpoint manifests and ack-record info dicts, so correlation survives
thread hops and, via the flight recorder, crashes.

``build_traces`` reconstructs span trees from recorder events — shared
by ``repro.obs.report`` and the trace-propagation tests.
"""
from __future__ import annotations

import os
import struct
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

# Flight-recorder event kinds (also the replay wire values).
EVT_POINT = 0
EVT_BEGIN = 1
EVT_END = 2


def new_id() -> int:
    """63-bit nonzero random correlation id."""
    while True:
        v = struct.unpack("<Q", os.urandom(8))[0] >> 1
        if v:
            return v


@dataclass
class Span:
    """A live span handle (ended via ``TelemetryPlane.end``)."""
    name: str
    trace: int
    span: int
    parent: int = 0
    node: Optional[str] = None
    t0: float = 0.0


def ctx(span: Optional[Span]) -> Optional[dict]:
    """Propagation context for scheduler ``span=`` kwargs / manifests."""
    if span is None:
        return None
    return {"trace": span.trace, "span": span.span}


def build_traces(events: Iterable[dict]) -> Dict[int, dict]:
    """Group replayed recorder events into per-trace span trees.

    Returns ``{trace_id: {"spans": {span_id: {...}}, "roots": [...],
    "points": [...]}}``. A span whose BEGIN was overwritten by ring
    wrap-around is synthesized from its END so the tree stays
    connected. Trace 0 collects untraced events.
    """
    traces: Dict[int, dict] = {}
    for ev in sorted(events, key=lambda e: (e["ts"], e.get("seq", 0))):
        tr = traces.setdefault(ev["trace"],
                               {"spans": {}, "points": [], "roots": []})
        spans = tr["spans"]
        if ev["kind"] == EVT_BEGIN:
            spans[ev["span"]] = {
                "name": ev["name"], "parent": ev["parent"],
                "node": ev.get("node"), "t0": ev["ts"], "t1": None,
                "status": None, "attrs": dict(ev.get("attrs") or {}),
                "events": []}
        elif ev["kind"] == EVT_END:
            sp = spans.get(ev["span"])
            if sp is None:
                sp = spans[ev["span"]] = {
                    "name": ev["name"], "parent": ev["parent"],
                    "node": ev.get("node"), "t0": None, "t1": None,
                    "status": None, "attrs": {}, "events": []}
            sp["t1"] = ev["ts"]
            attrs = dict(ev.get("attrs") or {})
            sp["status"] = attrs.pop("status", "ok")
            sp["attrs"].update(attrs)
        else:
            tr["points"].append(ev)
            sp = spans.get(ev["span"]) or spans.get(ev["parent"])
            if sp is not None:
                sp["events"].append(ev)
    for tr in traces.values():
        spans = tr["spans"]
        tr["roots"] = sorted(sid for sid, sp in spans.items()
                             if sp["parent"] not in spans)
    return traces


def connected_to_root(trace: dict, span_id: int) -> bool:
    """True if ``span_id`` reaches a root span via parent links."""
    spans = trace["spans"]
    seen = set()
    cur = span_id
    while cur in spans and cur not in seen:
        seen.add(cur)
        parent = spans[cur]["parent"]
        if parent not in spans:
            return cur in trace["roots"]
        cur = parent
    return False


def span_names(trace: dict) -> List[str]:
    return sorted({sp["name"] for sp in trace["spans"].values()})
