"""Training-data pipeline with pmem staging (the paper's burst-buffer path).

Shards of tokenized data live in the external store; the data scheduler
stages upcoming shards into node-local pmem ahead of consumption
(prefetch depth configurable) so the training loop reads at B-APM speed,
never at external-filesystem speed. A synthetic corpus generator provides
deterministic data for tests/examples.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.cluster import SimCluster


def synthetic_shard(seed: int, n_seqs: int, seq_len: int,
                    vocab: int) -> Dict[str, np.ndarray]:
    """Deterministic synthetic LM data (zipf-ish token distribution)."""
    rng = np.random.default_rng(seed)
    ranks = rng.zipf(1.3, size=(n_seqs, seq_len + 1)).astype(np.int64)
    tokens = (ranks % vocab).astype(np.int32)
    return {"tokens": tokens}


def make_batch(shard: Dict[str, np.ndarray], cfg: ModelConfig,
               shape: ShapeConfig, rng: np.random.Generator
               ) -> Dict[str, np.ndarray]:
    toks = shard["tokens"]
    idx = rng.integers(0, toks.shape[0], size=shape.global_batch)
    seqs = toks[idx, :shape.seq_len + 1]
    text_len = shape.seq_len - cfg.prefix_len
    batch = {
        "tokens": seqs[:, :text_len].astype(np.int32),
        "labels": np.concatenate(
            [seqs[:, 1:shape.seq_len + 1]], axis=1).astype(np.int32),
        "loss_mask": np.ones((shape.global_batch, shape.seq_len),
                             np.float32),
    }
    batch["loss_mask"][:, -1] = 0.0
    if cfg.prefix_len:
        batch["loss_mask"][:, :cfg.prefix_len] = 0.0
        batch["prefix_embeds"] = rng.standard_normal(
            (shape.global_batch, cfg.prefix_len, cfg.d_model)
        ).astype(np.float32) * 0.02
    if cfg.enc_dec:
        batch["enc_frames"] = rng.standard_normal(
            (shape.global_batch, shape.seq_len, cfg.d_model)
        ).astype(np.float32) * 0.02
    return batch


class StagedDataset:
    """Iterates batches; shards are staged into pmem ``prefetch`` ahead."""

    def __init__(self, cluster: SimCluster, cfg: ModelConfig,
                 shape: ShapeConfig, n_shards: int = 8,
                 seqs_per_shard: int = 64, prefetch: int = 2, seed: int = 0):
        self.cluster = cluster
        self.cfg = cfg
        self.shape = shape
        self.n_shards = n_shards
        self.prefetch = prefetch
        self.rng = np.random.default_rng(seed)
        self._futures: Dict[int, object] = {}
        # populate the external store (normally done by the data-prep job)
        for i in range(n_shards):
            name = f"data_shard_{i}"
            if not cluster.external.exists(name):
                cluster.external.put(name, synthetic_shard(
                    seed + i, seqs_per_shard, shape.seq_len,
                    cfg.vocab_size))

    def _node_for(self, i: int) -> str:
        # stable home node per shard; only shards whose home died are
        # re-targeted onto survivors — a node loss must not remap (and
        # force re-staging of) every shard already resident elsewhere
        ids = self.cluster.node_ids
        nid = ids[i % len(ids)]
        if getattr(self.cluster.pools[nid], "alive", True):
            return nid
        live = [n for n in ids
                if getattr(self.cluster.pools[n], "alive", True)]
        live = live or ids
        return live[i % len(live)]

    def _ensure_staged(self, i: int) -> None:
        i = i % self.n_shards
        nid = self._node_for(i)
        name = f"data_shard_{i}"
        if self.cluster.stores[nid].exists(name) or i in self._futures:
            return
        self._futures[i] = self.cluster.scheduler.stage_in(nid, name, name)

    def batches(self, steps: int) -> Iterator[Dict[str, np.ndarray]]:
        for step in range(steps):
            i = step % self.n_shards
            # prefetch upcoming shards (async, burst-buffer semantics)
            for ahead in range(self.prefetch + 1):
                self._ensure_staged(i + ahead)
            fut = self._futures.pop(i, None)
            if fut is not None:
                try:
                    fut.result()  # only blocks if prefetch fell behind
                except IOError:
                    pass  # target node died mid-stage; re-stage below
            name = f"data_shard_{i}"
            nid = self._node_for(i)
            if not self.cluster.stores[nid].exists(name):
                self.cluster.scheduler.stage_in(nid, name, name).result()
            shard = self.cluster.stores[nid].get(name)
            yield make_batch(shard, self.cfg, self.shape, self.rng)
