from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, DECODE, MLP_GEGLU,
                                MLP_GELU, MLP_MOE, MLP_NONE, MLP_SWIGLU,
                                PREFILL, RGLRU, SHAPES, SSD, TRAIN, LayerSpec,
                                ModelConfig, MoEConfig, ParallelConfig,
                                RGLRUConfig, RunConfig, ShapeConfig, SSMConfig)
from repro.configs.registry import (ARCH_IDS, Cell, cell_skip_reason, cells,
                                    get_config, get_smoke_config)
