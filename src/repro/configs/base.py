"""Configuration dataclasses for models, shapes, meshes and runs.

Every assigned architecture is expressed as a ``ModelConfig`` built out of a
repeating ``block pattern`` of (mixer, mlp) layer specs, which is what lets a
single transformer implementation cover dense / GQA / MoE / SSM / hybrid /
encoder-decoder families while still compiling to a compact scan-over-layers
HLO.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

# mixer kinds
ATTN_GLOBAL = "attn_global"      # full (causal for decoder) attention
ATTN_LOCAL = "attn_local"        # sliding-window attention
RGLRU = "rglru"                  # RG-LRU recurrent block (RecurrentGemma)
SSD = "ssd"                      # Mamba2 state-space-duality block

# mlp kinds
MLP_GELU = "gelu"                # plain 2-matmul MLP
MLP_SWIGLU = "swiglu"            # gated 3-matmul MLP (llama-style)
MLP_GEGLU = "geglu"              # gated with gelu (gemma-style)
MLP_MOE = "moe"                  # mixture-of-experts FFN
MLP_NONE = "none"                # no MLP (mamba2 blocks are mixer-only)


@dataclass(frozen=True)
class LayerSpec:
    mixer: str = ATTN_GLOBAL
    mlp: str = MLP_SWIGLU
    # MoE-with-parallel-dense-residual (snowflake-arctic style)
    dense_residual: bool = False


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    d_ff: int = 0                 # expert hidden size (0 -> ModelConfig.d_ff)
    router_softcap: float = 30.0  # grok-style router logit cap (0 = off)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64            # P
    n_groups: int = 1             # B/C groups
    conv_width: int = 4
    chunk_size: int = 256
    expand: int = 2               # d_inner = expand * d_model


@dataclass(frozen=True)
class RGLRUConfig:
    width: int = 0                # recurrent width (0 -> d_model)
    conv_width: int = 4
    block_width: int = 256        # kernel scan block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    # attention details
    window: int = 4096            # sliding window for ATTN_LOCAL
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    linear_bias: bool = False     # biases on all projections (starcoder2/whisper)
    attn_softcap: float = 0.0     # gemma2: 50.0
    final_softcap: float = 0.0    # gemma2: 30.0
    post_norms: bool = False      # gemma2 sandwich norms
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    # sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    # multimodal prefix stub (vlm / audio frontends)
    prefix_len: int = 0           # precomputed embeddings prepended to tokens
    # numerics
    param_dtype: str = "bfloat16"
    # vocab padding granularity for TP
    vocab_pad_to: int = 256
    # whether long_500k applies (sub-quadratic decoders only)
    subquadratic: bool = False
    tie_embeddings: bool = False  # documented deviation: we always untie

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        g = self.vocab_pad_to
        return (self.vocab_size + g - 1) // g * g

    def padded_heads(self, model_par: int) -> int:
        """Q heads zero-padded up to a multiple of the TP degree."""
        return (self.n_heads + model_par - 1) // model_par * model_par

    @property
    def groups(self) -> Tuple[Tuple[Tuple[LayerSpec, ...], int], ...]:
        """Split n_layers into (period, repeats) + optional tail period.

        Returns a tuple of (period_specs, repeats) groups; scan runs over
        repeats with the period body unrolled (period lengths are tiny).
        """
        p = len(self.pattern)
        reps, tail = divmod(self.n_layers, p)
        out = []
        if reps:
            out.append((tuple(self.pattern), reps))
        if tail:
            out.append((tuple(self.pattern[:tail]), 1))
        return tuple(out)

    def param_count(self, model_par: int = 1, padded: bool = False) -> int:
        """Analytic parameter count (used for 6·N·D model-FLOPs roofline).

        With ``padded=True`` counts the physically-materialized (head/vocab
        padded) parameters instead of the logical ones.
        """
        d, dh = self.d_model, self.resolved_head_dim
        hq = self.padded_heads(model_par) if padded else self.n_heads
        hkv = self.n_kv_heads
        v = self.padded_vocab if padded else self.vocab_size
        total = 2 * v * d  # untied in+out embeddings
        specs = [s for period, reps in self.groups for s in period * reps]
        for s in specs:
            if s.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
                total += d * hq * dh + 2 * d * hkv * dh + hq * dh * d
            elif s.mixer == RGLRU:
                w = (self.rglru.width or d) if self.rglru else d
                total += 2 * d * w + w * d + w * self.rglru.conv_width + 3 * w
            elif s.mixer == SSD:
                sc = self.ssm
                dinner = sc.expand * d
                h = dinner // sc.head_dim
                total += d * (2 * dinner + 2 * sc.n_groups * sc.d_state + h)
                total += (dinner + 2 * sc.n_groups * sc.d_state) * sc.conv_width
                total += 2 * h + dinner * d
            nm = {MLP_GELU: 2, MLP_SWIGLU: 3, MLP_GEGLU: 3}.get(s.mlp, 0)
            ff = self.moe.d_ff or self.d_ff if (s.mlp == MLP_MOE and self.moe) else self.d_ff
            if s.mlp == MLP_MOE:
                total += self.moe.n_experts * 3 * d * ff + d * self.moe.n_experts
            elif s.mlp != MLP_NONE:
                total += nm * d * self.d_ff
            if s.dense_residual:
                total += 3 * d * self.d_ff
            total += 2 * d  # norms
        if self.enc_dec:
            # encoder layers: self-attn + mlp ; decoder adds cross-attn
            enc = self.n_enc_layers * (2 * (d * hq * dh + 2 * d * hkv * dh + hq * dh * d) * 0 + 0)
            # counted explicitly below for clarity
            per_enc = d * hq * dh + 2 * d * hkv * dh + hq * dh * d + 2 * d * self.d_ff + 2 * d
            per_cross = d * hq * dh + 2 * d * hkv * dh + hq * dh * d + d
            total += self.n_enc_layers * per_enc + self.n_layers * per_cross
        return int(total)

    def active_param_count(self, model_par: int = 1) -> int:
        """Active params per token (MoE: top_k of n_experts) for 6·N_active·D."""
        if self.moe is None:
            return self.param_count(model_par)
        d = self.d_model
        ff = self.moe.d_ff or self.d_ff
        specs = [s for period, reps in self.groups for s in period * reps]
        n_moe = sum(1 for s in specs if s.mlp == MLP_MOE)
        inactive = n_moe * (self.moe.n_experts - self.moe.top_k) * 3 * d * ff
        return int(self.param_count(model_par) - inactive)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

TRAIN, PREFILL, DECODE = "train", "prefill", "decode"


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == DECODE


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, TRAIN),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, PREFILL),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, DECODE),
    "long_500k": ShapeConfig("long_500k", 524288, 1, DECODE),
}


# ---------------------------------------------------------------------------
# Run / parallelism config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelConfig:
    dp: int = 1                   # data axis
    tp: int = 1                   # model axis
    pods: int = 1                 # pod axis (DP by default, PP optional)
    pod_role: str = "dp"          # dp | pp
    seq_parallel: bool = False    # shard residual stream on seq over model
    microbatches: int = 1         # gradient-accumulation splits
    remat: str = "block"          # none | block (remat each layer body)
    zero1: bool = True            # shard optimizer moments over dp
    grad_compression: str = "none"  # none | int8ef
    moe_impl: str = "etp"         # etp | gshard (dense fallback)
    attn_impl: str = "blockwise"  # naive | blockwise | pallas | interpret
    ce_chunk: int = 512           # chunked cross-entropy sequence block


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = ParallelConfig()
    seed: int = 0
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    opt_moments_dtype: str = "float32"   # float32 | int8 (blockwise-quantized)
    master_weights: bool = False
