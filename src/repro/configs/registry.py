"""Architecture registry: --arch <id> resolution and the 40-cell matrix."""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

from repro.configs.base import DECODE, SHAPES, ModelConfig, ShapeConfig

# arch id -> module name
_ARCH_MODULES = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-tiny": "whisper_tiny",
    "gemma2-9b": "gemma2_9b",
    "qwen2-72b": "qwen2_72b",
    "starcoder2-15b": "starcoder2_15b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "grok-1-314b": "grok1_314b",
    "arctic-480b": "arctic_480b",
    "mamba2-1.3b": "mamba2_1p3b",
    "internvl2-26b": "internvl2_26b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def _mod(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).smoke_config()


@dataclass(frozen=True)
class Cell:
    arch: str
    shape: ShapeConfig
    skip: Optional[str] = None  # reason, if this cell is skipped by design

    @property
    def name(self) -> str:
        return f"{self.arch}:{self.shape.name}"


def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Assignment-mandated skips (documented in DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: 500k context requires sub-quadratic "
                "attention (assignment: skip for pure full-attention archs)")
    return None


def cells(arch: Optional[str] = None,
          shape: Optional[str] = None) -> Iterator[Cell]:
    """All (arch x shape) cells, skip-annotated. 10 archs x 4 shapes = 40."""
    archs = [arch] if arch else list(ARCH_IDS)
    shapes = [SHAPES[shape]] if shape else list(SHAPES.values())
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            yield Cell(a, s, cell_skip_reason(cfg, s))
