"""starcoder2-15b — dense GQA, RoPE, LayerNorm + biases. [arXiv:2402.19173; hf]

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152, gelu MLP.
"""
from repro.configs.base import ATTN_GLOBAL, MLP_GELU, LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab_size=49_152,
        pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_GELU),),
        norm="layernorm",
        linear_bias=True,
        rope_theta=100_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_GELU),),
        norm="layernorm",
        linear_bias=True,
        rope_theta=100_000.0,
    )
