"""grok-1-314b — MoE, 8 experts top-2. [hf:xai-org/grok-1; unverified]

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, attention +
router/output logit softcaps (tanh 30). EP degree 8 < TP 16 -> the MoE ETP
path splits each expert's hidden dim 2-ways (inner TP, see
distributed/moe_parallel.py).
"""
from repro.configs.base import (ATTN_GLOBAL, MLP_MOE, LayerSpec, ModelConfig,
                                MoEConfig)


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=32768,
        vocab_size=131_072,
        pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_MOE),),
        moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25,
                      router_softcap=30.0),
        attn_softcap=30.0,
        final_softcap=30.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok1-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_MOE),),
        moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=1.5,
                      router_softcap=30.0),
        attn_softcap=30.0,
        final_softcap=30.0,
    )
