"""recurrentgemma-9b — Griffin-style hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427; unverified] 38L d_model=4096 16H (GQA kv=1, i.e. MQA)
d_ff=12288 vocab=256000, pattern (rec, rec, local-attn), window 2048.
"""
from repro.configs.base import (ATTN_LOCAL, MLP_GEGLU, RGLRU, LayerSpec,
                                ModelConfig, RGLRUConfig)


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256_000,
        pattern=(
            LayerSpec(mixer=RGLRU, mlp=MLP_GEGLU),
            LayerSpec(mixer=RGLRU, mlp=MLP_GEGLU),
            LayerSpec(mixer=ATTN_LOCAL, mlp=MLP_GEGLU),
        ),
        window=2048,
        rglru=RGLRUConfig(width=4096, conv_width=4),
        subquadratic=True,
        tie_embeddings=True,  # deviation: implemented untied (see DESIGN.md)
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        pattern=(
            LayerSpec(mixer=RGLRU, mlp=MLP_GEGLU),
            LayerSpec(mixer=RGLRU, mlp=MLP_GEGLU),
            LayerSpec(mixer=ATTN_LOCAL, mlp=MLP_GEGLU),
        ),
        window=16,
        rglru=RGLRUConfig(width=64, conv_width=4, block_width=8),
        subquadratic=True,
    )
