"""whisper-tiny — encoder-decoder with conv audio frontend (stub).

[arXiv:2212.04356; unverified] 4L (enc) + 4L (dec) d_model=384 6H (kv=6)
d_ff=1536 vocab=51865. The conv frontend is a stub per the assignment:
``input_specs()`` supplies precomputed frame embeddings for the encoder.
"""
from repro.configs.base import (ATTN_GLOBAL, MLP_GELU, LayerSpec, ModelConfig)


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,
        n_enc_layers=4,
        enc_dec=True,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51_865,
        pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_GELU),),
        norm="layernorm",
        linear_bias=True,
        rope_theta=0.0,  # learned positional embeddings instead of RoPE
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="audio",
        n_layers=2,
        n_enc_layers=2,
        enc_dec=True,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_GELU),),
        norm="layernorm",
        linear_bias=True,
        rope_theta=0.0,
    )
