"""deepseek-coder-33b — llama-arch dense GQA. [arXiv:2401.14196; hf]

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256, SwiGLU.
Note: 56 heads is not divisible by the TP degree 16 — the sharding plan
zero-pads Q heads to 64 (waste surfaced in the roofline ratio column).
"""
from repro.configs.base import ATTN_GLOBAL, MLP_SWIGLU, LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        family="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=19200,
        vocab_size=32_256,
        pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_SWIGLU),),
        rope_theta=100_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=6,  # deliberately not a power of two (exercises head padding)
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_SWIGLU),),
        rope_theta=100_000.0,
    )
