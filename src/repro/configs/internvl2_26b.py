"""internvl2-26b — VLM: InternViT frontend (stub) + InternLM2-20B backbone.

[arXiv:2404.16821; hf] 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553. The vision frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed patch embeddings (prefix_len=256)
prepended to the token stream.
"""
from repro.configs.base import ATTN_GLOBAL, MLP_SWIGLU, LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92_553,
        pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_SWIGLU),),
        prefix_len=256,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_SWIGLU),),
        prefix_len=8,
        rope_theta=1_000_000.0,
    )
