"""mamba2-1.3b — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified] 48L d_model=2048, d_ff=0 (mixer-only blocks),
vocab=50280, ssm_state=128, expand 2 -> d_inner=4096, head_dim 64 -> 64 heads.
"""
from repro.configs.base import (MLP_NONE, SSD, LayerSpec, ModelConfig,
                                SSMConfig)


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=1,        # unused by SSD blocks (heads live in SSMConfig)
        n_kv_heads=1,
        d_ff=0,
        vocab_size=50_280,
        pattern=(LayerSpec(mixer=SSD, mlp=MLP_NONE),),
        ssm=SSMConfig(d_state=128, head_dim=64, n_groups=1, conv_width=4,
                      chunk_size=256, expand=2),
        subquadratic=True,
        tie_embeddings=True,  # deviation: implemented untied (see DESIGN.md)
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=512,
        pattern=(LayerSpec(mixer=SSD, mlp=MLP_NONE),),
        ssm=SSMConfig(d_state=16, head_dim=8, n_groups=1, conv_width=4,
                      chunk_size=16, expand=2),
        subquadratic=True,
    )
