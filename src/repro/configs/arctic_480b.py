"""arctic-480b — dense-MoE hybrid, 128 experts top-2 + dense residual.

[hf:Snowflake/snowflake-arctic-base; hf] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128e top-2 with a parallel dense residual MLP
(Snowflake's dense-MoE hybrid). 56 heads -> Q-head padding to 64 under TP 16.
"""
from repro.configs.base import (ATTN_GLOBAL, MLP_MOE, LayerSpec, ModelConfig,
                                MoEConfig)


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32_000,
        pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_MOE,
                           dense_residual=True),),
        moe=MoEConfig(n_experts=128, top_k=2, capacity_factor=1.25),
        rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=512,
        pattern=(LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_MOE,
                           dense_residual=True),),
        moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.5),
    )
