"""gemma2-9b — dense, local+global alternating attention, logit softcaps.

[arXiv:2408.00118; hf] 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000, head_dim=256, window 4096, attn softcap 50, final softcap 30,
sandwich (pre+post) RMSNorms, GeGLU.
"""
from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, MLP_GEGLU, LayerSpec,
                                ModelConfig)


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256_000,
        pattern=(
            LayerSpec(mixer=ATTN_LOCAL, mlp=MLP_GEGLU),
            LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_GEGLU),
        ),
        window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        post_norms=True,
        tie_embeddings=True,  # deviation: implemented untied (see DESIGN.md)
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        pattern=(
            LayerSpec(mixer=ATTN_LOCAL, mlp=MLP_GEGLU),
            LayerSpec(mixer=ATTN_GLOBAL, mlp=MLP_GEGLU),
        ),
        window=16,
        attn_softcap=50.0,
        final_softcap=30.0,
        post_norms=True,
    )
