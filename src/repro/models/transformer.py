"""Block-pattern transformer LM: one implementation, ten architectures.

A model is a repeating pattern of (mixer, mlp) layer specs (configs/base.py).
Layers are *stacked by period position* and executed with ``lax.scan`` over
period repeats (+ remat on the body), so HLO size and compile time are
independent of depth. Covers dense/GQA, MoE, SSM (Mamba2), hybrid
(RecurrentGemma), encoder-decoder (Whisper) and VLM-prefix (InternVL) forms.

Distribution is injected through ``ModelRuntime``: sharding-constraint hook,
TP degree (for head/vocab padding layouts), and optional shard_map
implementations for decode attention (flash-decoding) and MoE (ETP).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, MLP_MOE, MLP_NONE,
                                RGLRU, SSD, LayerSpec, ModelConfig)
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import HeadLayout, make_head_layout
from repro.models.layers import (ParamBuilder, apply_mlp, apply_norm,
                                 embed_tokens, init_embeddings, init_mlp,
                                 init_norm, rope, softcap)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelRuntime:
    """Execution-environment injection (kept out of ModelConfig so the same
    config lowers for smoke tests, dry-runs, and TPU runs)."""
    tp: int = 1
    attn_impl: str = "blockwise"          # naive|blockwise|pallas|interpret
    rglru_impl: str = "jnp"               # jnp|pallas|interpret
    ssd_impl: str = "jnp"
    moe_fn: Optional[Callable] = None     # shard_map ETP: (p, x, cfg)->(y,aux)
    decode_attn_fn: Optional[Callable] = None
    constrain: Callable = lambda x, kind: x
    remat: bool = True
    remat_policy: str = "full"            # full | dots (save matmul outputs)
    max_seq: int = 4096                   # sizes learned-pos tables / caches
    moe_dp: int = 1                       # 2D expert-parallel slot factor

    def head_layout(self, cfg: ModelConfig) -> HeadLayout:
        return make_head_layout(cfg.n_heads, cfg.n_kv_heads, self.tp)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(key: jax.Array, cfg: ModelConfig, spec: LayerSpec,
                rt: ModelRuntime, cross: bool = False,
                causal: bool = True) -> Tuple[Params, Params]:
    pb = ParamBuilder(key, dtype=jnp.bfloat16)
    gemma = cfg.norm == "rmsnorm" and cfg.post_norms
    init_norm(pb, "norm1", cfg.d_model, cfg.norm, gemma)
    layout = rt.head_layout(cfg)
    dh = cfg.resolved_head_dim
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        sub = pb.child("mixer")
        attn_mod.init_attention(sub, cfg.d_model, layout, dh,
                                qkv_bias=cfg.qkv_bias,
                                linear_bias=cfg.linear_bias)
    elif spec.mixer == RGLRU:
        rglru_mod.init_rglru(pb.child("mixer"), cfg)
    elif spec.mixer == SSD:
        ssm_mod.init_ssd(pb.child("mixer"), cfg)
    else:
        raise ValueError(spec.mixer)
    if cfg.post_norms:
        init_norm(pb, "post_norm1", cfg.d_model, cfg.norm, gemma)
    if cross:
        init_norm(pb, "norm_cross", cfg.d_model, cfg.norm, gemma)
        sub = pb.child("cross")
        attn_mod.init_attention(sub, cfg.d_model, layout, dh,
                                linear_bias=cfg.linear_bias)
    if spec.mlp != MLP_NONE:
        init_norm(pb, "norm2", cfg.d_model, cfg.norm, gemma)
        if spec.mlp == MLP_MOE:
            moe_layout = moe_mod.make_moe_layout(cfg, rt.tp, rt.moe_dp)
            moe_mod.init_moe(pb.child("mlp"), cfg, moe_layout)
        else:
            init_mlp(pb.child("mlp"), cfg.d_model, cfg.d_ff, spec.mlp,
                     cfg.linear_bias)
        if spec.dense_residual:
            init_mlp(pb.child("dense_mlp"), cfg.d_model, cfg.d_ff, "swiglu",
                     cfg.linear_bias)
        if cfg.post_norms:
            init_norm(pb, "post_norm2", cfg.d_model, cfg.norm, gemma)
    return pb.params, pb.specs


def init_params(key: jax.Array, cfg: ModelConfig, rt: ModelRuntime
                ) -> Tuple[Params, Params]:
    """Build (params, logical-axis specs)."""
    pb = ParamBuilder(key, dtype=jnp.bfloat16)
    init_embeddings(pb, cfg.padded_vocab, cfg.d_model)
    gemma = cfg.norm == "rmsnorm" and cfg.post_norms
    init_norm(pb, "final_norm", cfg.d_model, cfg.norm, gemma)
    if cfg.rope_theta <= 0:  # learned positions (whisper)
        pb.param("pos_embed", (rt.max_seq, cfg.d_model), (None, None),
                 init="normal", scale=0.02)

    def add_stack(parent: ParamBuilder, name: str, period, reps,
                  cross: bool, causal: bool) -> None:
        grp = parent.child(name)
        for i, spec in enumerate(period):
            grp.stacked(
                f"p{i}", reps,
                functools.partial(_init_layer, cfg=cfg, spec=spec, rt=rt,
                                  cross=cross, causal=causal))

    for gi, (period, reps) in enumerate(cfg.groups):
        add_stack(pb, f"group{gi}", period, reps, cross=cfg.enc_dec,
                  causal=True)
    if cfg.enc_dec:
        enc = pb.child("encoder")
        if cfg.rope_theta <= 0:
            enc.param("pos_embed", (rt.max_seq, cfg.d_model), (None, None),
                      init="normal", scale=0.02)
        init_norm(enc, "final_norm", cfg.d_model, cfg.norm, gemma)
        add_stack(enc, "group0", (LayerSpec(mixer=ATTN_GLOBAL,
                                            mlp=cfg.pattern[0].mlp),),
                  cfg.n_enc_layers, cross=False, causal=False)
    return pb.params, pb.specs


def abstract_params(cfg: ModelConfig, rt: ModelRuntime
                    ) -> Tuple[Params, Params]:
    """ShapeDtypeStruct params (no allocation) + specs, for dry-runs.

    Specs are static Python (axis-name tuples); they are captured as a side
    effect of tracing init_params under eval_shape.
    """
    holder = {}

    def go(k):
        p, s = init_params(k, cfg, rt)
        holder["specs"] = s
        return p

    shapes = jax.eval_shape(go, jax.random.PRNGKey(0))
    return shapes, holder["specs"]


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_attn_full(lp: Params, x: jax.Array, spec: LayerSpec,
                     cfg: ModelConfig, rt: ModelRuntime,
                     positions: jax.Array, causal: bool,
                     kv_x: Optional[jax.Array] = None,
                     collect_cache: bool = False):
    layout = rt.head_layout(cfg)
    q, k, v = attn_mod.qkv_project(lp, x, kv_x)
    kv_pos = positions if kv_x is None else \
        jnp.arange(kv_x.shape[1], dtype=positions.dtype)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, kv_pos, cfg.rope_theta)
    window = cfg.window if spec.mixer == ATTN_LOCAL else 0
    o = attn_mod.attend(q, k, v, causal=causal, window=window,
                        cap=cfg.attn_softcap, impl=rt.attn_impl)
    y = attn_mod.out_project(lp, o, layout.head_mask())
    cache = None
    if collect_cache:
        s_cache = min(window, rt.max_seq) if window else rt.max_seq
        s = k.shape[1]
        kpos = jnp.broadcast_to(kv_pos, k.shape[:2]).astype(jnp.int32)
        if s < s_cache:  # pad to cache size
            pad = s_cache - s
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=-1)
        elif s > s_cache:  # keep last window (ring layout: slot = pos % Sc)
            k, v, kpos = (t[:, -s_cache:] for t in (k, v, kpos))
            # entry j holds pos (s - s_cache + j); slot for pos p is p % Sc,
            # so new[i] = old[(i - s % Sc) % Sc]  ==  roll by +(s % Sc).
            roll = s % s_cache
            k = jnp.roll(k, roll, axis=1)
            v = jnp.roll(v, roll, axis=1)
            kpos = jnp.roll(kpos, roll, axis=1)
        cache = {"k": k, "v": v, "kpos": kpos}
    return y, cache


def _decode_attn(rt: ModelRuntime, cache: Params, k_new, v_new, q, pos,
                 *, window: int, cap: float):
    fn = rt.decode_attn_fn
    if fn is None:
        fn = _jnp_decode_attn
    return fn(cache["k"], cache["v"], cache["kpos"], k_new, v_new, q, pos,
              window=window, cap=cap)


def _jnp_decode_attn(k_cache, v_cache, kpos, k_new, v_new, q, pos, *,
                     window: int, cap: float):
    """Single-device decode attention with in-place ring-buffer update."""
    s_cache = k_cache.shape[1]
    if k_new is not None:
        slot = pos % s_cache
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new[:, None], (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new[:, None], (0, slot, 0, 0))
        kpos = jax.lax.dynamic_update_slice(
            kpos, jnp.broadcast_to(pos, (kpos.shape[0], 1)).astype(kpos.dtype),
            (0, slot))
    o = attn_mod.decode_attend(q, k_cache, v_cache, kpos, pos, window=window,
                               cap=cap)
    return o, {"k": k_cache, "v": v_cache, "kpos": kpos}


def _apply_attn_decode(lp: Params, x: jax.Array, spec: LayerSpec,
                       cfg: ModelConfig, rt: ModelRuntime, cache: Params,
                       pos: jax.Array, cross: bool = False):
    layout = rt.head_layout(cfg)
    q = jnp.einsum("bd,dhk->bhk", x[:, 0], lp["wq"])
    if "bq" in lp:
        q = q + lp["bq"]
    positions = pos[None]  # [S=1]
    if cfg.rope_theta > 0:
        q = rope(q[:, None], positions, cfg.rope_theta)[:, 0]
    if cross:
        k_new = v_new = None
    else:
        k_new = jnp.einsum("bd,dhk->bhk", x[:, 0], lp["wk"])
        v_new = jnp.einsum("bd,dhk->bhk", x[:, 0], lp["wv"])
        if "bk" in lp:
            k_new, v_new = k_new + lp["bk"], v_new + lp["bv"]
        k_new = rope(k_new[:, None], positions, cfg.rope_theta)[:, 0]
    window = cfg.window if spec.mixer == ATTN_LOCAL else 0
    o, new_cache = _decode_attn(rt, cache, k_new, v_new, q, pos,
                                window=window, cap=cfg.attn_softcap)
    y = attn_mod.out_project(lp, o[:, None], layout.head_mask())
    return y, new_cache


def apply_layer(lp: Params, x: jax.Array, spec: LayerSpec, cfg: ModelConfig,
                rt: ModelRuntime, *, mode: str, positions=None, cache=None,
                enc_out=None, pos=None, causal: bool = True):
    """mode: full | prefill | decode. Returns (x, cache_out, aux)."""
    gemma = cfg.norm == "rmsnorm" and cfg.post_norms
    aux = jnp.zeros((), jnp.float32)
    cache_out: Dict[str, Any] = {}
    h = apply_norm(lp["norm1"], x, cfg.norm, gemma)

    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        if mode == "decode":
            y, c = _apply_attn_decode(lp["mixer"], h, spec, cfg, rt,
                                      cache["self"], pos)
            cache_out["self"] = c
        else:
            y, c = _apply_attn_full(lp["mixer"], h, spec, cfg, rt, positions,
                                    causal, collect_cache=(mode == "prefill"))
            if mode == "prefill":
                cache_out["self"] = c
    elif spec.mixer == RGLRU:
        st = cache["self"] if mode == "decode" else None
        y, st2 = rglru_mod.apply_rglru(lp["mixer"], h, cfg, state=st,
                                       impl=rt.rglru_impl,
                                       return_state=(mode == "prefill"))
        if mode in ("decode", "prefill"):
            cache_out["self"] = st2
    elif spec.mixer == SSD:
        st = cache["self"] if mode == "decode" else None
        y, st2 = ssm_mod.apply_ssd(lp["mixer"], h, cfg, state=st,
                                   impl=rt.ssd_impl,
                                   return_state=(mode == "prefill"))
        if mode in ("decode", "prefill"):
            cache_out["self"] = st2
    else:
        raise ValueError(spec.mixer)

    if cfg.post_norms:
        y = apply_norm(lp["post_norm1"], y, cfg.norm, gemma)
    x = x + y
    x = rt.constrain(x, "resid")

    if "cross" in lp:  # whisper decoder cross-attention
        h = apply_norm(lp["norm_cross"], x, cfg.norm, gemma)
        if mode == "decode":
            y, _ = _apply_attn_decode(lp["cross"], h, spec, cfg, rt,
                                      cache["cross"], pos, cross=True)
            cache_out["cross"] = cache["cross"]
        else:
            y, c = _apply_attn_full(lp["cross"], h, spec, cfg, rt, positions,
                                    causal=False, kv_x=enc_out,
                                    collect_cache=(mode == "prefill"))
            if mode == "prefill":
                cache_out["cross"] = {k2: v2 for k2, v2 in c.items()}
        x = x + y

    if spec.mlp != MLP_NONE:
        h = apply_norm(lp["norm2"], x, cfg.norm, gemma)
        if spec.mlp == MLP_MOE:
            if rt.moe_fn is not None:
                y, a = rt.moe_fn(lp["mlp"], h, cfg)
            else:
                y, a = moe_mod.apply_moe_gshard(lp["mlp"], h, cfg)
            aux = aux + a
        else:
            y = apply_mlp(lp["mlp"], h, spec.mlp)
        if spec.dense_residual:
            y = y + apply_mlp(lp["dense_mlp"], h, "swiglu")
        if cfg.post_norms:
            y = apply_norm(lp["post_norm2"], y, cfg.norm, gemma)
        x = x + y
        x = rt.constrain(x, "resid")
    return x, (cache_out or None), aux


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _run_groups(params: Params, cfg: ModelConfig, rt: ModelRuntime,
                x: jax.Array, *, mode: str, positions, enc_out=None,
                cache=None, pos=None, groups=None, prefix: str = "group",
                causal: bool = True):
    """Scan over each (period, repeats) group. Returns (x, caches, aux)."""
    groups = groups if groups is not None else cfg.groups
    total_aux = jnp.zeros((), jnp.float32)
    caches_out = {}
    for gi, (period, reps) in enumerate(groups):
        gp = params[f"{prefix}{gi}"]
        gcache = cache[f"{prefix}{gi}"] if cache is not None else None

        def body(carry, xs, period=period):
            xc, aux_c = carry
            lp_all, cache_slice = xs
            new_cache_slice = {}
            for i, spec in enumerate(period):
                c_i = None if cache_slice is None else cache_slice[f"p{i}"]
                base = functools.partial(
                    apply_layer, spec=spec, cfg=cfg, rt=rt, mode=mode,
                    positions=positions, enc_out=enc_out, pos=pos,
                    causal=causal)
                call = (lambda lp, xin, c, _f=base: _f(lp, xin, cache=c))
                if rt.remat and mode == "full":
                    pol = None if rt.remat_policy == "full" else \
                        jax.checkpoint_policies \
                        .dots_with_no_batch_dims_saveable
                    call = jax.checkpoint(call, policy=pol)
                xc, c_out, a = call(lp_all[f"p{i}"], xc, c_i)
                new_cache_slice[f"p{i}"] = c_out
                aux_c = aux_c + a
            ys = new_cache_slice if any(
                v is not None for v in new_cache_slice.values()) else None
            return (xc, aux_c), ys

        (x, total_aux), ys = jax.lax.scan(body, (x, total_aux), (gp, gcache))
        if ys is not None:
            caches_out[f"{prefix}{gi}"] = ys
    return x, (caches_out or None), total_aux


def encode(params: Params, cfg: ModelConfig, rt: ModelRuntime,
           frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub frame embeddings [B,S,D]."""
    enc = params["encoder"]
    x = frames.astype(jnp.bfloat16)
    s = x.shape[1]
    if "pos_embed" in enc:
        x = x + enc["pos_embed"][:s][None].astype(x.dtype)
    positions = jnp.arange(s)
    x, _, _ = _run_groups(enc, cfg, rt, x, mode="full", positions=positions,
                          groups=((cfg.pattern[:1], cfg.n_enc_layers),),
                          causal=False)
    gemma = cfg.norm == "rmsnorm" and cfg.post_norms
    return apply_norm(enc["final_norm"], x, cfg.norm, gemma)


def forward(params: Params, cfg: ModelConfig, rt: ModelRuntime,
            tokens: jax.Array, *, prefix_embeds=None, enc_frames=None,
            mode: str = "full"):
    """Returns (hidden [B,S,D], caches|None, aux). Logits via lm_head()."""
    x = embed_tokens(params, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    if "pos_embed" in params:
        x = x + params["pos_embed"][:s][None].astype(x.dtype)
    x = rt.constrain(x, "resid")
    positions = jnp.arange(s)
    enc_out = None
    if cfg.enc_dec:
        assert enc_frames is not None
        enc_out = encode(params, cfg, rt, enc_frames)
    x, caches, aux = _run_groups(params, cfg, rt, x, mode=mode,
                                 positions=positions, enc_out=enc_out)
    gemma = cfg.norm == "rmsnorm" and cfg.post_norms
    x = apply_norm(params["final_norm"], x, cfg.norm, gemma)
    return x, caches, aux


def lm_head(params: Params, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    logits = jnp.einsum("...d,dv->...v", hidden, params["out_embed"])
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, rt: ModelRuntime, batch: int,
               enc_len: int = 0) -> Tuple[Params, Params]:
    """Zero/empty decode caches (+ logical axis specs) for all layers."""
    layout = rt.head_layout(cfg)
    dh = cfg.resolved_head_dim

    def layer_cache(spec: LayerSpec):
        out, specs = {}, {}
        if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
            sc = min(cfg.window, rt.max_seq) if spec.mixer == ATTN_LOCAL \
                else rt.max_seq
            out["self"] = {
                "k": jnp.zeros((batch, sc, layout.kv_heads, dh),
                               jnp.bfloat16),
                "v": jnp.zeros((batch, sc, layout.kv_heads, dh),
                               jnp.bfloat16),
                "kpos": jnp.full((batch, sc), -1, jnp.int32)}
            specs["self"] = {
                "k": ("kv_batch", "kv_seq", None, None),
                "v": ("kv_batch", "kv_seq", None, None),
                "kpos": ("kv_batch", "kv_seq")}
        elif spec.mixer == RGLRU:
            st = rglru_mod.init_rglru_state(cfg, batch)
            out["self"] = st
            specs["self"] = {"h": ("kv_batch", "rglru"),
                             "conv": ("kv_batch", None, "rglru")}
        elif spec.mixer == SSD:
            st = ssm_mod.init_ssd_state(cfg, batch)
            out["self"] = st
            specs["self"] = {"h": ("kv_batch", "ssm_heads", None, None),
                             "conv": ("kv_batch", None, None)}
        if cfg.enc_dec:
            out["cross"] = {
                "k": jnp.zeros((batch, enc_len, layout.kv_heads, dh),
                               jnp.bfloat16),
                "v": jnp.zeros((batch, enc_len, layout.kv_heads, dh),
                               jnp.bfloat16),
                "kpos": jnp.zeros((batch, enc_len), jnp.int32)}
            specs["cross"] = {"k": ("kv_batch", "kv_seq", None, None),
                              "v": ("kv_batch", "kv_seq", None, None),
                              "kpos": ("kv_batch", "kv_seq")}
        return out, specs

    cache, specs = {}, {}
    for gi, (period, reps) in enumerate(cfg.groups):
        g, gs = {}, {}
        for i, spec in enumerate(period):
            c1, s1 = layer_cache(spec)
            g[f"p{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (reps,) + a.shape), c1)
            gs[f"p{i}"] = jax.tree.map(
                lambda s: (None,) + tuple(s), s1,
                is_leaf=lambda t: isinstance(t, tuple))
        cache[f"group{gi}"] = g
        specs[f"group{gi}"] = gs
    return cache, specs


def decode_step(params: Params, cfg: ModelConfig, rt: ModelRuntime,
                cache: Params, tokens: jax.Array, pos: jax.Array):
    """One token: tokens [B] int32, pos scalar int32.
    Returns (logits [B, V], new_cache)."""
    x = embed_tokens(params, tokens)[:, None]  # [B,1,D]
    if "pos_embed" in params:
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], pos, 1, axis=0)[None].astype(x.dtype)
    x, new_cache, _ = _run_groups(params, cfg, rt, x, mode="decode",
                                  positions=pos[None], cache=cache, pos=pos)
    gemma = cfg.norm == "rmsnorm" and cfg.post_norms
    x = apply_norm(params["final_norm"], x, cfg.norm, gemma)
    return lm_head(params, cfg, x[:, 0]), new_cache


def prefill(params: Params, cfg: ModelConfig, rt: ModelRuntime,
            tokens: jax.Array, *, prefix_embeds=None, enc_frames=None):
    """Run the prompt, return (last-token logits, decode caches)."""
    hidden, caches, _ = forward(params, cfg, rt, tokens,
                                prefix_embeds=prefix_embeds,
                                enc_frames=enc_frames, mode="prefill")
    return lm_head(params, cfg, hidden[:, -1]), caches
