"""Mixture-of-experts FFN: router + dispatch.

Two implementations share router semantics:
  * ``gshard`` — dense compute-all-experts weighted combine (exact; used for
    smoke tests and as the correctness oracle for the distributed path).
  * ``etp``    — expert-(tensor-)parallel shard_map path in
    ``distributed/moe_parallel.py`` (capacity-based dispatch, all_to_all,
    inner-TP via ppermute) — the production path.

Expert weights are stored *device-major*: [slots, E_loc, D, F_loc] where
``slots = tp`` mesh degree, slot s owns expert group ``s // inner`` and FFN
shard ``s % inner`` with ``inner = max(1, tp // n_experts)``. With tp == 1
this degenerates to [1, E, D, F] (the logical layout).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamBuilder, softcap

Params = Dict[str, Any]


class MoELayout(NamedTuple):
    slots: int       # total virtual slots (= tp, or tp*dp for 2D)
    inner: int       # FFN shards per expert group
    e_loc: int       # experts per slot group
    f_loc: int       # FFN hidden per slot
    dp: int = 1      # data-axis slot factor (2D expert parallelism)

    @property
    def groups(self) -> int:
        return self.slots // self.inner


def make_moe_layout(cfg: ModelConfig, tp: int, dp: int = 1) -> MoELayout:
    e = cfg.moe.n_experts
    f = cfg.moe.d_ff or cfg.d_ff
    slots = tp * dp
    inner = max(1, slots // e)
    groups = slots // inner
    assert slots % inner == 0 and e % groups == 0, (e, tp, dp)
    assert f % inner == 0, (f, inner)
    if dp > 1:  # inner ring must stay within one model row (ppermute axis)
        assert inner <= dp and dp % inner == 0, (inner, dp)
    return MoELayout(slots, inner, e // groups, f // inner, dp)


def can_use_2d(cfg: ModelConfig, tp: int, dp: int,
               last_axis: int = 0) -> bool:
    if cfg.moe is None or dp <= 1:
        return False
    e = cfg.moe.n_experts
    f = cfg.moe.d_ff or cfg.d_ff
    slots = tp * dp
    inner = max(1, slots // e)
    groups = slots // inner
    last = last_axis or dp
    return (slots % inner == 0 and e % groups == 0 and f % inner == 0
            and inner <= last and last % inner == 0 and dp % inner == 0)


def init_moe(pb: ParamBuilder, cfg: ModelConfig, layout: MoELayout) -> None:
    d = cfg.d_model
    sl, el, fl = layout.slots, layout.e_loc, layout.f_loc
    pb.param("router", (d, cfg.moe.n_experts), (None, None), init="fan_in")
    if layout.dp > 1:
        # 2D expert parallelism (training): slots span model x data — the
        # weights are fully resident, tokens travel (two-hop all_to_all).
        tp = sl // layout.dp
        pb.param("wi", (tp, layout.dp, el, d, fl),
                 ("expert_slots", "expert_slots_dp", None, None, None),
                 init="fan_in")
        pb.param("wg", (tp, layout.dp, el, d, fl),
                 ("expert_slots", "expert_slots_dp", None, None, None),
                 init="fan_in")
        pb.param("wo", (tp, layout.dp, el, fl, d),
                 ("expert_slots", "expert_slots_dp", None, None, None),
                 init="fan_in")
        return
    # "expert_f" is unsharded by default; decode plans map it to the data
    # axes (2D expert sharding -> giant MoEs stay resident, no FSDP gathers)
    pb.param("wi", (sl, el, d, fl), ("expert_slots", None, None, "expert_f"),
             init="fan_in")
    pb.param("wg", (sl, el, d, fl), ("expert_slots", None, None, "expert_f"),
             init="fan_in")
    pb.param("wo", (sl, el, fl, d), ("expert_slots", None, "expert_f", None),
             init="fan_in")


def router_probs(p: Params, x: jax.Array, cfg: ModelConfig
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [..., D] -> (top-k gate weights [..., k], expert ids [..., k],
    full probs [..., E] for aux loss)."""
    logits = jnp.einsum("...d,de->...e", x, p["router"]) \
        .astype(jnp.float32)
    logits = softcap(logits, cfg.moe.router_softcap)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.moe.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, ids, probs


def load_balance_loss(probs: jax.Array, ids: jax.Array, n_experts: int
                      ) -> jax.Array:
    """Switch-style auxiliary load-balancing loss."""
    me = probs.reshape(-1, n_experts).mean(0)
    assign = jax.nn.one_hot(ids.reshape(-1), n_experts).mean(0) * ids.shape[-1]
    return n_experts * jnp.sum(me * assign)


def logical_expert_weights(p: Params, cfg: ModelConfig):
    """Device-major [slots, E_loc, D, F_loc] -> logical [E, D, F] views."""
    if p["wi"].ndim == 5:  # 2D layout: flatten (tp, dp) -> slots
        p = dict(p)
        for k in ("wi", "wg", "wo"):
            w = p[k]
            p[k] = w.reshape((w.shape[0] * w.shape[1],) + w.shape[2:])
    slots = p["wi"].shape[0]
    layout = make_moe_layout(cfg, slots)
    e, d = cfg.moe.n_experts, cfg.d_model
    f = cfg.moe.d_ff or cfg.d_ff

    def undev(w, last_is_d):
        g, r, el, fl = layout.groups, layout.inner, layout.e_loc, layout.f_loc
        if last_is_d:  # wo: [slots, el, fl, d]
            w = w.reshape(g, r, el, fl, d).transpose(0, 2, 1, 3, 4)
            return w.reshape(e, f, d)
        w = w.reshape(g, r, el, d, fl).transpose(0, 2, 3, 1, 4)
        return w.reshape(e, d, f)

    return undev(p["wi"], False), undev(p["wg"], False), undev(p["wo"], True)


def apply_moe_gshard(p: Params, x: jax.Array, cfg: ModelConfig
                     ) -> Tuple[jax.Array, jax.Array]:
    """Dense all-experts fallback (exact oracle; any slot layout). x [B,S,D]."""
    wi, wg, wo = logical_expert_weights(p, cfg)
    gates, ids, probs = router_probs(p, x, cfg)
    e = cfg.moe.n_experts
    # combine weights per expert: [B,S,E]
    comb = jnp.zeros(x.shape[:-1] + (e,), jnp.float32)
    for j in range(cfg.moe.top_k):
        comb = comb + jax.nn.one_hot(ids[..., j], e) * gates[..., j:j + 1]
    h = jnp.einsum("bsd,edf->bsef", x, wi)
    g = jnp.einsum("bsd,edf->bsef", x, wg)
    h = jax.nn.silu(g) * h
    y = jnp.einsum("bsef,efd->bsed", h, wo)
    out = jnp.einsum("bsed,bse->bsd", y, comb.astype(y.dtype))
    aux = load_balance_loss(probs, ids, e)
    return out, aux
