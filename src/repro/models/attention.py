"""Attention: GQA/MQA, causal / sliding-window / cross, logit softcap.

Head layout: Q heads are stored FLAT as ``H = kv_heads * group`` (group-major:
q head ``h`` reads kv head ``h // group``), because a single TP mesh axis can
shard the flat head dim even when neither kv_heads nor group alone divides
the TP degree (qwen2: 8 kv x 8 group over tp=16). TP head-padding happens
*inside* groups (group padded: deepseek 7->8/group) or on kv heads for MHA
(whisper 6->16); a static ``head_mask`` zeroes padded heads' outputs so
padding never changes the math and padded Wo rows get zero gradient.

K/V projections are small (kv_heads <= 8) and kept replicated under TP; the
``repeat`` to flat heads is a local slice of a replicated tensor (no comms).

Implementations:
  naive      - full score matrix (oracle / tiny shapes)
  blockwise  - scan over Q blocks, online-softmax scan over KV blocks
               (flash structure in pure jnp; the dry-run path)
  local      - sliding window with *static* KV slices per Q block: compute
               scales with window, not seq^2
  pallas     - kernels/flash_attention (TPU fast path; interpret for tests)
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamBuilder, rope, softcap

Params = Dict[str, Any]

NEG_INF = -2.0e38


class HeadLayout(NamedTuple):
    kv_heads: int        # physical (possibly padded for MHA) KV heads
    group: int           # physical Q heads per KV head (possibly padded)
    real_kv: int
    real_group: int

    @property
    def q_heads(self) -> int:
        return self.kv_heads * self.group

    def head_mask(self) -> jax.Array:
        h = jnp.arange(self.q_heads)
        return ((h % self.group < self.real_group) &
                (h // self.group < self.real_kv)).astype(jnp.bfloat16)


def make_head_layout(n_heads: int, n_kv_heads: int, tp: int) -> HeadLayout:
    """Pad Q heads (inside groups / kv for MHA) so q_heads % tp == 0."""
    if n_heads == n_kv_heads:  # MHA: pad kv heads alongside
        kh = n_heads if n_heads % tp == 0 else \
            (n_heads + tp - 1) // tp * tp
        return HeadLayout(kh, 1, n_heads, 1)
    g = n_heads // n_kv_heads
    g_pad = g
    while (n_kv_heads * g_pad) % tp:
        g_pad += 1
    return HeadLayout(n_kv_heads, g_pad, n_kv_heads, g)


def repeat_kv(k: jax.Array, group: int) -> jax.Array:
    """[..., Kh, Dh] -> [..., Kh*group, Dh] (local expand of replicated kv)."""
    if group == 1:
        return k
    return jnp.repeat(k, group, axis=-2)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(pb: ParamBuilder, d: int, layout: HeadLayout, dh: int,
                   *, qkv_bias: bool = False, linear_bias: bool = False):
    h, kh = layout.q_heads, layout.kv_heads
    pb.param("wq", (d, h, dh), (None, "heads", None), init="fan_in")
    pb.param("wk", (d, kh, dh), (None, None, None), init="fan_in")
    pb.param("wv", (d, kh, dh), (None, None, None), init="fan_in")
    pb.param("wo", (h, dh, d), ("heads", None, None), init="fan_in")
    if qkv_bias or linear_bias:
        pb.param("bq", (h, dh), ("heads", None), init="zeros")
        pb.param("bk", (kh, dh), (None, None), init="zeros")
        pb.param("bv", (kh, dh), (None, None), init="zeros")
    if linear_bias:
        pb.param("bo", (d,), (None,), init="zeros")


def qkv_project(p: Params, x: jax.Array, kv_x: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: [B,S,D] -> q [B,S,H,Dh], k/v [B,Skv,Kh,Dh]."""
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def out_project(p: Params, o: jax.Array, head_mask: jax.Array) -> jax.Array:
    """o: [B,S,H,Dh] -> [B,S,D]; padded heads masked to keep math exact."""
    o = o * head_mask[:, None].astype(o.dtype)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    return y


# ---------------------------------------------------------------------------
# Core attention math (flat heads; kv repeated locally)
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, *, causal: bool, window: int = 0,
                    cap: float = 0.0, q_offset: int = 0) -> jax.Array:
    """Oracle. q [B,Sq,H,Dh]; k,v [B,Sk,Kh,Dh] -> [B,Sq,H,Dh]."""
    g = q.shape[2] // k.shape[2]
    kk, vv = repeat_kv(k, g), repeat_kv(v, g)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bshd->bhqs", q, kk).astype(jnp.float32) * scale
    if cap > 0:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(q.shape[1]) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p.astype(vv.dtype), vv)


def _online_block(carry, k_blk, v_blk, q_blk, mask, scale, cap):
    """One online-softmax step. carry = (o, m, l). q_blk [B,bq,H,D];
    k_blk/v_blk [B,bk,H,D] (already repeated)."""
    o, m, l = carry
    s = jnp.einsum("bqhd,bshd->bhqs", q_blk, k_blk).astype(jnp.float32)
    s = s * scale
    if cap > 0:
        s = cap * jnp.tanh(s / cap)
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    m_safe = jnp.maximum(m_new, -1e30)
    p = jnp.exp(s - m_safe[..., None])
    alpha = jnp.exp(jnp.maximum(m, -1e30) - m_safe)
    l_new = l * alpha + p.sum(axis=-1)
    pv = jnp.einsum("bhqs,bshd->bhqd", p.astype(v_blk.dtype), v_blk)
    o_new = o * alpha[..., None].astype(o.dtype) + pv.astype(o.dtype)
    return o_new, m_new, l_new


def blockwise_attention(q, k, v, *, causal: bool, cap: float = 0.0,
                        q_offset: int = 0, bq: int = 512,
                        bk: int = 512) -> jax.Array:
    """Flash-structured attention in jnp (scan over Q and KV blocks)."""
    B, Sq, H, Dh = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    g = H // Kh
    kk, vv = repeat_kv(k, g), repeat_kv(v, g)
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale = Dh ** -0.5
    nq, nk = Sq // bq, Sk // bk
    q_blocks = q.reshape(B, nq, bq, H, Dh).transpose(1, 0, 2, 3, 4)
    k_blocks = kk.reshape(B, nk, bk, H, Dh).transpose(1, 0, 2, 3, 4)
    v_blocks = vv.reshape(B, nk, bk, H, Dh).transpose(1, 0, 2, 3, 4)

    def per_q_block(qi, q_blk):
        qpos = qi * bq + jnp.arange(bq) + q_offset

        def kv_step(carry, xs):
            ki, k_blk, v_blk = xs
            kpos = ki * bk + jnp.arange(bk)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            return _online_block(carry, k_blk, v_blk, q_blk, mask, scale,
                                 cap), None

        o0 = jnp.zeros((B, H, bq, Dh), jnp.float32)
        m0 = jnp.full((B, H, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        (o, m, l), _ = jax.lax.scan(
            kv_step, (o0, m0, l0), (jnp.arange(nk), k_blocks, v_blocks))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return o.transpose(0, 2, 1, 3)  # [B,bq,H,Dh]

    out = jax.lax.map(lambda xs: per_q_block(*xs), (jnp.arange(nq), q_blocks))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dh).astype(q.dtype)


def local_attention(q, k, v, *, window: int, cap: float = 0.0,
                    bq: int = 512) -> jax.Array:
    """Sliding-window causal attention with static KV slices per Q block.

    Compute per Q block covers exactly span = window + bq keys ending at the
    block's last row -> cost O(S * window), not O(S^2).
    """
    B, Sq, H, Dh = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    assert Sq == Sk, "local attention is self-attention"
    g = H // Kh
    kk, vv = repeat_kv(k, g), repeat_kv(v, g)
    bq = min(bq, Sq)
    span = min(window + bq, Sk)  # static slice length
    scale = Dh ** -0.5
    nq = Sq // bq
    q_blocks = q.reshape(B, nq, bq, H, Dh).transpose(1, 0, 2, 3, 4)

    def per_q_block(qi, q_blk):
        qs = qi * bq
        start = jnp.clip(qs + bq - span, 0, Sk - span)
        k_sl = jax.lax.dynamic_slice_in_dim(kk, start, span, axis=1)
        v_sl = jax.lax.dynamic_slice_in_dim(vv, start, span, axis=1)
        qpos = qs + jnp.arange(bq)
        kpos = start + jnp.arange(span)
        mask = (qpos[:, None] >= kpos[None, :]) & \
               (kpos[None, :] > qpos[:, None] - window)
        s = jnp.einsum("bqhd,bshd->bhqs", q_blk, k_sl).astype(jnp.float32)
        s = s * scale
        if cap > 0:
            s = cap * jnp.tanh(s / cap)
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqs,bshd->bqhd", p.astype(v_sl.dtype), v_sl)

    out = jax.lax.map(lambda xs: per_q_block(*xs), (jnp.arange(nq), q_blocks))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dh).astype(q.dtype)


def attend(q, k, v, *, causal: bool, window: int = 0, cap: float = 0.0,
           impl: str = "blockwise", q_offset: int = 0) -> jax.Array:
    """Dispatch over implementations. q [B,S,H,D]; k,v [B,Sk,Kh,D]."""
    if impl in ("pallas", "interpret"):
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(
            q, k, v, causal=causal, window=window, cap=cap,
            interpret=(impl == "interpret"))
    if impl == "naive" or q.shape[1] < 8:
        return naive_attention(q, k, v, causal=causal, window=window, cap=cap,
                               q_offset=q_offset)
    if window > 0 and q_offset == 0 and causal:
        return local_attention(q, k, v, window=window, cap=cap)
    return blockwise_attention(q, k, v, causal=causal, cap=cap,
                               q_offset=q_offset)


# ---------------------------------------------------------------------------
# Decode (single new token against a cache) - jnp fallback.
# distributed/decode_attn.py provides the sequence-sharded flash-decoding
# version with the same signature.
# ---------------------------------------------------------------------------

def decode_attend(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                  k_pos: jax.Array, pos: jax.Array, *, window: int = 0,
                  cap: float = 0.0) -> jax.Array:
    """q [B,H,Dh]; caches [B,Sc,Kh,Dh]; k_pos [B,Sc] absolute positions
    (-1 = empty). Returns [B,H,Dh]."""
    g = q.shape[1] // k_cache.shape[2]
    kk, vv = repeat_kv(k_cache, g), repeat_kv(v_cache, g)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhd,bshd->bhs", q, kk).astype(jnp.float32) * scale
    if cap > 0:
        s = cap * jnp.tanh(s / cap)
    valid = (k_pos >= 0) & (k_pos <= pos)
    if window > 0:
        valid &= k_pos > pos - window
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p.astype(vv.dtype), vv)
