"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block: x -> { gate branch: gelu(W_gate x) ;
              rec branch:  conv1d_4(W_in x) -> RG-LRU }
       out = W_out (rglru_out * gate)

RG-LRU (per channel): r_t = sigmoid(BD_a(x_t)); i_t = sigmoid(BD_x(x_t))
  log a_t = -c * softplus(Lambda) * r_t           (c = 8)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)
Gate projections BD_* are block-diagonal with n_heads blocks (as in
RecurrentGemma). Training uses an associative scan over time; the Pallas
kernel (kernels/rglru) is the TPU fast path with identical semantics.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamBuilder, conv1d_channels

Params = Dict[str, Any]
C_RGLRU = 8.0


def init_rglru(pb: ParamBuilder, cfg: ModelConfig) -> None:
    d = cfg.d_model
    w = cfg.rglru.width or d
    nb = cfg.n_heads
    bs = w // nb
    pb.param("w_in", (d, w), (None, "rglru"), init="fan_in")
    pb.param("w_gate", (d, w), (None, "rglru"), init="fan_in")
    pb.param("conv_w", (w, cfg.rglru.conv_width), ("rglru", None),
             init="fan_in")
    pb.param("conv_b", (w,), ("rglru",), init="zeros")
    pb.param("bd_a", (nb, bs, bs), ("rglru_heads", None, None), init="fan_in")
    pb.param("bd_a_bias", (nb, bs), ("rglru_heads", None), init="zeros")
    pb.param("bd_x", (nb, bs, bs), ("rglru_heads", None, None), init="fan_in")
    pb.param("bd_x_bias", (nb, bs), ("rglru_heads", None), init="zeros")
    pb.param("lam", (w,), ("rglru",), init="lru_lambda")
    pb.param("w_out", (w, d), ("rglru", None), init="fan_in")


def _gates(p: Params, xr: jax.Array, nb: int) -> Tuple[jax.Array, jax.Array]:
    """Block-diagonal gate projections. xr: [..., W] -> (log_a, i) in f32."""
    shp = xr.shape
    xb = xr.reshape(shp[:-1] + (nb, shp[-1] // nb))
    r = jnp.einsum("...hb,hbc->...hc", xb, p["bd_a"]) + p["bd_a_bias"]
    i = jnp.einsum("...hb,hbc->...hc", xb, p["bd_x"]) + p["bd_x_bias"]
    r = jax.nn.sigmoid(r.astype(jnp.float32)).reshape(shp)
    i = jax.nn.sigmoid(i.astype(jnp.float32)).reshape(shp)
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    return log_a, i


def rglru_scan(log_a: jax.Array, gated_x: jax.Array) -> jax.Array:
    """Linear recurrence h_t = a_t h_{t-1} + b_t via associative scan.

    log_a, gated_x: [B, S, W] (f32). Returns h: [B, S, W].
    """
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12)) * gated_x

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def apply_rglru(p: Params, x: jax.Array, cfg: ModelConfig,
                state: Optional[Params] = None, impl: str = "jnp",
                return_state: bool = False
                ) -> Tuple[jax.Array, Optional[Params]]:
    """x: [B,S,D]. state (decode): {'h': [B,W], 'conv': [B,K-1,W]}.

    Returns (y [B,S,D], new_state or None).
    """
    nb = cfg.n_heads
    k = cfg.rglru.conv_width
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"]))
    xr = jnp.einsum("bsd,dw->bsw", x, p["w_in"])
    conv_carry = None if state is None else state["conv"]
    new_conv = None
    if state is not None or return_state:
        prev = conv_carry if conv_carry is not None else \
            jnp.zeros(xr.shape[:1] + (k - 1, xr.shape[-1]), xr.dtype)
        new_conv = jnp.concatenate([prev.astype(xr.dtype), xr],
                                   axis=1)[:, -(k - 1):]
    xr = conv1d_channels(xr, p["conv_w"], conv_carry) + p["conv_b"]
    log_a, i = _gates(p, xr, nb)
    gated = i * xr.astype(jnp.float32)

    if state is None:  # training / prefill over full sequence
        if impl in ("pallas", "interpret"):
            from repro.kernels.rglru import ops as rg_ops
            h = rg_ops.rglru(log_a, gated,
                             block=cfg.rglru.block_width,
                             interpret=(impl == "interpret"))
        else:
            h = rglru_scan(log_a, gated)
        new_state = {"h": h[:, -1], "conv": new_conv} if return_state else None
        y = h.astype(x.dtype)
    else:  # single-step decode: S == 1
        a = jnp.exp(log_a[:, 0])
        b = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a[:, 0]), 1e-12)) \
            * gated[:, 0]
        h1 = a * state["h"] + b
        new_state = {"h": h1, "conv": new_conv}
        y = h1[:, None].astype(x.dtype)
    y = jnp.einsum("bsw,wd->bsd", y * gate.astype(y.dtype), p["w_out"])
    return y, new_state


def init_rglru_state(cfg: ModelConfig, batch: int) -> Params:
    w = cfg.rglru.width or cfg.d_model
    k = cfg.rglru.conv_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, k - 1, w), jnp.bfloat16)}
