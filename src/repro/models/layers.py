"""Common layers: norms, RoPE, MLPs, embeddings, and the ParamBuilder.

Parameters are plain nested-dict pytrees. Alongside every param tree we build
a mirror tree of *logical axis* tuples (e.g. ``("embed", "heads", None)``)
which ``distributed/sharding.py`` maps onto mesh axes. This keeps the model
code mesh-free while still giving GSPMD full sharding information.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Specs = Dict[str, Any]


class ParamBuilder:
    """Collects (param, logical-axes) pairs under a PRNG key."""

    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self._key = key
        self.dtype = dtype
        self.params: Params = {}
        self.specs: Specs = {}

    def _next(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, name: str, shape: Tuple[int, ...], axes: Tuple,
              init: str = "normal", scale: float = 0.02) -> jax.Array:
        assert len(axes) == len(shape), (name, shape, axes)
        if init == "normal":
            w = jax.random.normal(self._next(), shape, jnp.float32) * scale
        elif init == "fan_in":
            fan = shape[0] if len(shape) else 1
            w = jax.random.normal(self._next(), shape, jnp.float32)
            w = w / math.sqrt(max(fan, 1))
        elif init == "zeros":
            w = jnp.zeros(shape, jnp.float32)
        elif init == "ones":
            w = jnp.ones(shape, jnp.float32)
        elif init == "lru_lambda":  # RG-LRU Λ init: a in [0.9, 0.999]
            u = jax.random.uniform(self._next(), shape, jnp.float32,
                                   minval=0.9 ** 2, maxval=0.999 ** 2)
            # a = exp(-c*softplus(Λ)); choose Λ s.t. softplus(Λ) = -log(a)/c
            c = 8.0
            sp = -jnp.log(u) / (2.0 * c)  # u = a^2
            w = jnp.log(jnp.expm1(jnp.maximum(sp, 1e-8)))
        elif init == "ssm_a":  # mamba2 A_log init: A in [1, 16]
            u = jax.random.uniform(self._next(), shape, jnp.float32,
                                   minval=1.0, maxval=16.0)
            w = jnp.log(u)
        elif init == "ssm_dt":  # dt bias: softplus^-1 of dt in [1e-3, 1e-1]
            u = jax.random.uniform(self._next(), shape, jnp.float32,
                                   minval=math.log(1e-3), maxval=math.log(1e-1))
            dt = jnp.exp(u)
            w = dt + jnp.log(-jnp.expm1(-dt))
        else:
            raise ValueError(init)
        keep_f32 = init in ("lru_lambda", "ssm_a", "ssm_dt")
        w = w.astype(jnp.float32 if keep_f32 else self.dtype)
        self.params[name] = w
        self.specs[name] = tuple(axes)
        return w

    def child(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(self._next(), self.dtype)
        self.params[name] = sub.params
        self.specs[name] = sub.specs
        return sub

    def stacked(self, name: str, n: int,
                init_fn: Callable[[jax.Array], Tuple[Params, Specs]]) -> None:
        """vmap an init fn over ``n`` keys -> leaves with leading layer dim."""
        keys = jax.random.split(self._next(), n)
        params, specs = init_fn(keys[0])  # specs are static; take from one
        stacked = jax.vmap(lambda k: init_fn(k)[0])(keys)
        self.params[name] = stacked
        self.specs[name] = jax.tree.map(
            lambda s: (None,) + tuple(s), specs,
            is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
             gemma_scale: bool = False) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if gemma_scale \
        else w.astype(jnp.float32)
    return (y * scale).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def init_norm(pb: ParamBuilder, name: str, d: int, kind: str,
              gemma_scale: bool) -> None:
    c = pb.child(name)
    c.param("w", (d,), (None,), init="zeros" if gemma_scale else "ones")
    if kind == "layernorm":
        c.param("b", (d,), (None,), init="zeros")


def apply_norm(p: Params, x: jax.Array, kind: str,
               gemma_scale: bool) -> jax.Array:
    if kind == "layernorm":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"], gemma_scale=gemma_scale)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    sin = jnp.sin(ang)[..., None, :]  # broadcast over heads
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(pb: ParamBuilder, d: int, f: int, kind: str, bias: bool) -> None:
    gated = kind in ("swiglu", "geglu")
    pb.param("w1", (d, f), (None, "mlp"), init="fan_in")
    if gated:
        pb.param("w3", (d, f), (None, "mlp"), init="fan_in")
    pb.param("w2", (f, d), ("mlp", None), init="fan_in")
    if bias:
        pb.param("b1", (f,), ("mlp",), init="zeros")
        pb.param("b2", (d,), (None,), init="zeros")


def apply_mlp(p: Params, x: jax.Array, kind: str) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["w1"])
    if "b1" in p:
        h = h + p["b1"]
    if kind == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("...d,df->...f", x, p["w3"])
    elif kind == "geglu":
        h = jax.nn.gelu(h) * jnp.einsum("...d,df->...f", x, p["w3"])
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(kind)
    y = jnp.einsum("...f,fd->...d", h, p["w2"])
    if "b2" in p:
        y = y + p["b2"]
    return y


# ---------------------------------------------------------------------------
# Embeddings (vocab padded to a TP-friendly multiple; untied in/out)
# ---------------------------------------------------------------------------

def init_embeddings(pb: ParamBuilder, vocab_padded: int, d: int) -> None:
    # both tables vocab-sharded; GSPMD lowers the in_embed gather to masked
    # local lookups + all-reduce (col-sharding trips the SPMD partitioner
    # under remat+scan on this XLA version).
    pb.param("in_embed", (vocab_padded, d), ("vocab", None),
             init="normal", scale=0.02)
    pb.param("out_embed", (d, vocab_padded), (None, "vocab"), init="fan_in")


def embed_tokens(p: Params, tokens: jax.Array) -> jax.Array:
    return p["in_embed"][tokens]


def conv1d_channels(x: jax.Array, w: jax.Array,
                    carry: Optional[jax.Array] = None) -> jax.Array:
    """Causal depthwise temporal conv. x: [B, S, C]; w: [C, K].

    With ``carry`` [B, K-1, C] (previous tokens) prepended; else zero-pad.
    """
    k = w.shape[-1]
    if carry is None:
        pad = jnp.zeros(x.shape[:-2] + (k - 1, x.shape[-1]), x.dtype)
    else:
        pad = carry.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=-2)  # [B, S+K-1, C]
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[..., i:i + x.shape[-2], :] * w[:, i]
    return out
