"""Mamba2 SSD (state-space duality) blocks.

Block: in_proj -> (z, x, B, C, dt); causal conv over (x,B,C); SSD scan;
gated RMSNorm; out_proj. The SSD scan is the chunked algorithm from
arXiv:2405.21060 (intra-chunk quadratic term + inter-chunk state
recurrence); kernels/ssd provides the Pallas fast path.

Shapes: x [B,S,H,P], dt [B,S,H], A [H] (negative), B/C [B,S,G,N] (G groups
broadcast over heads).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamBuilder, conv1d_channels, rms_norm

Params = Dict[str, Any]


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    n_heads = d_inner // sc.head_dim
    return d_inner, n_heads, sc.head_dim, sc.n_groups, sc.d_state


def init_ssd(pb: ParamBuilder, cfg: ModelConfig) -> None:
    d = cfg.d_model
    d_inner, h, p_, g, n = ssm_dims(cfg)
    cw = cfg.ssm.conv_width
    pb.param("wz", (d, h, p_), (None, "ssm_heads", None), init="fan_in")
    pb.param("wx", (d, h, p_), (None, "ssm_heads", None), init="fan_in")
    pb.param("wbc", (d, 2 * g * n), (None, None), init="fan_in")
    pb.param("wdt", (d, h), (None, "ssm_heads"), init="fan_in")
    pb.param("conv_x", (d_inner, cw), ("ssm_flat", None), init="fan_in")
    pb.param("conv_bc", (2 * g * n, cw), (None, None), init="fan_in")
    pb.param("a_log", (h,), ("ssm_heads",), init="ssm_a")
    pb.param("d_skip", (h,), ("ssm_heads",), init="ones")
    pb.param("dt_bias", (h,), ("ssm_heads",), init="ssm_dt")
    pb.param("norm_w", (h, p_), ("ssm_heads", None), init="ones")
    pb.param("w_out", (h, p_, d), ("ssm_heads", None, None), init="fan_in")


def _segsum(log_a: jax.Array) -> jax.Array:
    """log_a [..., Q] -> L [..., Q, Q] with L[i,j] = sum_{k=j+1..i} log_a_k
    for i>=j, else -inf."""
    q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # [., i, j] = cs_i - cs_j
    mask = jnp.arange(q)[:, None] >= jnp.arange(q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, chunk: int,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x [B,S,H,P]; dt [B,S,H] (f32, post-softplus); a [H] (negative, f32);
    b,c [B,S,G,N]; h0 optional initial state [B,H,P,N].
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    B_, S, H, P = x.shape
    G, N = b.shape[-2], b.shape[-1]
    q = min(chunk, S)
    s_orig = S
    if S % q:  # pad tail: dt=0 rows are exact no-ops (decay 1, contribution 0)
        pad = q - S % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // q
    rep = H // G
    dtype = x.dtype

    da = dt * a  # [B,S,H] negative decay logs
    xdt = x * dt[..., None].astype(dtype)

    xc = xdt.reshape(B_, nc, q, H, P)
    dac = da.reshape(B_, nc, q, H)
    bc_ = b.reshape(B_, nc, q, G, N)
    cc = c.reshape(B_, nc, q, G, N)
    bh = jnp.repeat(bc_, rep, axis=-2)  # [B,nc,q,H,N]
    ch = jnp.repeat(cc, rep, axis=-2)

    # --- intra-chunk (quadratic within chunk) ---
    L = jnp.exp(_segsum(dac.transpose(0, 1, 3, 2)))  # [B,nc,H,q,q]
    scores = jnp.einsum("bciht,bcjht->bchij", ch, bh,
                        preferred_element_type=jnp.float32)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp",
                         (scores * L).astype(dtype), xc)

    # --- chunk summaries: state contribution of each chunk ---
    cs = jnp.cumsum(dac, axis=2)  # [B,nc,q,H]
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)  # [B,nc,q,H]
    states = jnp.einsum("bcqht,bcqhp->bchpt",
                        (bh * decay_to_end[..., None]).astype(dtype), xc)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(jnp.sum(dac, axis=2))  # [B,nc,H]

    def step(carry, xs):
        st, dec = xs
        new = carry * dec[..., None, None].astype(carry.dtype) + \
            st.astype(carry.dtype)
        return new, carry  # emit state *before* this chunk

    init = jnp.zeros((B_, H, P, N), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    # --- inter-chunk output: y_i += C_i . (decay_in * prev_state) ---
    decay_in = jnp.exp(cs)  # [B,nc,q,H]
    y_inter = jnp.einsum("bcqht,bchpt->bcqhp",
                         (ch * decay_in[..., None]).astype(dtype),
                         prev_states.astype(dtype))
    y = (y_intra + y_inter).reshape(B_, S, H, P)[:, :s_orig]
    return y, final


def ssd_decode_step(h: jax.Array, x: jax.Array, dt: jax.Array, a: jax.Array,
                    b: jax.Array, c: jax.Array, d_skip: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD update. h [B,H,P,N]; x [B,H,P]; dt [B,H];
    b,c [B,G,N]. Returns (y [B,H,P], h_new)."""
    G = b.shape[-2]
    rep = h.shape[1] // G
    bh = jnp.repeat(b, rep, axis=-2)  # [B,H,N]
    ch = jnp.repeat(c, rep, axis=-2)
    decay = jnp.exp(dt * a)  # [B,H]
    upd = jnp.einsum("bhp,bhn->bhpn", x * dt[..., None].astype(x.dtype),
                     bh.astype(x.dtype))
    h_new = h * decay[..., None, None].astype(h.dtype) + upd.astype(h.dtype)
    y = jnp.einsum("bhpn,bhn->bhp", h_new.astype(x.dtype), ch.astype(x.dtype))
    y = y + x * d_skip[:, None].astype(x.dtype)
    return y, h_new


def apply_ssd(p: Params, xin: jax.Array, cfg: ModelConfig,
              state: Optional[Params] = None, impl: str = "jnp",
              return_state: bool = False
              ) -> Tuple[jax.Array, Optional[Params]]:
    """xin [B,S,D]. state (decode): {'h': [B,H,P,N], 'conv': [B,K-1,Cc]}."""
    d_inner, H, P, G, N = ssm_dims(cfg)
    B_, S, _ = xin.shape
    cw = cfg.ssm.conv_width
    z = jnp.einsum("bsd,dhp->bshp", xin, p["wz"])
    x = jnp.einsum("bsd,dhp->bshp", xin, p["wx"]).reshape(B_, S, d_inner)
    bcb = jnp.einsum("bsd,dc->bsc", xin, p["wbc"])
    dt_raw = jnp.einsum("bsd,dh->bsh", xin, p["wdt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    conv_in = jnp.concatenate([x, bcb], axis=-1)  # [B,S,Cc]
    conv_w = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=0)
    carry = None if state is None else state["conv"]
    new_conv = None
    if state is not None or return_state:
        prev = carry if carry is not None else \
            jnp.zeros((B_, cw - 1, conv_in.shape[-1]), conv_in.dtype)
        new_conv = jnp.concatenate([prev.astype(conv_in.dtype), conv_in],
                                   axis=1)[:, -(cw - 1):]
    conv_out = jax.nn.silu(conv1d_channels(conv_in, conv_w, carry))
    x = conv_out[..., :d_inner].reshape(B_, S, H, P)
    b = conv_out[..., d_inner:d_inner + G * N].reshape(B_, S, G, N)
    c = conv_out[..., d_inner + G * N:].reshape(B_, S, G, N)

    if state is None:
        if impl in ("pallas", "interpret"):
            from repro.kernels.ssd import ops as ssd_ops
            y, h_fin = ssd_ops.ssd(x, dt, a, b, c, chunk=cfg.ssm.chunk_size,
                                   interpret=(impl == "interpret"))
        else:
            y, h_fin = ssd_chunked(x, dt, a, b, c, cfg.ssm.chunk_size)
        y = y + x * p["d_skip"].astype(x.dtype)[:, None]
        new_state = {"h": h_fin, "conv": new_conv} if return_state else None
    else:
        y1, h_new = ssd_decode_step(state["h"], x[:, 0], dt[:, 0], a,
                                    b[:, 0], c[:, 0], p["d_skip"])
        y = y1[:, None]
        new_state = {"h": h_new, "conv": new_conv}

    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y.reshape(B_, -1, H * P),
                 p["norm_w"].reshape(-1)).reshape(y.shape)
    out = jnp.einsum("bshp,hpd->bsd", y, p["w_out"])
    return out, new_state


def init_ssd_state(cfg: ModelConfig, batch: int) -> Params:
    d_inner, H, P, G, N = ssm_dims(cfg)
    cc = d_inner + 2 * G * N
    return {"h": jnp.zeros((batch, H, P, N), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, cc),
                              jnp.bfloat16)}
