"""Pipeline parallelism over the 'pod' axis (optional role, GPipe schedule).

The multi-pod mesh's "pod" axis defaults to data-parallel; this module lets
it act as a pipeline axis instead: layer groups are stacked [n_stages, ...]
and sharded P('pod'); microbatches stream through stages with
collective_permute handoffs. Fill/drain bubbles are the standard
(n_stages - 1) / (n_micro + n_stages - 1) fraction.

This is exercised by tests/benchmarks as a scaling option; the default
dry-run keeps pod = DP (see DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from repro.distributed.compat import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.distributed.mesh import batch_axes


def pipeline_apply(mesh: Mesh, stage_fn: Callable, stage_params,
                   x_micro: jax.Array, axis: str = "pod") -> jax.Array:
    """Run ``stage_fn(params_stage, x) -> x`` over pipeline stages.

    stage_params: pytree with leading [n_stages] dim (sharded on ``axis``).
    x_micro: [n_micro, mb, ...] microbatched activations (replicated on
    ``axis``). Returns [n_micro, mb, ...] outputs of the LAST stage.
    """
    n_stages = mesh.shape[axis]
    other = tuple(a for a in mesh.axis_names if a != axis)

    def inner(params, xm):
        # params: leading dim 1 (my stage); xm [n_micro, mb, ...] replicated
        my_params = jax.tree.map(lambda a: a[0], params)
        sid = jax.lax.axis_index(axis)
        n_micro = xm.shape[0]
        total = n_micro + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        buf = jnp.zeros_like(xm[0])
        outs = jnp.zeros_like(xm)

        def step(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range)
            take = jnp.clip(t, 0, n_micro - 1)
            buf = jnp.where(sid == 0, xm[take], buf)
            y = stage_fn(my_params, buf)
            # last stage emits microbatch t - (n_stages - 1)
            emit_idx = t - (n_stages - 1)
            do_emit = (sid == n_stages - 1) & (emit_idx >= 0)
            outs = jax.lax.cond(
                do_emit,
                lambda o: jax.lax.dynamic_update_slice_in_dim(
                    o, y[None], jnp.maximum(emit_idx, 0), axis=0),
                lambda o: o, outs)
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(step, (buf, outs), jnp.arange(total))
        # broadcast last stage's outputs to all stages for a clean out_spec
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params,
                             is_leaf=lambda x: False), P())
    fn = shard_map(
        inner, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params), P()),
        out_specs=P(),
        check_vma=False)
    return fn(stage_params, x_micro)
