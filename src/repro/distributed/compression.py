"""Compressed data-parallel gradient reduction with error feedback.

XLA's all-reduce cannot run a custom reduction on quantized payloads, so the
classic "int8 ring all-reduce" is decomposed the way production JAX stacks
do it: reduce_scatter in bf16 (the arithmetic part) + QUANTIZED all_gather
(the broadcast part, int8 + per-block f32 scales = ~4x fewer broadcast
bytes), with persistent error-feedback on the quantization residual so the
bias vanishes over steps. Wire bytes drop from 2N to N + N/4 (~1.8x);
the collective-roofline win shows up directly in the dry-run HLO.

Used by train/train_step.py when ParallelConfig.grad_compression == "int8ef".
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.compat import axis_size

BLOCK = 1024


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x [n] f32 -> (q int8 [n], scales f32 [n/BLOCK])."""
    xb = x.reshape(-1, BLOCK)
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-20)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale.reshape(-1)


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.reshape(-1, BLOCK).astype(jnp.float32) *
            scale[:, None]).reshape(-1)


def compressed_psum_scatter_gather(x: jax.Array, axis: str,
                                   err: jax.Array
                                   ) -> Tuple[jax.Array, jax.Array]:
    """Inside shard_map: mean-reduce ``x`` [n] over ``axis`` with int8
    compressed broadcast + error feedback state ``err`` [n/devices].

    Returns (reduced [n], new_err). n must divide (devices * BLOCK).
    """
    nd = axis_size(axis)
    # 1) bf16 reduce_scatter: each device owns n/nd reduced elements
    shard = jax.lax.psum_scatter(x.astype(jnp.bfloat16), axis,
                                 scatter_dimension=0, tiled=True)
    shard = shard.astype(jnp.float32) / nd + err
    # 2) int8 quantize + all_gather (compressed broadcast)
    q, scale = _quantize(shard)
    deq = _dequantize(q, scale)
    new_err = shard - deq
    qg = jax.lax.all_gather(q, axis, axis=0, tiled=True)
    sg = jax.lax.all_gather(scale, axis, axis=0, tiled=True)
    return _dequantize(qg, sg), new_err


def init_error_state(n: int, devices: int) -> jax.Array:
    assert n % (devices * BLOCK) == 0
    return jnp.zeros((n // devices,), jnp.float32)
