"""JAX version-compat shims for the distributed layer.

The repo targets the modern spelling (``jax.shard_map`` with a
``check_vma`` kwarg) but must also run on JAX 0.4.x, where shard_map
lives in ``jax.experimental.shard_map`` and the replication-check kwarg
is named ``check_rep``. This module resolves both at import time so call
sites can use one spelling everywhere.
"""
from __future__ import annotations

import inspect

import jax

try:  # JAX >= 0.5: top-level export
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # JAX 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = inspect.signature(_shard_map).parameters
_HAS_CHECK_VMA = "check_vma" in _PARAMS
_HAS_CHECK_REP = "check_rep" in _PARAMS


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """``jax.shard_map`` across JAX versions.

    ``check_vma`` maps onto the old ``check_rep`` kwarg when running on
    0.4.x; both mean "verify per-device replication of outputs".
    """
    if check_vma is not None:
        if _HAS_CHECK_VMA:
            kwargs["check_vma"] = check_vma
        elif _HAS_CHECK_REP:
            kwargs["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def axis_size(axis_name):
    """Static size of a mapped mesh axis, inside shard_map bodies.

    ``jax.lax.axis_size`` only exists on newer JAX; ``psum(1, axis)`` is
    the portable spelling and constant-folds to a static int at trace
    time on 0.4.x.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
