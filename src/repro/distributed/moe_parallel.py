"""MoE expert-(tensor-)parallelism over the single 'model' mesh axis.

Virtual-slot scheme (DESIGN.md §4): slots = tp; slot ``s`` owns expert group
``s // inner`` and FFN-hidden shard ``s % inner`` with
``inner = max(1, tp // n_experts)``. Only *full-axis* collectives are needed
(subgroup psum is unsupported in shard_map): one all_to_all dispatches
tokens, and the inner-TP partial down-projections are summed with an
(inner-1)-step ppermute ring.

Two execution paths share router/dispatch semantics with models/moe.py:

  make_moe_etp        - training/prefill: tokens are sequence-sharded over
                        'model'; dispatch is gather/scatter-based (no
                        one-hot einsum blowup); all_to_all to expert owners.
  make_moe_replicated - decode: token count is tiny, so tokens stay
                        replicated over 'model'; every shard computes its
                        expert group's contribution and one psum combines
                        groups and inner F-shards simultaneously
                        (zero all_to_all on the latency-critical path).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from repro.distributed.compat import axis_size, shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.mesh import batch_axes, model_axis_size
from repro.models.moe import load_balance_loss, make_moe_layout, router_probs


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _route_and_slot(p, x_flat, cfg: ModelConfig, cap: int):
    """Shared routing: returns (slot [T*k], keep [T*k], gates_flat [T*k],
    aux). slot = expert_id * cap + rank-within-expert."""
    gates, ids, probs = router_probs(p, x_flat, cfg)
    e = cfg.moe.n_experts
    t, k = ids.shape
    ids_flat = ids.reshape(-1)
    gates_flat = gates.reshape(-1).astype(jnp.float32)
    order = jnp.argsort(ids_flat, stable=True)
    sorted_ids = ids_flat[order]
    ranks_sorted = jnp.arange(t * k) - jnp.searchsorted(sorted_ids,
                                                        sorted_ids, "left")
    ranks = jnp.zeros((t * k,), jnp.int32).at[order] \
        .set(ranks_sorted.astype(jnp.int32))
    keep = ranks < cap
    slot = ids_flat * cap + jnp.minimum(ranks, cap - 1)
    aux = load_balance_loss(probs, ids, e)
    return slot, keep, gates_flat, aux


def _dispatch(x_flat, slot, keep, e: int, cap: int):
    """Scatter tokens into [E, cap, D] capacity buffer (dropped -> zero)."""
    d = x_flat.shape[-1]
    src = jnp.where(keep, slot, e * cap)  # dropped rows -> overflow slot
    buf = jnp.zeros((e * cap + 1, d), x_flat.dtype)
    tk = slot.shape[0]
    t = x_flat.shape[0]
    k = tk // t
    xk = jnp.repeat(x_flat, k, axis=0)  # choice j of token t at row t*k+j
    buf = buf.at[src].set(xk)  # duplicate experts per token get one copy each
    return buf[:-1].reshape(e, cap, d)


def _combine(y_buf, slot, keep, gates_flat, t: int):
    """Gather expert outputs back to tokens with gate weighting."""
    d = y_buf.shape[-1]
    flat = y_buf.reshape(-1, d)
    y = flat[slot] * (gates_flat * keep)[:, None].astype(flat.dtype)
    return y.reshape(t, -1, d).sum(axis=1)


def _expert_ffn(recv, wi, wg, wo):
    """recv [..., D] batched over leading expert dims; w* [el, D, Fl]."""
    h = jnp.einsum("...ecd,edf->...ecf", recv, wi)
    g = jnp.einsum("...ecd,edf->...ecf", recv, wg)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    return jnp.einsum("...ecf,efd->...ecd", h, wo)


def make_moe_etp(mesh: Mesh):
    """Sequence-sharded ETP path. Signature: (params, x [B,S,D], cfg)
    -> (y [B,S,D], aux)."""
    batch = batch_axes(mesh) or None
    tp = model_axis_size(mesh)
    all_axes = tuple(mesh.axis_names)

    def moe_fn(p, x, cfg: ModelConfig):
        layout = make_moe_layout(cfg, tp)
        e = cfg.moe.n_experts
        groups, inner, el = layout.groups, layout.inner, layout.e_loc

        def inner_fn(xl, router, wi, wg, wo):
            b_loc, s_loc, d = xl.shape
            t = b_loc * s_loc
            x_flat = xl.reshape(t, d)
            cap = _round_up(max(1, int(t * cfg.moe.top_k *
                                       cfg.moe.capacity_factor / e)), 8)
            slot, keep, gates_flat, aux = _route_and_slot(
                {"router": router}, x_flat, cfg, cap)
            x_disp = _dispatch(x_flat, slot, keep, e, cap)  # [E, cap, D]
            xg = x_disp.reshape(groups, el, cap, d)
            if inner > 1:  # replicate each group's tokens to its F-shards
                xg = jnp.broadcast_to(xg[:, None], (groups, inner, el, cap, d))
            x_send = xg.reshape(tp, el, cap, d)
            if tp > 1:
                recv = jax.lax.all_to_all(x_send, "model", split_axis=0,
                                          concat_axis=0, tiled=True)
            else:
                recv = x_send
            # recv [tp(src), el, cap, D]; FFN with my F-shard
            y_part = _expert_ffn(recv, wi[0], wg[0], wo[0])
            if inner > 1:  # ring-sum partial down-projections within group
                acc = y_part
                for sigma in range(1, inner):
                    perm = [(s, (s // inner) * inner + (s % inner + sigma)
                             % inner) for s in range(tp)]
                    acc = acc + jax.lax.ppermute(y_part, "model", perm)
                y_part = acc
            if tp > 1:
                back = jax.lax.all_to_all(y_part, "model", split_axis=0,
                                          concat_axis=0, tiled=True)
            else:
                back = y_part
            # back [tp(slot), el, cap, D]; group g data identical across its
            # inner slots -> read the r==0 copy.
            y_buf = back.reshape(groups, inner, el, cap, d)[:, 0] \
                .reshape(e, cap, d)
            y = _combine(y_buf, slot, keep, gates_flat, t)
            aux = jax.lax.pmean(aux, all_axes)
            return y.reshape(b_loc, s_loc, d), aux

        fn = shard_map(
            inner_fn, mesh=mesh,
            in_specs=(P(batch, "model", None), P(None, None),
                      P("model", None, None, None),
                      P("model", None, None, None),
                      P("model", None, None, None)),
            out_specs=(P(batch, "model", None), P()),
            check_vma=False)
        return fn(x, p["router"], p["wi"], p["wg"], p["wo"])

    return moe_fn


def make_moe_etp2d(mesh: Mesh):
    """2D expert-parallel training path (perf iteration 5): expert slots
    span model x data (slots = tp*dp), so the weights are FULLY RESIDENT —
    no FSDP re-gathers per layer/microbatch. Tokens travel instead: a
    two-hop all_to_all (over 'model', then over each batch axis) routes
    capacity blocks to the owning slot; partial down-projections from
    inner F-shards ring-sum with a ppermute over the innermost batch axis.
    """
    baxes = batch_axes(mesh)
    tp = model_axis_size(mesh)
    all_axes = tuple(mesh.axis_names)
    dp = 1
    for a in baxes:
        dp *= mesh.shape[a]

    def moe_fn(p, x, cfg: ModelConfig):
        layout = make_moe_layout(cfg, tp, dp)
        e = cfg.moe.n_experts
        slots, inner, el = layout.slots, layout.inner, layout.e_loc
        groups = layout.groups
        last_ax = baxes[-1]
        last_n = mesh.shape[last_ax]
        assert last_n % inner == 0, (last_n, inner)

        def inner_fn(xl, router, wi, wg, wo):
            b_loc, s_loc, d = xl.shape
            t = b_loc * s_loc
            x_flat = xl.reshape(t, d)
            cap = _round_up(max(1, int(t * cfg.moe.top_k *
                                       cfg.moe.capacity_factor / e)), 4)
            slot, keep, gates_flat, aux = _route_and_slot(
                {"router": router}, x_flat, cfg, cap)
            x_disp = _dispatch(x_flat, slot, keep, e, cap)  # [E, cap, D]
            xg = x_disp.reshape(groups, el, cap, d)
            if inner > 1:
                xg = jnp.broadcast_to(xg[:, None],
                                      (groups, inner, el, cap, d))
            x_send = xg.reshape(slots, el, cap, d)

            def hops(z, reverse=False):
                # dims: [tp, *batch_axis_sizes, el, cap, d]
                z = z.reshape((tp,) + tuple(mesh.shape[a] for a in baxes)
                              + (el, cap, d))
                seq = [("model", 0)] + [(a, 1 + i)
                                        for i, a in enumerate(baxes)]
                for ax, dim in (reversed(seq) if reverse else seq):
                    z = jax.lax.all_to_all(z, ax, split_axis=dim,
                                           concat_axis=dim, tiled=True)
                return z.reshape(slots, el, cap, d)

            recv = hops(x_send)
            y_part = _expert_ffn(recv, wi[0, 0], wg[0, 0], wo[0, 0])
            if inner > 1:  # ring-sum F-shard partials (same-group slots
                # are consecutive in the innermost batch axis)
                acc = y_part
                for sigma in range(1, inner):
                    perm = [(i, (i // inner) * inner +
                             (i % inner + sigma) % inner)
                            for i in range(last_n)]
                    acc = acc + jax.lax.ppermute(y_part, last_ax, perm)
                y_part = acc
            back = hops(y_part, reverse=True)
            y_buf = back.reshape(groups, inner, el, cap, d)[:, 0] \
                .reshape(e, cap, d)
            y = _combine(y_buf, slot, keep, gates_flat, t)
            aux = jax.lax.pmean(aux, all_axes)
            return y.reshape(b_loc, s_loc, d), aux

        w_spec = P("model", baxes if len(baxes) > 1 else baxes[0],
                   None, None, None)
        fn = shard_map(
            inner_fn, mesh=mesh,
            in_specs=(P(baxes if len(baxes) > 1 else baxes[0], "model",
                        None),
                      P(None, None), w_spec, w_spec, w_spec),
            out_specs=(P(baxes if len(baxes) > 1 else baxes[0], "model",
                         None), P()),
            check_vma=False)
        return fn(x, p["router"], p["wi"], p["wg"], p["wo"])

    return moe_fn


def make_moe_replicated(mesh: Mesh, expert_2d: bool = False):
    """Decode path: tokens replicated over 'model'; one psum combines expert
    groups and inner F-shards.

    expert_2d (perf iteration 3, EXPERIMENTS.md §Perf): additionally shard
    the experts' FFN hidden dim over the *data* axes so giant MoEs
    (arctic/grok) stay fully resident — no per-token FSDP all-gather of
    expert weights. Tokens (tiny at decode) are all-gathered over the data
    axes instead, and the final psum runs over every mesh axis at once,
    folding expert-group, inner-TP, and data-F partial sums together.
    """
    batch = batch_axes(mesh) or None
    baxes = batch_axes(mesh)
    tp = model_axis_size(mesh)
    all_axes = tuple(mesh.axis_names)
    dp = 1
    for a in baxes:
        dp *= mesh.shape[a]

    def moe_fn(p, x, cfg: ModelConfig):
        layout = make_moe_layout(cfg, tp)
        e = cfg.moe.n_experts
        groups, inner, el = layout.groups, layout.inner, layout.e_loc
        use_2d = expert_2d and dp > 1 and layout.f_loc % dp == 0 and \
            x.shape[0] % dp == 0

        def inner_fn(xl, router, wi, wg, wo):
            b_loc, s, d = xl.shape
            xg = xl
            if use_2d:  # gather the (tiny) token batch across data axes
                for ax in baxes:
                    xg = jax.lax.all_gather(xg, ax, axis=0, tiled=True)
            b_tot = xg.shape[0]
            t = b_tot * s
            x_flat = xg.reshape(t, d)
            cap = _round_up(max(1, int(t * cfg.moe.top_k *
                                       cfg.moe.capacity_factor / e)), 4)
            slot, keep, gates_flat, aux = _route_and_slot(
                {"router": router}, x_flat, cfg, cap)
            x_disp = _dispatch(x_flat, slot, keep, e, cap)  # [E, cap, D]
            g_idx = jax.lax.axis_index("model") // inner if tp > 1 else 0
            x_mine = jax.lax.dynamic_slice_in_dim(
                x_disp.reshape(groups, el, cap, d), g_idx, 1, axis=0)[0]
            y_part = _expert_ffn(x_mine[None], wi[0], wg[0], wo[0])[0]
            # place my experts' outputs into the full [E, cap, D] frame
            y_all = jnp.zeros((groups, el, cap, d), y_part.dtype)
            y_all = jax.lax.dynamic_update_slice_in_dim(
                y_all, y_part[None], g_idx, axis=0).reshape(e, cap, d)
            y_tok = _combine(y_all, slot, keep, gates_flat, t)
            if use_2d:
                y_tok = jax.lax.psum(y_tok, all_axes)
                # slice my batch rows back out
                idx = jnp.int32(0)
                stride = b_tot
                for ax in baxes:
                    stride = stride // axis_size(ax)
                    idx = idx + jax.lax.axis_index(ax) * stride
                y_tok = jax.lax.dynamic_slice_in_dim(
                    y_tok.reshape(b_tot, s, d), idx, b_loc, axis=0)
                aux = jax.lax.pmean(aux, all_axes)
                return y_tok, aux
            if tp > 1:
                y_tok = jax.lax.psum(y_tok, "model")
            aux = jax.lax.pmean(aux, all_axes)
            return y_tok.reshape(b_loc, s, d), aux

        w_spec = P("model", None, None, batch) if use_2d else \
            P("model", None, None, None)
        wo_spec = P("model", None, batch, None) if use_2d else \
            P("model", None, None, None)
        fn = shard_map(
            inner_fn, mesh=mesh,
            in_specs=(P(batch, None, None), P(None, None),
                      w_spec, w_spec, wo_spec),
            out_specs=(P(batch, None, None), P()),
            check_vma=False)
        return fn(x, p["router"], p["wi"], p["wg"], p["wo"])

    return moe_fn
