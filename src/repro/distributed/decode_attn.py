"""Flash-decoding: sequence-sharded KV-cache attention for serve_step.

The KV cache is sharded [batch -> data axes, seq -> model]; the new token's
query (tiny) is replicated across the model axis. Every model shard computes
partial attention (m, l, o) over its KV slice for *all* Q heads, the partials
are combined with a pmax/psum log-sum-exp, and the new token's K/V is written
only by the ring-slot-owning shard. This is what makes decode cells shardable
even with 1-8 KV heads (head-sharding alone cannot use tp=16), and it turns
the decode bottleneck into a single small psum instead of a KV all-gather.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from repro.distributed.compat import axis_size, shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.distributed.mesh import batch_axes
from repro.models.attention import NEG_INF, repeat_kv


def _partial_attend(q, kc, vc, kp, pos, window, cap):
    """Local partial attention. q [B,H,D]; kc/vc [B,Sloc,Kh,D]; kp [B,Sloc].
    Returns (o [B,H,D] f32, m [B,H], l [B,H])."""
    g = q.shape[1] // kc.shape[2]
    kk, vv = repeat_kv(kc, g), repeat_kv(vc, g)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhd,bshd->bhs", q, kk).astype(jnp.float32) * scale
    if cap > 0:
        s = cap * jnp.tanh(s / cap)
    valid = (kp >= 0) & (kp <= pos)
    if window > 0:
        valid &= kp > pos - window
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - jnp.maximum(m, -1e30)[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bhs,bshd->bhd", p, vv.astype(jnp.float32))
    return o, m, l


def make_flash_decode(mesh: Mesh):
    """Build the decode-attention fn with the jnp-fallback signature:
    (k_cache, v_cache, kpos, k_new, v_new, q, pos, *, window, cap)
    -> (o [B,H,D], {'k','v','kpos'})."""
    batch = batch_axes(mesh) or None
    has_model = "model" in mesh.axis_names and mesh.shape["model"] > 1

    dp = 1
    if batch:
        for a in (batch if isinstance(batch, tuple) else (batch,)):
            dp *= mesh.shape[a]

    def flash_decode(k_cache, v_cache, kpos, k_new, v_new, q, pos, *,
                     window: int, cap: float):
        write = k_new is not None
        b = k_cache.shape[0]
        bspec = batch if (batch and b % dp == 0) else None
        seq_ok = has_model and k_cache.shape[1] % mesh.shape["model"] == 0
        sspec = "model" if seq_ok else None

        def inner(kc, vc, kp, q_, pos_, *new):
            sc_loc = kc.shape[1]
            if seq_ok:
                midx = jax.lax.axis_index("model")
                nshard = axis_size("model")
            else:
                midx, nshard = 0, 1
            if write:
                kn, vn = new
                slot = pos_ % (sc_loc * nshard)   # global ring slot
                local = slot % sc_loc
                own = (slot // sc_loc) == midx
                # in-place-friendly masked write: read the current row,
                # select, DUS back (no full-buffer select).
                cur_k = jax.lax.dynamic_slice(
                    kc, (0, local, 0, 0), (kc.shape[0], 1) + kc.shape[2:])
                cur_v = jax.lax.dynamic_slice(
                    vc, (0, local, 0, 0), (vc.shape[0], 1) + vc.shape[2:])
                cur_p = jax.lax.dynamic_slice(kp, (0, local),
                                              (kp.shape[0], 1))
                kn_w = jnp.where(own, kn[:, None].astype(kc.dtype), cur_k)
                vn_w = jnp.where(own, vn[:, None].astype(vc.dtype), cur_v)
                kp_w = jnp.where(own, jnp.broadcast_to(
                    pos_, (kp.shape[0], 1)).astype(kp.dtype), cur_p)
                kc = jax.lax.dynamic_update_slice(kc, kn_w, (0, local, 0, 0))
                vc = jax.lax.dynamic_update_slice(vc, vn_w, (0, local, 0, 0))
                kp = jax.lax.dynamic_update_slice(kp, kp_w, (0, local))
            o, m, l = _partial_attend(q_, kc, vc, kp, pos_, window, cap)
            if seq_ok:
                m_g = jax.lax.pmax(m, "model")
                corr = jnp.exp(jnp.maximum(m, -1e30) -
                               jnp.maximum(m_g, -1e30))
                l_g = jax.lax.psum(l * corr, "model")
                o_g = jax.lax.psum(o * corr[..., None], "model")
            else:
                l_g, o_g = l, o
            out = (o_g / jnp.maximum(l_g, 1e-30)[..., None])
            return out.astype(q_.dtype), kc, vc, kp

        kv_spec = P(bspec, sspec, None, None)
        kp_spec = P(bspec, sspec)
        new_specs = (P(bspec, None, None),) * 2 if write else ()
        fn = shard_map(
            inner, mesh=mesh,
            in_specs=(kv_spec, kv_spec, kp_spec, P(bspec, None, None), P())
            + new_specs,
            out_specs=(P(bspec, None, None), kv_spec, kv_spec, kp_spec),
            check_vma=False)
        args = (k_cache, v_cache, kpos, q, pos) + \
            ((k_new, v_new) if write else ())
        o, kc, vc, kp = fn(*args)
        return o, {"k": kc, "v": vc, "kpos": kp}

    return flash_decode
