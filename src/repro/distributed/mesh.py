"""Mesh axis conventions.

Production meshes (launch/mesh.py): single-pod (16,16)=("data","model"),
multi-pod (2,16,16)=("pod","data","model"). "pod" defaults to an extra
data-parallel axis; distributed/pipeline.py can repurpose it as a pipeline
axis. Everything here is mesh-shape agnostic (smoke tests use tiny meshes).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that shard the batch dimension (every non-'model' axis)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
