"""Sharding plans: logical param axes -> mesh PartitionSpecs + ModelRuntime.

``models/*`` annotate every param/cache leaf with logical axis names; this
module is the single place where those names meet the mesh. It also builds
the ``ModelRuntime`` injection (sharding-constraint hook, flash-decoding
attention, ETP MoE) for a given (config, shape, mesh).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import (DECODE, ModelConfig, ParallelConfig,
                                ShapeConfig)
from repro.distributed.mesh import batch_axes, model_axis_size

# logical axis name -> mesh axis ("__batch__" resolves to the batch axes)
LOGICAL_RULES: Dict[Optional[str], Optional[str]] = {
    None: None,
    "vocab": "model",
    "embed_shard": "model",       # in_embed d_model dim (local-gather lookup)
    "heads": "model",
    "mlp": "model",
    "rglru": "model",
    "rglru_heads": "model",
    "ssm_heads": "model",
    "ssm_flat": "model",
    "expert_slots": "model",
    "kv_batch": "__batch__",
    "kv_seq": "model",
    "zero_flat": "__all__",       # flattened optimizer blocks: all axes
    "expert_slots_dp": "__batch__",  # 2D expert parallelism (training)
}


def spec_to_pspec(spec: Tuple, mesh: Mesh,
                  overrides: Optional[Dict[str, str]] = None) -> P:
    axes = []
    for name in spec:
        tgt = (overrides or {}).get(name, LOGICAL_RULES.get(name))
        if tgt == "__batch__":
            axes.append(batch_axes(mesh) or None)
        elif tgt == "__all__":
            axes.append(tuple(a for a in mesh.axis_names
                              if mesh.shape[a] > 1) or None)
        elif tgt is not None and tgt in mesh.axis_names and \
                mesh.shape[tgt] > 1:
            axes.append(tgt)
        else:
            axes.append(None)
    return P(*axes)


def _axes_size(entry, mesh: Mesh) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def _fit_pspec(pspec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes whose size does not divide the array dim (e.g. a
    batch=1 long-context cell cannot be data-sharded)."""
    entries = list(pspec) + [None] * (len(shape) - len(pspec))
    out = []
    for e, s in zip(entries, shape):
        out.append(e if s % _axes_size(e, mesh) == 0 else None)
    return P(*out)


def _is_spec(x) -> bool:
    return isinstance(x, tuple) and \
        all(isinstance(e, (str, type(None))) for e in x)


def tree_shardings(shapes, specs, mesh: Mesh, zero1: bool = False,
                   overrides: Optional[Dict[str, str]] = None):
    """NamedShardings for a (ShapeDtypeStruct tree, logical-axes tree) pair.

    zero1: additionally shard the first divisible replicated dim over the
    batch (data) axes — optimizer-state sharding.
    """
    baxes = batch_axes(mesh)
    dp = 1
    for a in baxes:
        dp *= mesh.shape[a]

    def one(sds, spec):
        ps = _fit_pspec(spec_to_pspec(spec, mesh, overrides), sds.shape,
                        mesh)
        if zero1 and dp > 1:
            entries = list(ps) + [None] * (len(sds.shape) - len(ps))
            used = set()
            for e in entries:
                for a in (e if isinstance(e, tuple) else (e,)):
                    if a:
                        used.add(a)
            if not used.intersection(baxes):
                for i, (e, s) in enumerate(zip(entries, sds.shape)):
                    if e is None and s % dp == 0 and s > 0:
                        entries[i] = baxes if len(baxes) > 1 else baxes[0]
                        ps = P(*entries)
                        break
        return NamedSharding(mesh, ps)

    return jax.tree.map(one, shapes, specs, is_leaf=lambda x: _is_spec(x))


@dataclasses.dataclass(frozen=True)
class Plan:
    """Sharding plan for one (model, shape, mesh) cell."""
    mesh: Mesh
    cfg: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig

    @property
    def tp(self) -> int:
        return model_axis_size(self.mesh)

    @property
    def batch(self) -> Tuple[str, ...]:
        return batch_axes(self.mesh)

    # ---- activation specs ----
    def resid_spec(self) -> P:
        if self.parallel.seq_parallel and self.shape.kind != DECODE:
            return P(self.batch or None, "model", None)
        return P(self.batch or None, None, None)

    def tokens_spec(self) -> P:
        return P(self.batch or None, None)

    def logits_spec(self) -> P:
        return P(self.batch or None, None, "model")

    def constrain(self, x, kind: str):
        if self.tp == 1 and not self.batch:
            return x
        if kind == "resid" and x.ndim == 3:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, self.resid_spec()))
        if kind == "logits":
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, self.logits_spec()))
        return x

    # ---- runtime (injection into models/transformer) ----
    def runtime(self):
        from repro.distributed import decode_attn as da
        from repro.distributed import moe_parallel as mp
        from repro.models.moe import can_use_2d
        from repro.models.transformer import ModelRuntime
        tp = self.tp
        decode_fn = None
        moe_fn = None
        moe_dp = 1
        baxes = self.batch
        dp = 1
        for a in baxes:
            dp *= self.mesh.shape[a]
        if tp > 1 or baxes:
            if self.shape.kind == DECODE:
                decode_fn = da.make_flash_decode(self.mesh)
                if self.cfg.moe:
                    moe_fn = mp.make_moe_replicated(self.mesh,
                                                    expert_2d=True)
            elif self.cfg.moe:
                last = self.mesh.shape[baxes[-1]] if baxes else 0
                if can_use_2d(self.cfg, tp, dp, last):
                    moe_fn = mp.make_moe_etp2d(self.mesh)
                    moe_dp = dp
                else:
                    moe_fn = mp.make_moe_etp(self.mesh)
        return ModelRuntime(
            tp=tp,
            attn_impl=self.parallel.attn_impl,
            moe_fn=moe_fn,
            decode_attn_fn=decode_fn,
            constrain=self.constrain,
            remat=(self.parallel.remat != "none"),
            remat_policy="dots" if self.parallel.remat == "dots" else "full",
            max_seq=self.shape.seq_len,
            moe_dp=moe_dp,
        )
