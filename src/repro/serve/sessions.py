"""Multi-tenant serve tier: sessions as leased exchange Datasets.

The paper's headline B-APM serving scenario — persistent-memory regions
that applications share and resume across processes and node failures —
needs more than the bare ``serve/<name>`` object-store keys the original
single-session engine used. A spilled session with no catalog record has
no lifetime (who may reclaim it?), no lineage (which prefix cache was it
forked from?), and no metadata-only recoverability answer after a node
loss. The **SessionManager** closes that gap by making every session's
KV/cursor state and every shared prefix cache a *leased, versioned
Dataset* in the existing exchange catalog:

  * ``spill`` publishes the engine's exported state as version N+1 of
    dataset ``sess/<name>`` (workflow ``serve``): bytes to a home pool
    chosen by stable hash (sessions spread across the fleet instead of
    piling on node0), record + content digest replicated, buddy replica
    acked through the ExchangeChannel. Lineage records the producing
    engine and the previous version + base prefix dataset, so
    ``catalog.lineage`` reconstructs a session's whole derivation even
    after its bytes are gone;
  * the manager holds a **lease** on the latest version of every live
    session, so ``catalog.gc`` can never reclaim one out from under the
    fleet, and the DLM cache's lease-pinned admission keeps hot sessions
    DRAM-resident under capacity pressure. Superseded versions are
    unretained + released at spill time — the next gc sweep reclaims
    their bytes while the lineage records survive;
  * eviction of cold sessions is *lease release* (``evict_cold``), not
    byte deletion: the bytes stay durable on pmem until ``end()``
    unretains them; the session just stops being DRAM-pinned;
  * ``resume`` re-acquires the lease BEFORE reading (acquire's
    under-lock reclaimed check makes the read race-free against gc),
    then reads DLM -> home pmem -> acked replica. A session published by
    another process is adopted from its catalog record alone — the
    cross-process fleet handoff of the paper's Fig. 8 "retain" path;
  * shared prefix/KV caches are first-class datasets
    (``prefix/<name>``) a whole fleet forks sessions from;
  * decision functions (``recoverable_sessions``, ``choose_evictions``)
    are ``@metadata_only``: they answer from catalog records and the
    in-DRAM session table — zero object-store probes, lint-enforced;
  * every lifecycle edge is instrumented through the TelemetryPlane:
    ``serve.sessions_active`` gauge, ``serve.resume_ms`` /
    ``serve.spill_to_ack_s`` histograms, and ONE trace-span tree per
    session lifetime (the root span's trace id is persisted in the
    record's annotations, so the tree reconnects across processes).

Repair needs zero new scan code: session spills are ordinary catalog
records, so ``RepairChannel``'s existing dataset-record scan re-buddies
them after a node loss, and the ``RepairDaemon``'s rate budget covers
session repair storms exactly like checkpoint ones.
"""
from __future__ import annotations

import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.analysis.annotations import metadata_only
from repro.core.dataset_exchange import (DEFAULT_LEASE_TTL_S,
                                         DatasetCatalog, Lease, live_pools)
from repro.obs.metrics import Registry

WORKFLOW = "serve"


def session_dataset(name: str) -> str:
    """Catalog dataset name for a session's spilled state."""
    return f"sess/{name}"


def prefix_dataset(name: str) -> str:
    """Catalog dataset name for a shared prefix/KV cache."""
    return f"prefix/{name}"


@dataclass
class _Session:
    """In-process view of one session's lifecycle state. The durable
    truth lives in the catalog record; this row caches the latest
    version, the lease the manager holds on it, and the engine binding."""
    name: str
    version: int = 0            # latest published version (0 = none yet)
    lease: Optional[Lease] = None
    engine: object = None       # bound ServeEngine while being served
    prefix: Optional[list] = None   # lineage ref of the base prefix ds
    span: object = None         # root span of the lifetime trace tree
    last_used: float = field(default_factory=time.time)
    spilling: object = None     # in-flight async publish future
    # host copy parked by a FAILED async suspend — the session state
    # would otherwise be lost with the engine DRAM already released
    pending_state: Optional[dict] = None


class SessionManager:
    """Checks sessions in and out of a fleet of ServeEngines, with the
    exchange catalog as the durable source of truth (see module doc)."""

    def __init__(self, tiered, catalog: DatasetCatalog, *,
                 workflow: str = WORKFLOW, owner: str = "serve",
                 lease_ttl_s: float = DEFAULT_LEASE_TTL_S, obs=None):
        self.tiered = tiered
        self.catalog = catalog
        self.workflow = workflow
        self.owner = owner
        self.lease_ttl_s = float(lease_ttl_s)
        self.obs = obs
        reg = obs.registry if obs is not None else Registry()
        self._g_active = reg.gauge("serve.sessions_active")
        self._h_resume_ms = reg.histogram("serve.resume_ms")
        self._h_spill_to_ack = reg.histogram("serve.spill_to_ack_s")
        self._c_spills = reg.counter("serve.spills")
        self._c_resumes = reg.counter("serve.resumes")
        self._c_evictions = reg.counter("serve.evictions")
        self._c_adoptions = reg.counter("serve.adoptions")
        self._lock = threading.Lock()
        self._sessions: Dict[str, _Session] = {}

    # ---- telemetry helpers -------------------------------------------
    def _begin(self, name: str, sess: Optional[_Session] = None, **attrs):
        if self.obs is None:
            return None
        if sess is not None and sess.span is not None:
            return self.obs.begin(name, trace=sess.span.trace,
                                  parent=sess.span.span, **attrs)
        return self.obs.begin(name, **attrs)

    def _end(self, span, **attrs) -> None:
        if self.obs is not None and span is not None:
            self.obs.end(span, **attrs)

    # ---- placement ---------------------------------------------------
    def _home_for(self, key: str) -> str:
        """Stable-hash home placement: sessions spread across live pools
        instead of all landing on the catalog's default (first live)."""
        live = live_pools(self.catalog.stores, self.catalog.nodes)
        return live[zlib.crc32(key.encode()) % len(live)]

    # ---- session table -----------------------------------------------
    def _get(self, name: str) -> _Session:
        with self._lock:
            sess = self._sessions.get(name)
        if sess is None:
            raise KeyError(f"unknown session {name!r} "
                           f"(start/resume it first)")
        return sess

    def sessions(self) -> List[str]:
        with self._lock:
            return sorted(self._sessions)

    def active_sessions(self) -> List[str]:
        """Sessions currently bound to an engine (being served)."""
        with self._lock:
            return sorted(n for n, s in self._sessions.items()
                          if s.engine is not None)

    @metadata_only
    def discover(self) -> List[str]:
        """Session names known to the CATALOG (any process's spills) —
        the cross-process view a fresh fleet member resumes from. Pure
        record scan; latest-version bytes may or may not survive (ask
        ``recoverable_sessions``)."""
        tag = f"{session_dataset('')}"
        names = {rec["name"][len(tag):]
                 for rec in self.catalog.records(self.workflow)
                 if rec["name"].startswith(tag)}
        return sorted(names)

    # ---- prefix datasets (fleet-shared warm caches) ------------------
    def publish_prefix(self, name: str, source, *,
                       producer: Optional[str] = None) -> dict:
        """Publish a shared prefix/KV cache as dataset ``prefix/<name>``
        the whole fleet forks sessions from. ``source`` is an engine
        (its state is exported, DRAM kept) or a raw state tree."""
        state = source.export_state() if hasattr(source, "export_state") \
            else source
        ds = prefix_dataset(name)
        return self.catalog.publish(
            ds, state, workflow=self.workflow,
            producer=producer or getattr(source, "label", self.owner),
            node=self._home_for(ds), retained=True,
            annotations={"prefix": name})

    # ---- lifecycle ---------------------------------------------------
    def start(self, name: str, engine, *,
              prefix: Optional[str] = None) -> _Session:
        """Begin serving a NEW session on ``engine``, optionally seeding
        it from shared prefix dataset ``prefix/<prefix>`` (read under a
        short-lived lease so gc cannot reclaim it mid-read; the fork is
        recorded in the session's lineage)."""
        with self._lock:
            if name in self._sessions:
                raise KeyError(f"session {name!r} already exists "
                               f"(resume it instead)")
        sess = _Session(name=name)
        sess.span = self._begin("serve.session", session=name)
        if prefix is not None:
            ds = prefix_dataset(prefix)
            lease = self.catalog.acquire(ds, workflow=self.workflow,
                                         owner=self.owner,
                                         ttl_s=self.lease_ttl_s)
            try:
                state = self.catalog.get(ds, self.workflow, lease.version)
                engine.install_state(state)
                sess.prefix = [ds, self.workflow, lease.version]
            finally:
                self.catalog.release(lease)
        with self._lock:
            self._sessions[name] = sess
            sess.engine = engine
            sess.last_used = time.time()
        self._g_active.inc()
        return sess

    def _publish_spill(self, name: str, state: dict,
                       t_submit: float) -> dict:
        """Publish one spill as the session dataset's next version and
        hand the manager's lease old -> new. Runs synchronously or on
        the TieredIO I/O thread (async suspend); either way the lease
        handoff happens only AFTER the home-pmem write is durable."""
        sess = self._get(name)
        ds = session_dataset(name)
        with self._lock:
            prev_v = sess.version
            prefix = sess.prefix
            trace = sess.span.trace if sess.span is not None else 0
            producer = getattr(sess.engine, "label", None) or self.owner
        inputs = []
        if prev_v:
            inputs.append([ds, self.workflow, prev_v])
        if prefix:
            inputs.append(list(prefix))
        probe = self._ack_probe(name, t_submit)
        rec = self.catalog.publish(
            ds, state, workflow=self.workflow, producer=producer,
            inputs=inputs, node=self._home_for(ds), retained=True,
            annotations={"session": name, "trace": trace},
            on_replica=probe)
        new_lease = self.catalog.acquire(
            ds, workflow=self.workflow, version=rec["version"],
            owner=self.owner, ttl_s=self.lease_ttl_s)
        with self._lock:
            old_lease, sess.lease = sess.lease, new_lease
            sess.version = rec["version"]
            sess.spilling = None
            sess.pending_state = None
            sess.last_used = time.time()
        if old_lease is not None:
            self.catalog.release(old_lease)
        if prev_v:
            # the superseded spill is dead weight: unretain it so the
            # next gc sweep reclaims its bytes (the record survives —
            # lineage chains through it)
            self.catalog.unretain(ds, self.workflow, prev_v)
        self._c_spills.inc()
        return rec

    def _ack_probe(self, name: str, t_submit: float):
        """Called from the replicate worker after the buddy ack is in
        the record: the spill-to-ack latency the SLA cares about (a
        session is loss-of-one-node durable only past this point)."""
        def probe() -> None:
            self._h_spill_to_ack.observe(time.time() - t_submit)
            if self.obs is not None:
                self.obs.event("serve.spill_ack", session=name)
        return probe

    def spill(self, name: str, *, wait: bool = True):
        """Durable snapshot of a BOUND session (engine keeps serving
        from DRAM). Returns the catalog record, or the publish future
        when ``wait=False``."""
        return self._spill(name, release=False, wait=wait)

    def suspend(self, name: str, *, wait: bool = True):
        """Spill + unbind: the engine's DRAM copy is released and the
        engine freed for another session. With ``wait=False`` the
        publish rides the TieredIO I/O thread; a FAILED async publish
        parks the host copy in the session row (``pending_state``) so
        the state is never lost — the next ``resume`` installs it
        straight from DRAM and the next successful spill clears it."""
        return self._spill(name, release=True, wait=wait)

    def _spill(self, name: str, *, release: bool, wait: bool):
        sess = self._get(name)
        with self._lock:
            engine = sess.engine
            if engine is None:
                raise KeyError(f"session {name!r} is not bound to an "
                               f"engine (nothing to spill)")
            if sess.spilling is not None:
                raise RuntimeError(f"session {name!r} already has a "
                                   f"spill in flight")
        state = engine.export_state(release=release)
        if release:
            with self._lock:
                sess.engine = None
            self._g_active.dec()
        sp = self._begin("serve.spill", sess, session=name,
                         release=release)
        t0 = time.time()
        if wait or self.tiered is None:
            try:
                rec = self._publish_spill(name, state, t0)
            except Exception:
                with self._lock:
                    sess.pending_state = state
                self._end(sp, status="error")
                raise
            self._end(sp, version=rec["version"])
            return rec
        fut = self.tiered.run_async(
            lambda: self._publish_spill(name, state, t0))
        with self._lock:
            sess.spilling = fut

        def _done(f) -> None:
            if f.exception() is not None:
                with self._lock:
                    sess.pending_state = state
                    sess.spilling = None
                self._end(sp, status="error")
            else:
                self._end(sp, version=f.result()["version"])
        fut.add_done_callback(_done)
        return fut

    def resume(self, name: str, engine) -> None:
        """Install a session's state into ``engine`` and bind it. The
        lease is (re)acquired BEFORE the read — acquire's under-lock
        reclaimed check makes resume race-free against ``catalog.gc``.
        Read path: parked failed-spill DRAM copy, else DLM cache ->
        home pmem -> acked replica (the home node may be dead). A
        session this process has never seen is adopted from its catalog
        record — including the persisted trace id, so the lifetime span
        tree continues across processes."""
        t0 = time.perf_counter()
        sess = self._adopt(name)
        sp = self._begin("serve.resume", sess, session=name)
        with self._lock:
            if sess.engine is not None:
                raise RuntimeError(f"session {name!r} already bound")
            parked = sess.pending_state
        try:
            if parked is not None:
                state = parked  # failed spill never left DRAM
            else:
                self._ensure_lease(sess)
                state = self.catalog.get(session_dataset(name),
                                         self.workflow, sess.version)
            engine.install_state(state)
        except Exception:
            self._end(sp, status="error")
            raise
        with self._lock:
            sess.engine = engine
            sess.last_used = time.time()
        self._g_active.inc()
        self._c_resumes.inc()
        self._h_resume_ms.observe((time.perf_counter() - t0) * 1e3)
        self._end(sp, parked=parked is not None)

    def _adopt(self, name: str) -> _Session:
        """The session row, adopting catalog-only sessions published by
        another process (record -> version + persisted trace id)."""
        with self._lock:
            sess = self._sessions.get(name)
        if sess is not None:
            return sess
        rec = self.catalog.record(session_dataset(name), self.workflow)
        trace = (rec.get("annotations") or {}).get("trace") or None
        sess = _Session(name=name, version=rec["version"])
        if self.obs is not None:
            sess.span = self.obs.begin("serve.session", trace=trace,
                                       session=name, adopted=True)
        with self._lock:
            # two racing adopters: first one in wins, keep its row
            sess = self._sessions.setdefault(name, sess)
        self._c_adoptions.inc()
        return sess

    def _ensure_lease(self, sess: _Session) -> None:
        """Hold a live lease on the session's latest version (acquire
        before read; gc can then never reclaim it mid-resume)."""
        with self._lock:
            lease = sess.lease
        if lease is not None and not lease.expired():
            return
        new = self.catalog.acquire(session_dataset(sess.name),
                                   workflow=self.workflow,
                                   owner=self.owner,
                                   ttl_s=self.lease_ttl_s)
        with self._lock:
            sess.lease = new
            sess.version = new.version

    # ---- eviction (lease release, not byte deletion) -----------------
    @metadata_only
    def choose_evictions(self, max_idle_s: float,
                         now: Optional[float] = None) -> List[str]:
        """Cold-session eviction policy, decided purely from the in-DRAM
        session table: idle past the threshold, NOT bound to an engine,
        no spill in flight, and actually holding a lease to release. A
        live (bound or leased-and-busy) session is never chosen."""
        now = now if now is not None else time.time()
        with self._lock:
            return sorted(
                n for n, s in self._sessions.items()
                if s.engine is None and s.spilling is None
                and s.lease is not None and s.pending_state is None
                and now - s.last_used >= max_idle_s)

    def evict(self, name: str) -> None:
        """Evict ONE cold session by releasing the manager's lease: the
        DLM cache stops pinning it (capacity pressure may now drop the
        DRAM copy) — the pmem bytes stay durable until ``end()``."""
        sess = self._get(name)
        with self._lock:
            if sess.engine is not None or sess.spilling is not None:
                raise RuntimeError(f"session {name!r} is live — "
                                   f"refusing to evict")
            lease, sess.lease = sess.lease, None
        if lease is not None:
            self.catalog.release(lease)
        self._c_evictions.inc()
        if self.obs is not None:
            self.obs.event("serve.evict", session=name)

    def evict_cold(self, max_idle_s: float = 0.0) -> List[str]:
        """Release leases of every cold session (``choose_evictions``
        policy), then let TieredIO flush now-unpinned DLM entries. This
        REPLACES the old ad-hoc ``evict_cold`` spill loop for
        catalog-registered sessions: eviction is a metadata operation
        (lease release); the bytes were already durable at spill time."""
        victims = self.choose_evictions(max_idle_s)
        for name in victims:
            self.evict(name)
        if victims and self.tiered is not None:
            self.tiered.evict_cold(max_idle_s)
        return victims

    def end(self, name: str) -> None:
        """Terminate a session: release the lease, unretain EVERY
        version (the next gc sweep reclaims all its bytes), close the
        lifetime span. The catalog records survive — lineage outlives
        the session."""
        sess = self._get(name)
        with self._lock:
            if sess.spilling is not None:
                raise RuntimeError(f"session {name!r} has a spill in "
                                   f"flight — join it before end()")
            engine = sess.engine
            lease, sess.lease = sess.lease, None
            sess.engine = None
        if engine is not None:
            engine.cache = None
            self._g_active.dec()
        if lease is not None:
            self.catalog.release(lease)
        ds = session_dataset(name)
        for v in self.catalog.versions(ds, self.workflow):
            try:
                self.catalog.unretain(ds, self.workflow, v)
            except (KeyError, IOError, FileNotFoundError):
                continue  # already reclaimed / record unreachable
        with self._lock:
            self._sessions.pop(name, None)
        self._end(sess.span, status="ok", versions=sess.version)

    # ---- inspection / recovery ---------------------------------------
    def peek(self, name: str, leaf: str):
        """Byte-range read of ONE leaf of a session's latest spill (a
        single KV page, the ``pos`` cursor) via the catalog: home pool
        first, then ACKED replica holders — never a blind fan-out, and
        nothing admitted into the DLM cache."""
        with self._lock:
            sess = self._sessions.get(name)
            version = sess.version if sess is not None and sess.version \
                else None
        return self.catalog.get_leaf(session_dataset(name), leaf,
                                     self.workflow, version)

    @metadata_only
    def recoverable_sessions(self,
                             lost_nodes: Sequence[str] = ()) -> List[str]:
        """Which catalog-known sessions would survive losing
        ``lost_nodes``? Decided from catalog records alone (placement +
        replica acks) — ZERO object-store probes, mirroring
        ``restore_latest_recoverable``. Sessions whose failed spill is
        parked in this process's DRAM count as recoverable too."""
        tag = session_dataset("")
        latest: Dict[str, int] = {}
        for rec in self.catalog.records(self.workflow):
            if not rec["name"].startswith(tag):
                continue
            nm = rec["name"][len(tag):]
            if rec["version"] > latest.get(nm, 0):
                latest[nm] = rec["version"]
        out = {nm for nm, v in latest.items()
               if self.catalog.recoverable(session_dataset(nm),
                                           self.workflow, v, lost_nodes)}
        with self._lock:
            out.update(n for n, s in self._sessions.items()
                       if s.pending_state is not None
                       or s.engine is not None)
        return sorted(out)

    def repair(self, lost_nodes) -> dict:
        """Re-buddy session/prefix datasets after a node loss. Session
        spills are ordinary catalog records, so the existing
        RepairChannel dataset scan covers them with zero new code; when
        the continuous RepairDaemon runs, its (rate-budgeted) sweep is
        joined instead of double-scanning."""
        assert self.tiered is not None, "repair needs a TieredIO engine"
        daemon = getattr(self.tiered, "repair_daemon", None)
        if daemon is not None and daemon.running:
            daemon.wait_for(lost_nodes, timeout=60.0)
        if daemon is not None and daemon.covers(lost_nodes):
            return daemon.report()
        return self.tiered.repair(lost_nodes)

    def join(self, timeout: Optional[float] = None) -> None:
        """Block until every in-flight async spill is durable (bench /
        shutdown barrier)."""
        with self._lock:
            futs = [s.spilling for s in self._sessions.values()
                    if s.spilling is not None]
        for f in futs:
            try:
                f.result(timeout)
            except Exception:
                pass  # parked in pending_state by the done-callback
