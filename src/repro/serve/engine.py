"""Serving engine: prefill + batched decode with pmem KV spill (SLM mode).

The engine drives models/transformer's prefill/decode with jitted steps.
Idle or preempted sequences' KV caches can be *spilled* to the node's
B-APM (object store) and resumed later — long-context serving state
outlives DRAM pressure and even process restarts, which is precisely the
paper's persistent-memory serving story.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.object_store import PMemObjectStore
from repro.models import transformer as tfm


class ServeEngine:
    def __init__(self, cfg: ModelConfig, rt: tfm.ModelRuntime, params,
                 store: Optional[PMemObjectStore] = None):
        self.cfg = cfg
        self.rt = rt
        self.params = params
        self.store = store
        self.cache = None
        self.pos = 0
        self._decode = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(p, cfg, rt, c, t, pos))
        self._prefill = jax.jit(
            functools.partial(tfm.prefill, cfg=cfg, rt=rt),
            static_argnames=())

    # ---- lifecycle ----
    def prefill(self, tokens: np.ndarray, **frontend) -> np.ndarray:
        logits, cache = tfm.prefill(self.params, self.cfg, self.rt,
                                    jnp.asarray(tokens), **frontend)
        self.cache = cache
        self.pos = tokens.shape[1] + self.cfg.prefix_len
        return np.asarray(jnp.argmax(logits, axis=-1), np.int32)

    def decode(self, first_tokens: np.ndarray, steps: int) -> np.ndarray:
        toks = jnp.asarray(first_tokens)
        out = [np.asarray(toks)]
        for i in range(steps):
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos))
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self.pos += 1
            out.append(np.asarray(toks))
        return np.stack(out, axis=1)

    # ---- pmem spill (SLM): persist serving state, restore later ----
    def spill(self, name: str) -> None:
        assert self.store is not None, "no pmem store attached"
        host = jax.tree.map(np.asarray, self.cache)
        self.store.put(f"serve/{name}", {"cache": host,
                                         "pos": np.int32(self.pos)})
        self.cache = None  # DRAM freed

    def resume(self, name: str) -> None:
        assert self.store is not None
        obj = self.store.get(f"serve/{name}")
        self.cache = jax.tree.map(jnp.asarray, obj["cache"])
        self.pos = int(obj["pos"])
