"""Serving engine: prefill + batched decode with pmem KV spill (SLM mode).

The engine drives models/transformer's prefill/decode with jitted steps.
Idle or preempted sequences' KV caches can be *spilled* to the node's
B-APM and resumed later — long-context serving state outlives DRAM
pressure and even process restarts, which is precisely the paper's
persistent-memory serving story.

Two spill paths:
  * legacy direct-store (``store=``): synchronous object-store put/get;
  * TieredIO (``tiered=``): spill goes through the DLM write-back cache
    on the engine's I/O thread (nonblocking), and ``prefetch_sessions``
    warms cold session/KV state from pmem into DRAM *before* the next
    request needs it — the scheduler-driven cold-page prefetch of the
    paper's Fig. 8.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.object_store import PMemObjectStore
from repro.core.tiered_io import TieredIO
from repro.models import transformer as tfm


class ServeEngine:
    def __init__(self, cfg: ModelConfig, rt: tfm.ModelRuntime, params,
                 store: Optional[PMemObjectStore] = None,
                 tiered: Optional[TieredIO] = None):
        self.cfg = cfg
        self.rt = rt
        self.params = params
        self.store = store
        self.tiered = tiered
        self.cache = None
        self.pos = 0
        self._decode = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(p, cfg, rt, c, t, pos))
        self._prefill = jax.jit(
            functools.partial(tfm.prefill, cfg=cfg, rt=rt),
            static_argnames=())

    # ---- lifecycle ----
    def prefill(self, tokens: np.ndarray, **frontend) -> np.ndarray:
        logits, cache = tfm.prefill(self.params, self.cfg, self.rt,
                                    jnp.asarray(tokens), **frontend)
        self.cache = cache
        self.pos = tokens.shape[1] + self.cfg.prefix_len
        return np.asarray(jnp.argmax(logits, axis=-1), np.int32)

    def decode(self, first_tokens: np.ndarray, steps: int) -> np.ndarray:
        toks = jnp.asarray(first_tokens)
        out = [np.asarray(toks)]
        for i in range(steps):
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos))
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self.pos += 1
            out.append(np.asarray(toks))
        return np.stack(out, axis=1)

    # ---- pmem spill (SLM): persist serving state, restore later ----
    def spill(self, name: str, wait: bool = True, replicate: bool = True):
        """Persist the session's KV/cursor to pmem and free DRAM. With a
        TieredIO engine attached the write happens off-thread; pass
        ``wait=False`` to get the future instead of blocking. With
        ``replicate`` (default) the spilled state also gets a buddy-node
        replica over the fabric, so ``resume``/``prefetch_sessions``
        keep working when the home node's pool dies (the TieredIO DLM
        cache transparently falls back to ``replica/<nid>/...``)."""
        assert self.tiered is not None or self.store is not None, \
            "no pmem backend attached"  # check BEFORE dropping the KV
        host = jax.tree.map(np.asarray, self.cache)
        obj = {"cache": host, "pos": np.int32(self.pos)}
        self.cache = None  # DRAM freed
        obs = self._obs()
        if obs is not None:
            obs.counter("serve.spills").inc()
            obs.event("serve.spill", session=name, replicate=replicate)
        if self.tiered is not None:
            fut = self.tiered.offload(f"serve/{name}", obj,
                                      replicate=replicate)
            if wait:
                fut.result()
                return None
            return fut
        self.store.put(f"serve/{name}", obj)
        return None

    def _obs(self):
        """The TieredIO engine's telemetry plane, when one is wired."""
        return getattr(self.tiered, "obs", None) \
            if self.tiered is not None else None

    def resume(self, name: str) -> None:
        obs = self._obs()
        sp = obs.begin("serve.resume", session=name) \
            if obs is not None else None
        if self.tiered is not None:
            obj = self.tiered.fetch(f"serve/{name}")
        else:
            assert self.store is not None
            obj = self.store.get(f"serve/{name}")
        self.cache = jax.tree.map(jnp.asarray, obj["cache"])
        self.pos = int(obj["pos"])
        if obs is not None:
            obs.counter("serve.resumes").inc()
            obs.end(sp)

    def peek_session(self, name: str, leaf: str) -> np.ndarray:
        """Byte-range peek at ONE leaf of a spilled session — a single
        layer's KV page, or the ``pos`` cursor — without rehydrating
        the rest of the cache. The read covers exactly that leaf's
        bytes on pmem (home pool first, then acked replicas when the
        home died), decoding only its own tiles when the spill
        travelled wire-encoded; nothing is admitted into the DLM cache
        and ``self.cache`` is untouched. This is how a scheduler can
        inspect a cold session (how far did it decode? how big is its
        KV?) at O(leaf) cost instead of O(session)."""
        assert self.tiered is not None, "peek needs a TieredIO engine"
        return self.tiered.fetch_leaf(f"serve/{name}", leaf)

    def prefetch_sessions(self, names: List[str]):
        """Warm cold session state pmem -> DRAM ahead of resume (Fig. 8
        prefetch). Returns the TieredIO future (hit/load counts)."""
        assert self.tiered is not None, "prefetch needs a TieredIO engine"
        return self.tiered.prefetch([f"serve/{n}" for n in names])

    def evict_cold_sessions(self, max_idle_s: float = 0.0) -> int:
        """Spill idle cached sessions back to pmem (DRAM pressure valve)."""
        assert self.tiered is not None, "eviction needs a TieredIO engine"
        n = self.tiered.evict_cold(max_idle_s)
        obs = self._obs()
        if obs is not None:
            obs.counter("serve.evictions").inc(n)
        return n

    def repair(self, lost_nodes) -> dict:
        """Restore the replication factor of spilled session/KV state
        after a node loss: every ``dlm/serve/...`` object whose acked
        copies the loss reduced to a single survivor regains a buddy
        (TieredIO.repair walks dlm/acks.json — no probing). Call from
        the serving control plane when the cluster monitor reports a
        dead node; sessions spilled before the loss then survive the
        NEXT one too. When the continuous RepairDaemon is running, its
        sweep is joined (bounded wait) and its ledger report returned —
        an inline scan concurrent with a mid-sweep daemon would double
        every repair transfer, exactly the storm the daemon's rate
        limit exists to prevent."""
        assert self.tiered is not None, "repair needs a TieredIO engine"
        daemon = getattr(self.tiered, "repair_daemon", None)
        if daemon is not None and daemon.running:
            daemon.wait_for(lost_nodes, timeout=60.0)
        if daemon is not None and daemon.covers(lost_nodes):
            return daemon.report()
        return self.tiered.repair(lost_nodes)
