"""Per-request serving engine: prefill + batched decode (SLM mode).

The engine drives models/transformer's prefill/decode with jitted steps
and owns exactly ONE session's DRAM state (``cache``/``pos``) at a time.
Session *lifetime* — who may spill, resume, share, evict or reclaim that
state — is the SessionManager's job (``serve/sessions.py``): a fleet of
these engines checks sessions in and out of the manager, which registers
every spill as a leased, versioned Dataset in the exchange catalog.

The legacy direct spill paths on this class survive for single-engine
use and tests:
  * legacy direct-store (``store=``): synchronous object-store put/get;
  * TieredIO (``tiered=``): spill goes through the DLM write-back cache
    on the engine's I/O thread (nonblocking), and ``prefetch_sessions``
    warms cold session/KV state from pmem into DRAM *before* the next
    request needs it — the scheduler-driven cold-page prefetch of the
    paper's Fig. 8.
New serving code should go through the SessionManager instead: it rides
the catalog's leases, acks and repair instead of bare ``serve/<name>``
keys.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.object_store import PMemObjectStore
from repro.core.tiered_io import TieredIO
from repro.models import transformer as tfm


class SpillTicket:
    """Future-like handle for a nonblocking ``ServeEngine.spill``.

    The ticket OWNS the host copy of the session state until the pmem
    write is durable: a failed offload parks the copy in
    ``engine.failed_spills[name]`` (instead of silently losing the
    session with ``engine.cache`` already freed) and ``result()`` raises
    a ``RuntimeError`` naming the session, chained on the real cause.
    ``restore_failed_spill`` re-installs the parked copy."""

    def __init__(self, name: str, state: dict, future,
                 engine: "ServeEngine"):
        self.name = name
        self._state = state
        self._future = future
        self._engine = engine
        future.add_done_callback(self._on_done)

    def _on_done(self, fut) -> None:
        if fut.exception() is not None:
            # the spill never became durable: the host copy goes back
            # to the engine so the session is not lost
            self._engine.failed_spills[self.name] = self._state
        self._state = None  # durable (or parked): ticket drops its ref

    def done(self) -> bool:
        return self._future.done()

    def exception(self, timeout: Optional[float] = None):
        return self._future.exception(timeout)

    def result(self, timeout: Optional[float] = None):
        try:
            return self._future.result(timeout)
        except Exception as e:  # noqa: BLE001 — re-raised with context
            raise RuntimeError(
                f"spill of session {self.name!r} never became durable; "
                f"host copy retained in "
                f"ServeEngine.failed_spills[{self.name!r}]") from e


class ServeEngine:
    def __init__(self, cfg: ModelConfig, rt: tfm.ModelRuntime, params,
                 store: Optional[PMemObjectStore] = None,
                 tiered: Optional[TieredIO] = None,
                 label: str = "engine0"):
        self.cfg = cfg
        self.rt = rt
        self.params = params
        self.store = store
        self.tiered = tiered
        self.label = label  # producer id stamped into session lineage
        self.cache = None
        self.pos = 0
        # host copies of spills that failed after ``cache`` was freed
        # (see SpillTicket): {session name: state dict}
        self.failed_spills: Dict[str, dict] = {}
        self._decode = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(p, cfg, rt, c, t, pos))
        self._prefill = jax.jit(
            functools.partial(tfm.prefill, cfg=cfg, rt=rt))

    # ---- lifecycle ----
    def prefill(self, tokens: np.ndarray, **frontend) -> np.ndarray:
        # cfg/rt are baked into the jitted partial; tokens must go by
        # keyword (positionally it would collide with the bound cfg)
        logits, cache = self._prefill(self.params,
                                      tokens=jnp.asarray(tokens),
                                      **frontend)
        self.cache = cache
        self.pos = tokens.shape[1] + self.cfg.prefix_len
        return np.asarray(jnp.argmax(logits, axis=-1), np.int32)

    def decode(self, first_tokens: np.ndarray, steps: int) -> np.ndarray:
        toks = jnp.asarray(first_tokens)
        out = [np.asarray(toks)]
        for i in range(steps):
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos))
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self.pos += 1
            out.append(np.asarray(toks))
        return np.stack(out, axis=1)

    # ---- session-state handoff (the SessionManager's interface) ----
    def export_state(self, release: bool = False) -> dict:
        """Host copy of the session state (``{"cache", "pos"}``) —
        what the manager publishes as a dataset version. ``release``
        frees the engine's DRAM copy after the export."""
        assert self.cache is not None, "no session state resident"
        host = jax.tree.map(np.asarray, self.cache)
        obj = {"cache": host, "pos": np.int32(self.pos)}
        if release:
            self.cache = None
        return obj

    def install_state(self, obj: dict) -> None:
        """Adopt a session state tree (from a resume, a shared prefix
        dataset, or a parked failed spill)."""
        self.cache = jax.tree.map(jnp.asarray, obj["cache"])
        self.pos = int(obj["pos"])

    def restore_failed_spill(self, name: str) -> None:
        """Re-install the host copy a failed nonblocking spill parked
        (see SpillTicket) — the in-process recovery for a spill whose
        pmem write died under it."""
        self.install_state(self.failed_spills.pop(name))

    # ---- pmem spill (SLM): persist serving state, restore later ----
    def spill(self, name: str, wait: bool = True, replicate: bool = True):
        """Persist the session's KV/cursor to pmem and free DRAM. With a
        TieredIO engine attached the write happens off-thread; pass
        ``wait=False`` to get a ``SpillTicket`` instead of blocking —
        the ticket owns the host copy until the write is durable, so a
        failed offload parks it in ``failed_spills`` rather than losing
        the session. With ``replicate`` (default) the spilled state also
        gets a buddy-node replica over the fabric, so ``resume``/
        ``prefetch_sessions`` keep working when the home node's pool
        dies (the TieredIO DLM cache transparently falls back to
        ``replica/<nid>/...``)."""
        assert self.tiered is not None or self.store is not None, \
            "no pmem backend attached"  # check BEFORE dropping the KV
        obj = self.export_state(release=True)
        obs = self._obs()
        if obs is not None:
            obs.counter("serve.spills").inc()
            obs.event("serve.spill", session=name, replicate=replicate)
        if self.tiered is not None:
            fut = self.tiered.offload(f"serve/{name}", obj,
                                      replicate=replicate)
            if wait:
                fut.result()
                return None
            return SpillTicket(name, obj, fut, self)
        self.store.put(f"serve/{name}", obj)
        return None

    def _obs(self):
        """The TieredIO engine's telemetry plane, when one is wired."""
        return getattr(self.tiered, "obs", None) \
            if self.tiered is not None else None

    def resume(self, name: str) -> None:
        obs = self._obs()
        sp = obs.begin("serve.resume", session=name) \
            if obs is not None else None
        if self.tiered is not None:
            obj = self.tiered.fetch(f"serve/{name}")
        else:
            assert self.store is not None
            obj = self.store.get(f"serve/{name}")
        self.install_state(obj)
        if obs is not None:
            obs.counter("serve.resumes").inc()
            obs.end(sp)

    def peek_session(self, name: str, leaf: str) -> np.ndarray:
        """Byte-range peek at ONE leaf of a spilled session — a single
        layer's KV page, or the ``pos`` cursor — without rehydrating
        the rest of the cache. The read covers exactly that leaf's
        bytes on pmem (home pool first, then acked replicas when the
        home died), decoding only its own tiles when the spill
        travelled wire-encoded; nothing is admitted into the DLM cache
        and ``self.cache`` is untouched. This is how a scheduler can
        inspect a cold session (how far did it decode? how big is its
        KV?) at O(leaf) cost instead of O(session)."""
        assert self.tiered is not None, "peek needs a TieredIO engine"
        return self.tiered.fetch_leaf(f"serve/{name}", leaf)

    def prefetch_sessions(self, names: List[str]):
        """Warm cold session state pmem -> DRAM ahead of resume (Fig. 8
        prefetch). Returns the TieredIO future (hit/load counts)."""
        assert self.tiered is not None, "prefetch needs a TieredIO engine"
        return self.tiered.prefetch([f"serve/{n}" for n in names])

    def evict_cold_sessions(self, max_idle_s: float = 0.0) -> int:
        """Spill idle cached sessions back to pmem (DRAM pressure valve).
        The SessionManager's lease-release eviction supersedes this for
        catalog-registered sessions."""
        assert self.tiered is not None, "eviction needs a TieredIO engine"
        n = self.tiered.evict_cold(max_idle_s)
        obs = self._obs()
        if obs is not None:
            obs.counter("serve.evictions").inc(n)
        return n

    def repair(self, lost_nodes) -> dict:
        """Restore the replication factor of spilled session/KV state
        after a node loss: every ``dlm/serve/...`` object whose acked
        copies the loss reduced to a single survivor regains a buddy
        (TieredIO.repair walks dlm/acks.json — no probing). Call from
        the serving control plane when the cluster monitor reports a
        dead node; sessions spilled before the loss then survive the
        NEXT one too. When the continuous RepairDaemon is running, its
        sweep is joined (bounded wait) and its ledger report returned —
        an inline scan concurrent with a mid-sweep daemon would double
        every repair transfer, exactly the storm the daemon's rate
        limit exists to prevent."""
        assert self.tiered is not None, "repair needs a TieredIO engine"
        daemon = getattr(self.tiered, "repair_daemon", None)
        if daemon is not None and daemon.running:
            daemon.wait_for(lost_nodes, timeout=60.0)
        if daemon is not None and daemon.covers(lost_nodes):
            return daemon.report()
        return self.tiered.repair(lost_nodes)
