"""pmemlint: invariant lint passes + persistence-order sanitizer.

The B-APM programming model (PAPER.md; Weiland et al., arXiv:1805.10041)
makes correctness an *ordering* problem: byte-granular stores are durable
only after an explicit flush+fence, so the durability story of the whole
data plane — committed-tail MetaLog appends, crash-atomic ``put_json``,
ack-before-report — rests on write/flush/commit ordering that used to
live only in docstrings. This package checks it mechanically:

  * ``repro.analysis.lint`` — the AST lint driver
    (``python -m repro.analysis.lint src/repro``) enforcing three
    invariant families: persistence ordering, metadata-only recovery,
    and lock discipline (see README.md in this directory).
  * ``repro.analysis.annotations`` — the ``@metadata_only`` /
    ``@rehydration_entry`` markers the call-graph pass keys on.
  * ``repro.analysis.sanitizer`` — a record-and-check shim over
    ``PMemRegion``/``PMemPool`` that validates the committed-tail
    discipline at runtime and enumerates torn-write crash states
    (``pytest --pmem-sanitize`` runs existing crash tests under it).
"""
from repro.analysis.annotations import metadata_only, rehydration_entry
from repro.analysis.sanitizer import PMemSanitizer

__all__ = ["metadata_only", "rehydration_entry", "PMemSanitizer"]
