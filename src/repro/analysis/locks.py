"""Invariant family (c): lock discipline.

Two lockset-style passes over each class:

  unguarded-write      an instance attribute that is written under a
                       lock at ANY site must be written under a lock at
                       EVERY site (outside ``__init__``). A mixed
                       discipline is how the PR-2/PR-5 era races slipped
                       in: one thread mutates under ``self._lock`` while
                       another mutates bare.
  blocking-under-lock  a blocking call — ``Future.result``, ``join``,
                       ``sleep``, ``wait``/``wait_for``, channel
                       ``submit``/``replicate``/``drain``/``stage_in`` —
                       made while holding a lock. With scheduler worker
                       threads acking back into locked registries, a
                       blocking call under a lock is a deadlock waiting
                       for its second participant.

Both passes treat nested closures as UNGUARDED flows (a closure defined
under a lock runs later, on another thread, without it) — which is
exactly the checkpoint-ack callback pattern, so writes inside closures
count as unguarded sites for the attribute lockset.

To keep the pass usable on this codebase's style — public methods take
the lock, private ``_helpers`` assume it ("Lock held." docstrings) — a
*lock-held-on-entry* fixpoint is computed per class: a private method
every one of whose intra-class call sites is guarded (directly under a
``with <lock>`` or inside another held-on-entry method) is treated as
guarded throughout. Helpers that are ALSO called bare anywhere stay
unguarded, which is the actual race.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, FuncInfo, Module, call_name,
                                 lock_items, src, walk_in_order)

BLOCKING = {"result", "join", "sleep", "wait", "wait_for", "submit",
            "replicate", "drain", "stage_in", "run_job"}


def _self_attr_target(node: ast.AST) -> Optional[str]:
    """The ``self.attr`` an assignment target writes, if any — covers
    ``self.x = ``, ``self.x += ``, ``self.x[k] = `` (container mutate)."""
    t = node
    if isinstance(t, ast.Subscript):
        t = t.value
    if isinstance(t, ast.Attribute) and \
            isinstance(t.value, ast.Name) and t.value.id == "self":
        return t.attr
    return None


class _MethodScan(ast.NodeVisitor):
    """Walk ONE function body tracking the lock-held depth. Nested defs
    are scanned separately with depth reset to 0 (closures run later,
    lock not held)."""

    def __init__(self, mod: Module, fn: FuncInfo):
        self.mod = mod
        self.fn = fn
        self.depth = 0
        #: attr -> list of (guarded, lineno)
        self.writes: Dict[str, List[Tuple[bool, int]]] = {}
        #: (name, receiver, lineno, lock source) blocking calls under lock
        self.blocking: List[Tuple[str, str, int, str]] = []
        #: intra-class calls: method name -> [guarded-at-call-site]
        self.self_calls: Dict[str, List[bool]] = {}
        self._lock_stack: List[str] = []
        self._root = fn.node

    def scan(self) -> "_MethodScan":
        for stmt in getattr(self._root, "body", []):
            self.visit(stmt)
        return self

    # -- structure ---------------------------------------------------
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        return  # separate flow; indexed + scanned on its own

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda) -> None:
        return

    def visit_With(self, node: ast.With) -> None:
        locks = lock_items(node)
        if locks:
            self._lock_stack.extend(locks)
            self.depth += 1
        for item in node.items:
            self.visit(item.context_expr)
        for stmt in node.body:
            self.visit(stmt)
        if locks:
            self.depth -= 1
            del self._lock_stack[-len(locks):]

    # -- events ------------------------------------------------------
    def _record_write(self, target: ast.AST, lineno: int) -> None:
        attr = _self_attr_target(target)
        if attr is not None:
            self.writes.setdefault(attr, []).append(
                (self.depth > 0, lineno))

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._record_write(t, node.lineno)
        self.visit(node.value)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record_write(node.target, node.lineno)
        self.visit(node.value)

    @staticmethod
    def _is_blocking(name: str, recv: str) -> bool:
        if name not in BLOCKING:
            return False
        if name == "join":
            # str/bytes .join and os.path.join are not thread joins
            lit = recv.lstrip("frbuFRBU")
            if not recv or lit[:1] in ("'", '"') or \
                    recv.endswith("path"):
                return False
        return True

    def visit_Call(self, node: ast.Call) -> None:
        name, recv = call_name(node)
        if self._is_blocking(name, recv):
            lock = self._lock_stack[-1] if self.depth > 0 else ""
            self.blocking.append((name, recv, node.lineno, lock))
        if recv == "self":
            self.self_calls.setdefault(name, []).append(self.depth > 0)
        self.generic_visit(node)


def _class_methods(mod: Module, cls: str) -> List[FuncInfo]:
    return [fn for fn in mod.functions.values() if fn.cls == cls]


def _held_on_entry(cls: str, scans: Dict[str, "_MethodScan"]) -> Set[str]:
    """Method names whose every intra-class call site holds the lock —
    directly, or transitively via another held-on-entry caller. Only
    private (``_``-prefixed, non-dunder) direct methods qualify: a
    public method can be entered from anywhere, lock not held. A
    closure caller never confers held-ness (it runs on another thread,
    lock dropped)."""
    sites: Dict[str, List[Tuple[str, bool]]] = {}
    for q, scan in scans.items():
        for callee, flags in scan.self_calls.items():
            for g in flags:
                sites.setdefault(callee, []).append((q, g))
    held: Set[str] = set()
    changed = True
    while changed:
        changed = False
        for q in scans:
            if "." in q.split(f"{cls}.", 1)[-1]:
                continue  # closure, not a direct method
            name = q.rsplit(".", 1)[-1]
            if name in held or not name.startswith("_") or \
                    name.startswith("__"):
                continue
            calls = sites.get(name)
            if not calls:
                continue
            if all(g or (caller == f"{cls}.{caller.rsplit('.', 1)[-1]}"
                         and caller.rsplit(".", 1)[-1] in held)
                   for caller, g in calls):
                held.add(name)
                changed = True
    return held


def run(modules: List[Module]) -> List[Finding]:
    findings: List[Finding] = []
    for mod in modules:
        classes: Set[str] = {fn.cls for fn in mod.functions.values()
                             if fn.cls}
        for cls in sorted(classes):
            methods = _class_methods(mod, cls)
            scans = {fn.qualname: _MethodScan(mod, fn).scan()
                     for fn in methods}
            held = _held_on_entry(cls, scans)

            def is_held(q: str) -> bool:
                name = q.rsplit(".", 1)[-1]
                return name in held and q == f"{cls}.{name}"

            # ---- lockset: attr guarded anywhere => guarded everywhere
            guarded_attrs: Set[str] = set()
            for q, scan in scans.items():
                if q.endswith("__init__"):
                    continue
                for attr, sites in scan.writes.items():
                    if is_held(q) or any(g for g, _ in sites):
                        guarded_attrs.add(attr)
            for q, scan in scans.items():
                if q.endswith("__init__") or is_held(q):
                    continue
                for attr, sites in scan.writes.items():
                    if attr not in guarded_attrs:
                        continue
                    for guarded, lineno in sites:
                        if guarded:
                            continue
                        if mod.suppressed(lineno, "unguarded-write"):
                            continue
                        findings.append(Finding(
                            "unguarded-write", mod.rel, lineno, q, attr,
                            f"self.{attr} is written under a lock "
                            f"elsewhere in {cls} but written bare here "
                            f"— every write site must hold the lock "
                            f"(lockset rule)"))
            # ---- blocking calls while holding a lock
            for q, scan in scans.items():
                for name, recv, lineno, lock in scan.blocking:
                    if not lock:
                        if not is_held(q):
                            continue
                        lock = "<lock held on entry>"
                    if mod.suppressed(lineno, "blocking-under-lock"):
                        continue
                    callee = f"{recv}.{name}" if recv else name
                    findings.append(Finding(
                        "blocking-under-lock", mod.rel, lineno, q,
                        f"{name}",
                        f"blocking call `{callee}(...)` while holding "
                        f"`{lock}` — if the completion path needs the "
                        f"same lock this deadlocks; move the wait "
                        f"outside the critical section"))
    return findings
