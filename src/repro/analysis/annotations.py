"""Invariant annotations the lint passes key on.

Both decorators are runtime no-ops beyond marking the function and
registering its qualified name — they exist so the *contract* a
docstring used to state ("this decision path reads metadata only",
"reads through this entry point are sanctioned copies, not probes")
is machine-visible. ``repro.analysis.recovery`` reads the decorators
syntactically from the AST, so applying one is always safe: no import
cycle, no behavior change, no overhead on the decorated call.
"""
from __future__ import annotations

from typing import Callable, Set

#: qualified names (``module.Class.method``) declared metadata-only at
#: import time — runtime mirror of what the lint derives from the AST
METADATA_ONLY: Set[str] = set()

#: qualified names of sanctioned rehydration/copy entry points
REHYDRATION_ENTRIES: Set[str] = set()


def _qualname(fn: Callable) -> str:
    return f"{fn.__module__}.{fn.__qualname__}"


def metadata_only(fn: Callable) -> Callable:
    """Declare that ``fn`` (and everything it transitively calls) makes
    recovery/placement decisions from persisted *metadata* alone — ack
    records, catalog records, manifests, journals — and never reads
    object-store payload bytes except through a function marked
    ``@rehydration_entry``. The ``metadata-only-read`` lint pass walks
    the call graph and fails the build when the contract is broken."""
    fn.__pmem_metadata_only__ = True
    METADATA_ONLY.add(_qualname(fn))
    return fn


def rehydration_entry(fn: Callable) -> Callable:
    """Declare ``fn`` a sanctioned data-movement entry point: the object
    reads it performs (or schedules) are the *sources of copies being
    made* — replication, drain, stage-in/rehydration — never blind
    recovery probes. The metadata-only call-graph pass does not traverse
    into functions carrying this marker."""
    fn.__pmem_rehydration_entry__ = True
    REHYDRATION_ENTRIES.add(_qualname(fn))
    return fn
