"""Invariant family (b): metadata-only recovery.

Functions decorated ``@metadata_only`` (repro.analysis.annotations)
promise that recovery/placement *decisions* read persisted metadata only
— ack records, catalog records, manifests, journals — never object
payload bytes. The promise is what makes ``restore_latest_recoverable``,
``DatasetCatalog.recoverable``, ``WorkflowScheduler.resume`` and the
repair scans cheap and probe-free after a node loss (CHANGES.md PRs
2-5 all assert "zero blind probes" in tests; this pass enforces it at
the source level).

The pass builds a project-wide call graph and walks it transitively
from every ``@metadata_only`` root. An *object read* is:

  * a call to ``get_with_manifest`` / ``read_leaf_slice`` (always), or
  * a ``.get(...)`` whose receiver smells like an object store or the
    external tier (``...store...``, ``...external...``, ``self.view``) —
    plain dict ``.get`` never matches.

Traversal stops at functions decorated ``@rehydration_entry``: reads
there are the sources of sanctioned copies (replicate/drain/stage-in),
not probes. Call resolution is heuristic but effective on this
codebase: ``self.m()`` resolves within the class, bare names within the
module, and otherwise a method name that is defined by exactly ONE
class in the analyzed set resolves to it (ambiguous names are not
traversed — the direct-read patterns above still apply at every site).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, FuncInfo, Module, call_name, src,
                                 walk_in_order)

ALWAYS_READ = {"get_with_manifest", "read_leaf_slice", "get_leaf"}
STOREISH = ("store", "external", "view")


def _is_object_read(name: str, recv: str) -> bool:
    if name in ALWAYS_READ:
        return True
    if name == "get":
        low = recv.lower()
        return any(s in low for s in STOREISH)
    return False


class _Graph:
    """Project-wide function index + heuristic call resolution."""

    def __init__(self, modules: List[Module]):
        self.modules = modules
        # global id: f"{mod.rel}::{qualname}"
        self.funcs: Dict[str, Tuple[Module, FuncInfo]] = {}
        # method name -> list of global ids (for unique-name resolution)
        self.by_method: Dict[str, List[str]] = {}
        for mod in modules:
            for q, fn in mod.functions.items():
                gid = f"{mod.rel}::{q}"
                self.funcs[gid] = (mod, fn)
                self.by_method.setdefault(q.rsplit(".", 1)[-1],
                                          []).append(gid)

    def resolve(self, mod: Module, fn: FuncInfo, name: str,
                recv: str) -> Optional[str]:
        # self.m() -> method on the same class
        if recv == "self" and fn.cls:
            gid = f"{mod.rel}::{fn.cls}.{name}"
            if gid in self.funcs:
                return gid
        # bare f() -> sibling nested function, then module function
        if not recv:
            parent = fn.qualname.rsplit(".", 1)[0] \
                if "." in fn.qualname else ""
            for scope in (fn.qualname, parent, ""):
                q = f"{scope}.{name}" if scope else name
                gid = f"{mod.rel}::{q}"
                if gid in self.funcs:
                    return gid
            return None
        # obj.m() -> unique method name across the project
        cands = [g for g in self.by_method.get(name, ())
                 if "." in self.funcs[g][1].qualname]
        if len(cands) == 1:
            return cands[0]
        return None

    def effects(self, gid: str) -> Tuple[List[Tuple[ast.Call, str]],
                                         List[Tuple[str, ast.Call]]]:
        """(object reads, resolved callees) of one function, nested
        closures included — a closure defined here runs in this flow."""
        mod, fn = self.funcs[gid]
        reads: List[Tuple[ast.Call, str]] = []
        calls: List[Tuple[str, ast.Call]] = []
        stack = [gid]
        seen = {gid}
        while stack:
            g = stack.pop()
            m, f = self.funcs[g]
            for child in f.children:
                cg = f"{m.rel}::{child}"
                if cg in self.funcs and cg not in seen:
                    seen.add(cg)
                    stack.append(cg)
            for node in walk_in_order(f.node):
                if not isinstance(node, ast.Call):
                    continue
                name, recv = call_name(node)
                if _is_object_read(name, recv):
                    reads.append((node, f"{recv}.{name}" if recv
                                  else name))
                target = self.resolve(m, f, name, recv)
                if target is not None and target != g:
                    calls.append((target, node))
        return reads, calls


def run(modules: List[Module]) -> List[Finding]:
    graph = _Graph(modules)
    findings: List[Finding] = []
    roots = [gid for gid, (mod, fn) in graph.funcs.items()
             if "metadata_only" in fn.decorators]
    for root in roots:
        mod, fn = graph.funcs[root]
        if mod.func_suppressed(fn, "metadata-only-read"):
            continue
        # BFS keeping one witness path per function
        paths: Dict[str, List[str]] = {root: [fn.qualname]}
        queue = [root]
        visited = {root}
        while queue:
            gid = queue.pop(0)
            gmod, gfn = graph.funcs[gid]
            if gid != root and "metadata_only" in gfn.decorators:
                continue  # an inner @metadata_only is its own root
            reads, calls = graph.effects(gid)
            for call, what in reads:
                if gmod.suppressed(call.lineno, "metadata-only-read"):
                    continue
                via = " -> ".join(paths[gid])
                findings.append(Finding(
                    "metadata-only-read", mod.rel, fn.node.lineno,
                    fn.qualname, f"{what}@{gfn.qualname}",
                    f"@metadata_only function reaches object-store "
                    f"read `{src(call)[:60]}` "
                    f"({gmod.rel}:{call.lineno}) via {via} -> "
                    f"{gfn.qualname} — route it through a "
                    f"@rehydration_entry or drop the annotation"))
            for target, _call in calls:
                tmod, tfn = graph.funcs[target]
                if "rehydration_entry" in tfn.decorators:
                    continue
                if target not in visited:
                    visited.add(target)
                    paths[target] = paths[gid] + [tfn.qualname]
                    queue.append(target)
    return findings
