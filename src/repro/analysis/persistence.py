"""Invariant family (a): persistence ordering.

The B-APM durability contract (``pmem.py``, ``meta_log.py``): a store is
durable only after an explicit ``flush()`` (CLWB+SFENCE analogue), and a
*commit point* — a committed-tail advance, an atomic ``put_json``
metadata rename, a log-compaction ``rename`` — must never be reached
while the bytes it commits are still unflushed. Four rules:

  missing-flush        a function writes a PMemRegion and never flushes
                       after its last write (dirty bytes escape the flow)
  commit-before-flush  a commit point follows a region write with no
                       intervening flush (the crash window the paper's
                       explicit-persistence model warns about)
  raw-pool-path        code outside pmem.py touches pool-directory paths
                       with raw file APIs, bypassing PMemRegion/put_json
                       (no flush discipline, no crash atomicity)
  silent-swallow       an except handler in a persistence path whose
                       body is only pass/continue — a failed flush or
                       commit must at least be counted/surfaced

Heuristics (documented, baseline-able): a "region" receiver is a name
bound from ``pool.create/open/extend/open_or_create`` in the same
function, or whose source mentions ``region``. Ordering is judged on
source order within one function body (nested defs are separate flows).
``pmem.py`` itself is exempt from the region rules (it IS the
implementation) but not from silent-swallow.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from repro.analysis.core import (Finding, FuncInfo, Module, call_name, src,
                                 walk_in_order)

#: calls that constitute a durability commit point
COMMIT_CALLS = {"put_json", "rename", "replace"}
#: direct persistence operations — a function containing any of these is
#: a "persistence path" for the silent-swallow rule
PERSIST_MARKERS = {"flush", "fsync", "put_json", "rename", "replace",
                   "write"}


def _is_region_recv(recv: str, region_vars: Set[str]) -> bool:
    if not recv:
        return False
    base = recv.split(".")[0].split("[")[0]
    return ("region" in recv) or (base in region_vars) or \
        (recv in region_vars)


def _region_vars(fn_node: ast.AST) -> Set[str]:
    """Names bound from pool region factories within this function."""
    out: Set[str] = set()
    for node in walk_in_order(fn_node, into_defs=True):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            name, _recv = call_name(node.value)
            if name in ("create", "open", "extend", "open_or_create"):
                # ``open`` the builtin returns a file, not a region —
                # require an attribute call (pool.open), not bare open()
                if isinstance(node.value.func, ast.Name):
                    continue
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out.add(t.id)
    return out


def _tail_write(call: ast.Call) -> bool:
    """A region write whose offset argument names the committed-tail
    header slot — itself a commit point."""
    if not call.args:
        return False
    return "TAIL" in src(call.args[0]).upper()


def _events(mod: Module, fn: FuncInfo) -> List[Tuple[str, ast.Call]]:
    """(kind, call) in source order: kind in {write, tailwrite, flush,
    commit}."""
    region_vars = _region_vars(fn.node)
    events: List[Tuple[str, ast.Call]] = []
    for node in walk_in_order(fn.node):
        if not isinstance(node, ast.Call):
            continue
        name, recv = call_name(node)
        if name == "write" and _is_region_recv(recv, region_vars):
            events.append(("tailwrite" if _tail_write(node) else "write",
                           node))
        elif name == "flush" and _is_region_recv(recv, region_vars):
            events.append(("flush", node))
        elif name in COMMIT_CALLS:
            # ``replace``/``rename`` only count when they smell like a
            # pool/os-level atomic swap, not str.replace etc.
            if name in ("rename", "replace"):
                if not (recv == "os" or "pool" in recv or recv == "self"):
                    continue
            events.append(("commit", node))
    events.sort(key=lambda e: (e[1].lineno, e[1].col_offset))
    return events


def _check_ordering(mod: Module, fn: FuncInfo,
                    findings: List[Finding]) -> None:
    events = _events(mod, fn)
    writes = [e for e in events if e[0] in ("write", "tailwrite")]
    if not writes:
        return
    # missing-flush: some flush must follow the last write
    last_kind, last_call = writes[-1]
    has_final_flush = any(k == "flush" and
                          (c.lineno, c.col_offset) >
                          (last_call.lineno, last_call.col_offset)
                          for k, c in events)
    if not has_final_flush and \
            not mod.suppressed(last_call.lineno, "missing-flush") and \
            not mod.func_suppressed(fn, "missing-flush"):
        findings.append(Finding(
            "missing-flush", mod.rel, last_call.lineno, fn.qualname,
            src(last_call.func),
            f"region write `{src(last_call)[:60]}` is never followed by "
            f"a flush() in this flow — the bytes may not be durable"))
    # commit-before-flush: every (write .. commit/tailwrite) pair needs
    # an intervening flush
    pending: Optional[ast.Call] = None
    for kind, call in events:
        if kind == "write":
            pending = call
        elif kind == "flush":
            pending = None
        elif kind in ("commit", "tailwrite"):
            if pending is not None:
                rule = "commit-before-flush"
                if not mod.suppressed(call.lineno, rule) and \
                        not mod.func_suppressed(fn, rule):
                    findings.append(Finding(
                        rule, mod.rel, call.lineno, fn.qualname,
                        src(call.func),
                        f"commit point `{src(call.func)}` reached with "
                        f"unflushed region write at line "
                        f"{pending.lineno} — a crash here commits bytes "
                        f"that were never flushed"))
            # a tail write is itself a write that must reach a flush
            pending = call if kind == "tailwrite" else None


RAW_FILE_CALLS = {"open", "replace", "rename", "unlink", "rmtree",
                  "write_text", "write_bytes", "remove", "truncate"}


def _check_raw_paths(mod: Module, fn: FuncInfo,
                     findings: List[Finding]) -> None:
    for node in walk_in_order(fn.node, into_defs=False):
        if not isinstance(node, ast.Call):
            continue
        name, recv = call_name(node)
        if name not in RAW_FILE_CALLS:
            continue
        text = src(node)
        if "pool.root" in text or "pool._path" in text or \
                ".pools[" in text:
            if mod.suppressed(node.lineno, "raw-pool-path"):
                continue
            findings.append(Finding(
                "raw-pool-path", mod.rel, node.lineno, fn.qualname, name,
                f"`{text[:70]}` touches a pmem pool directory with raw "
                f"file APIs — only pmem.py may do that (use "
                f"PMemRegion/put_json so flush+commit discipline holds)"))


def _check_silent_swallow(mod: Module, fn: FuncInfo,
                          findings: List[Finding]) -> None:
    has_persist = False
    for node in walk_in_order(fn.node):
        if isinstance(node, ast.Call):
            name, _ = call_name(node)
            if name in PERSIST_MARKERS:
                has_persist = True
                break
    if not has_persist:
        return
    for node in walk_in_order(fn.node):
        if not isinstance(node, ast.ExceptHandler):
            continue
        # ``continue`` in a fan-out loop is NOT a silent swallow: the
        # surrounding loop accounts successes and raises on zero (the
        # put_json_all_pools / _meta_put_json pattern). Only a body
        # that is literally just ``pass`` drops the failure on the
        # floor with no accounting at all.
        body_trivial = all(isinstance(s, ast.Pass) for s in node.body)
        if not body_trivial:
            continue
        # the disable comment may sit on the ``except`` line or on the
        # ``pass`` itself — both read naturally at the suppression site
        if any(mod.suppressed(ln, "silent-swallow")
               for ln in [node.lineno] + [s.lineno for s in node.body]):
            continue
        caught = src(node.type) if node.type else "<bare>"
        findings.append(Finding(
            "silent-swallow", mod.rel, node.lineno, fn.qualname, caught,
            f"`except {caught}: pass` in a persistence path swallows a "
            f"failed flush/commit silently — count it and/or warn "
            f"(see PMemPool.dir_fsync_failures for the pattern)"))


def run(modules: List[Module]) -> List[Finding]:
    findings: List[Finding] = []
    for mod in modules:
        is_pmem_impl = mod.rel.endswith("core/pmem.py")
        for fn in mod.functions.values():
            if not is_pmem_impl:
                _check_ordering(mod, fn, findings)
                _check_raw_paths(mod, fn, findings)
            _check_silent_swallow(mod, fn, findings)
    return findings
