"""Persistence-order sanitizer: a record-and-check shim over PMemRegion
and PMemPool.

While installed, every region write/flush/resize/close (and pool-level
delete/rename) is intercepted and logged as an event stream, and the
B-APM ordering discipline is checked *as it happens*:

  * **committed-tail discipline** — a MetaLog tail advance (an 8-byte
    write at the header's tail slot on a region carrying the MLOG magic)
    must never commit bytes that are still unflushed: every byte in
    ``[HDR_SIZE, new_tail)`` must have been flushed before the tail
    write lands. Violating this is exactly the torn-append crash bug the
    committed-tail design exists to rule out.
  * **no dirty drops** — a region must never be deleted, renamed-over or
    (at teardown) left live while dirty on a live pool. ``PMemRegion``
    tracks ``dirty`` (the surfaced ``_flushed`` flag); the sanitizer
    asserts nobody abandons dirty bytes.

With ``capture=True`` the shim additionally keeps the written bytes, so
``crash_images()`` can *enumerate torn-write crash states*: for every
prefix of the recorded stream it yields the byte image a crash there
could leave — unflushed stores not yet persistent, all persistent (cache
eviction wrote them back early), and a half-applied final store (a torn
write). Feeding those images back through ``MetaLog`` replay (see
``materialize`` + tests/test_analysis.py and the ``--pmem-sanitize``
pytest flag wired in tests/conftest.py) proves replay lands on a
committed prefix for EVERY reachable crash state, not just the happy
path.

Violations are collected, not raised inline (an assert inside a
scheduler worker thread would be swallowed by the future); call
``raise_violations()`` — the pytest fixture does — to fail the test.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import pmem as _pmem

_MLOG_MAGIC = b"MLOG1\x00"
# the obs flight recorder (repro.obs.recorder) stores its committed
# tail at the same header slot under the same discipline — the tail
# check below covers both log formats
_OBS_MAGIC = b"OBSR1\x00"
_TAILED_MAGICS = (_MLOG_MAGIC, _OBS_MAGIC)
_TAIL_OFF = 8
_HDR_SIZE = 64


class _RegionState:
    __slots__ = ("path", "nbytes", "dirty", "unflushed", "events",
                 "initial", "pool_dead", "closed")

    def __init__(self, path: str, nbytes: int, initial: Optional[bytes]):
        self.path = path
        self.nbytes = nbytes
        self.dirty = False
        #: [start, end) byte ranges written since the last flush
        self.unflushed: List[Tuple[int, int]] = []
        #: (op, offset, payload-bytes-or-None) — capture mode keeps data
        self.events: List[Tuple[str, int, Optional[bytes]]] = []
        self.initial = initial
        self.pool_dead = False
        self.closed = False


class PMemSanitizer:
    """Monkeypatching shim; use as a context manager or via the
    ``--pmem-sanitize`` pytest flag (tests/conftest.py)."""

    def __init__(self, capture: bool = False,
                 max_capture_bytes: int = 8 << 20):
        self.capture = capture
        self.max_capture_bytes = max_capture_bytes
        self.violations: List[str] = []
        self.regions: Dict[str, _RegionState] = {}
        self.stats = {"writes": 0, "flushes": 0, "tail_advances": 0,
                      "closes": 0}
        self._lock = threading.RLock()
        self._orig: Dict[str, Callable] = {}
        self._installed = False

    # ---- lifecycle ---------------------------------------------------
    def install(self) -> "PMemSanitizer":
        if self._installed:
            return self
        san = self
        R, P = _pmem.PMemRegion, _pmem.PMemPool
        self._orig = {"r_init": R.__init__, "r_write": R.write,
                      "r_flush": R.flush, "r_resize": R.resize,
                      "r_close": R.close, "p_delete": P.delete,
                      "p_rename": P.rename}

        def r_init(self, path, nbytes, create):
            san._orig["r_init"](self, path, nbytes, create)
            san._on_open(self, create)

        def r_write(self, offset, data):
            san._orig["r_write"](self, offset, data)
            san._on_write(self, offset, data)

        def r_flush(self):
            san._orig["r_flush"](self)
            san._on_flush(self)

        def r_resize(self, nbytes):
            san._orig["r_resize"](self, nbytes)
            san._on_resize(self, nbytes)

        def r_close(self):
            san._on_close(self)
            san._orig["r_close"](self)

        def p_delete(self, name):
            san._on_drop(self, name, "delete")
            san._orig["p_delete"](self, name)

        def p_rename(self, src, dst):
            san._on_drop(self, dst, "rename-over")
            san._orig["p_rename"](self, src, dst)

        R.__init__, R.write, R.flush = r_init, r_write, r_flush
        R.resize, R.close = r_resize, r_close
        P.delete, P.rename = p_delete, p_rename
        self._installed = True
        return self

    def uninstall(self) -> None:
        if not self._installed:
            return
        R, P = _pmem.PMemRegion, _pmem.PMemPool
        R.__init__ = self._orig["r_init"]
        R.write = self._orig["r_write"]
        R.flush = self._orig["r_flush"]
        R.resize = self._orig["r_resize"]
        R.close = self._orig["r_close"]
        P.delete = self._orig["p_delete"]
        P.rename = self._orig["p_rename"]
        self._installed = False

    def __enter__(self) -> "PMemSanitizer":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()
        if not exc[0]:
            self.raise_violations()

    # ---- event hooks -------------------------------------------------
    def _state(self, region) -> _RegionState:
        key = str(region.path)
        st = self.regions.get(key)
        if st is None:
            st = _RegionState(key, region.nbytes, None)
            self.regions[key] = st
        return st

    def _on_open(self, region, create: bool) -> None:
        with self._lock:
            key = str(region.path)
            initial = None
            if self.capture and region.nbytes <= self.max_capture_bytes:
                initial = b"\x00" * region.nbytes if create \
                    else bytes(region._mm)
            st = _RegionState(key, region.nbytes, initial)
            st.events.append(("open", 0, None))
            self.regions[key] = st

    def _on_write(self, region, offset: int, data) -> None:
        buf = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
        with self._lock:
            st = self._state(region)
            self.stats["writes"] += 1
            payload = buf.tobytes() if (
                st.initial is not None and
                buf.nbytes <= self.max_capture_bytes) else None
            # committed-tail discipline: a tail advance on an MLOG
            # region must not cover unflushed entry bytes
            if offset == _TAIL_OFF and buf.nbytes == 8 and \
                    self._is_mlog(region):
                self.stats["tail_advances"] += 1
                new_tail = int.from_bytes(buf.tobytes(), "little")
                bad = [iv for iv in st.unflushed
                       if iv[0] < new_tail and iv[1] > _HDR_SIZE]
                if bad:
                    self.violations.append(
                        f"committed-tail: {st.path} advanced tail to "
                        f"{new_tail} over unflushed byte ranges {bad} — "
                        f"a crash now replays bytes that were never "
                        f"flushed (write -> flush -> tail -> flush)")
            st.unflushed.append((offset, offset + buf.nbytes))
            st.dirty = True
            st.events.append(("write", offset, payload))

    def _on_flush(self, region) -> None:
        with self._lock:
            st = self._state(region)
            self.stats["flushes"] += 1
            st.unflushed = []
            st.dirty = False
            st.events.append(("flush", 0, None))

    def _on_resize(self, region, nbytes: int) -> None:
        with self._lock:
            st = self._state(region)
            # resize flushes + remaps in pmem.py
            st.unflushed = []
            st.dirty = False
            st.nbytes = nbytes
            if st.initial is not None:
                img = self._replay_image(st, len(st.events))
                st.initial = img.ljust(nbytes, b"\x00")[:nbytes] \
                    if nbytes <= self.max_capture_bytes else None
                st.events = [("open", 0, None)]
            else:
                st.events.append(("resize", nbytes, None))

    def _on_close(self, region) -> None:
        with self._lock:
            st = self._state(region)
            self.stats["closes"] += 1
            # PMemRegion.close flushes when dirty — but a shimmed close
            # observing dirty bytes means SOME path relied on close()
            # for durability instead of flushing at its commit point;
            # surface it (the flush in close still runs afterwards).
            if st.dirty:
                self.violations.append(
                    f"dirty-close: {st.path} closed while dirty — the "
                    f"writing path never flushed; durability leaned on "
                    f"close() which a crash never calls")
            st.closed = True

    def _on_drop(self, pool, name: str, how: str) -> None:
        with self._lock:
            try:
                key = str(pool._path(name))
            except Exception:
                return
            st = self.regions.get(key)
            if st is None:
                return
            if st.dirty and getattr(pool, "alive", True):
                self.violations.append(
                    f"dirty-drop: {st.path} {how} while dirty — "
                    f"unflushed bytes were abandoned")
            st.closed = True
            st.dirty = False

    @staticmethod
    def _is_mlog(region) -> bool:
        try:
            return bytes(region._mm[:len(_MLOG_MAGIC)]) in _TAILED_MAGICS
        except Exception:
            return False

    # ---- teardown checks --------------------------------------------
    def check_no_dirty_regions(self) -> None:
        """Assert no live region was left dirty (dropped without a
        flush). Regions of dead pools (simulated node loss) and files
        already removed are crash debris, not bugs."""
        import os
        with self._lock:
            for st in self.regions.values():
                if st.dirty and not st.closed and os.path.exists(st.path):
                    self.violations.append(
                        f"dirty-teardown: {st.path} still dirty at "
                        f"teardown — a write path exited without flush")
                    st.dirty = False

    def raise_violations(self) -> None:
        self.check_no_dirty_regions()
        if self.violations:
            msgs = "\n  ".join(self.violations)
            raise AssertionError(
                f"pmem sanitizer: {len(self.violations)} persistence-"
                f"order violation(s):\n  {msgs}")

    # ---- crash-state enumeration (capture mode) ---------------------
    def _replay_image(self, st: _RegionState, upto: int,
                      *, persist_pending: bool = True,
                      tear_last: bool = False) -> bytes:
        img = bytearray(st.initial or b"")
        pending: List[Tuple[int, bytes]] = []

        def apply(off: int, data: bytes) -> None:
            end = off + len(data)
            if end > len(img):
                img.extend(b"\x00" * (end - len(img)))
            img[off:end] = data

        for i, (op, off, payload) in enumerate(st.events[:upto]):
            if op == "write" and payload is not None:
                pending.append((off, payload))
            elif op == "flush":
                for o, d in pending:
                    apply(o, d)
                pending = []
        if persist_pending:
            for k, (o, d) in enumerate(pending):
                if tear_last and k == len(pending) - 1:
                    apply(o, d[:len(d) // 2])
                else:
                    apply(o, d)
        return bytes(img)

    def crash_images(self, path_substr: str
                     ) -> Iterator[Tuple[str, bytes]]:
        """Enumerate byte images a crash could leave for every region
        whose path contains ``path_substr``. For each prefix of the
        event stream ending in a write, yields three states: ``lost``
        (no unflushed store persisted), ``persisted`` (cache eviction
        wrote everything back), and ``torn`` (the final store half-
        applied). Requires ``capture=True``."""
        if not self.capture:
            raise RuntimeError("crash_images needs PMemSanitizer("
                               "capture=True)")
        with self._lock:
            states = [st for st in self.regions.values()
                      if path_substr in st.path and st.initial is not None]
            for st in states:
                for i, (op, _off, _p) in enumerate(st.events):
                    if op != "write":
                        continue
                    upto = i + 1
                    yield (f"{st.path}@{upto}:lost",
                           self._replay_image(st, upto,
                                              persist_pending=False))
                    yield (f"{st.path}@{upto}:persisted",
                           self._replay_image(st, upto))
                    yield (f"{st.path}@{upto}:torn",
                           self._replay_image(st, upto, tear_last=True))

    @staticmethod
    def materialize(img: bytes, pool, name: str) -> None:
        """Write a crash image into ``pool`` under ``name`` through the
        sanctioned region API (create + write + flush), replacing any
        existing region — the replay half of crash-state enumeration."""
        if pool.exists(name):
            pool.delete(name)
        region = pool.create(name, max(len(img), 1))
        if img:
            region.write(0, np.frombuffer(img, dtype=np.uint8))
        region.flush()
