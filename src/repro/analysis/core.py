"""Shared infrastructure for the pmemlint AST passes.

Every pass works on ``Module`` objects (parsed files + a per-function
index) produced by ``collect``. Findings carry a *fingerprint* that is
stable under line drift (rule + file + function + key, no line numbers)
so the checked-in baseline survives unrelated edits; the printed report
still shows exact ``file:line`` locations.

Suppression: a ``# pmemlint: disable=<rule>[,<rule>...]`` comment on the
flagged line (or on the ``def`` line for function-level findings)
silences that rule there. Suppressions are for *reviewed* false
positives of the heuristics — new code should satisfy the invariant
instead.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Set, Tuple

_SUPPRESS_RE = re.compile(r"#\s*pmemlint:\s*disable=([\w,\-]+)")


@dataclass(frozen=True)
class Finding:
    rule: str          # e.g. "commit-before-flush"
    path: str          # repo-relative posix path
    line: int
    func: str          # qualified name within the module ("" = module)
    key: str           # stable detail key (attr/call name), not prose
    message: str       # human-readable explanation

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}|{self.path}|{self.func}|{self.key}"

    def render(self) -> str:
        where = f"{self.path}:{self.line}"
        scope = f" {self.func}:" if self.func else ""
        return f"{where} [{self.rule}]{scope} {self.message}"


@dataclass
class FuncInfo:
    """One function (or method, or nested closure) in a module."""
    qualname: str                  # "Class.method" / "func" / "f.<locals>.g"
    node: ast.AST
    cls: Optional[str]             # owning class name, if a method
    decorators: Set[str] = field(default_factory=set)
    #: nested functions defined inside this one (their effects run in
    #: this function's flow — closures are submitted as callbacks)
    children: List[str] = field(default_factory=list)


@dataclass
class Module:
    path: Path                     # absolute
    rel: str                       # repo-relative posix path
    tree: ast.Module
    source: str
    lines: List[str]
    functions: Dict[str, FuncInfo] = field(default_factory=dict)

    def suppressed(self, line: int, rule: str) -> bool:
        if 1 <= line <= len(self.lines):
            m = _SUPPRESS_RE.search(self.lines[line - 1])
            if m and rule in m.group(1).split(","):
                return True
        return False

    def func_suppressed(self, fn: FuncInfo, rule: str) -> bool:
        node = fn.node
        start = min((d.lineno for d in getattr(node, "decorator_list", [])),
                    default=node.lineno)
        for ln in range(start, node.lineno + 1):
            if self.suppressed(ln, rule):
                return True
        return False


def _decorator_names(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for d in getattr(node, "decorator_list", []):
        t = d.func if isinstance(d, ast.Call) else d
        if isinstance(t, ast.Name):
            out.add(t.id)
        elif isinstance(t, ast.Attribute):
            out.add(t.attr)
    return out


def _index_functions(mod: Module) -> None:
    def visit(node: ast.AST, qual: str, cls: Optional[str]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                visit(child, child.name, child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{qual}.{child.name}" if qual else child.name
                info = FuncInfo(q, child, cls, _decorator_names(child))
                mod.functions[q] = info
                if qual and qual in mod.functions:
                    mod.functions[qual].children.append(q)
                # nested defs scope under "<locals>"-free names: we use
                # plain dotted paths; collisions are not a concern for
                # lint addressing within one module
                visit(child, q, cls if cls and qual else None)
    visit(mod.tree, "", None)


def parse_module(path: Path, root: Path) -> Optional[Module]:
    try:
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
    except (OSError, SyntaxError):
        return None
    try:
        rel = path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        rel = path.as_posix()
    mod = Module(path, rel, tree, source, source.splitlines())
    _index_functions(mod)
    return mod


def collect(targets: List[Path], root: Path) -> List[Module]:
    """Parse every ``*.py`` under the target paths (files or dirs)."""
    files: List[Path] = []
    for t in targets:
        if t.is_dir():
            files.extend(sorted(t.rglob("*.py")))
        elif t.suffix == ".py":
            files.append(t)
    mods = []
    for f in files:
        if "__pycache__" in f.parts:
            continue
        m = parse_module(f, root)
        if m is not None:
            mods.append(m)
    return mods


def src(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:
        return ""


def call_name(call: ast.Call) -> Tuple[str, str]:
    """(callee name, receiver source) for a Call — receiver is "" for
    bare-name calls."""
    f = call.func
    if isinstance(f, ast.Name):
        return f.id, ""
    if isinstance(f, ast.Attribute):
        return f.attr, src(f.value)
    return "", ""


def walk_in_order(node: ast.AST, *, into_defs: bool = False
                  ) -> Iterator[ast.AST]:
    """Depth-first, source-order traversal of a function body. Nested
    function/lambda bodies are skipped unless ``into_defs`` — they run
    in a different flow (callbacks) and are indexed separately."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)) and not into_defs:
            continue
        yield child
        yield from walk_in_order(child, into_defs=into_defs)


LOCKISH = re.compile(r"lock", re.IGNORECASE)


def lock_items(node: ast.With) -> List[str]:
    """Sources of the with-items that look like locks."""
    out = []
    for item in node.items:
        s = src(item.context_expr)
        if LOCKISH.search(s) and "Lock(" not in s:
            out.append(s)
    return out
