"""pmemlint driver.

    python -m repro.analysis.lint src/repro
    python -m repro.analysis.lint src/repro --update-baseline
    python -m repro.analysis.lint src/repro --no-baseline   # raw report

Runs the three invariant families (persistence ordering, metadata-only
recovery, lock discipline) over the target paths and diffs the findings
against the checked-in baseline (``src/repro/analysis/baseline.json``).
Exit status 1 iff there are NEW findings — CI fails on regressions, not
on the reviewed legacy set. Baseline entries are line-number-free
fingerprints, so unrelated edits never churn the file; entries that no
longer fire are reported as stale (fix the baseline with
``--update-baseline`` once the cleanup lands).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List

from repro.analysis import locks, persistence, recovery
from repro.analysis.core import Finding, collect

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"

PASSES = (
    ("persistence-ordering", persistence.run),
    ("metadata-only-recovery", recovery.run),
    ("lock-discipline", locks.run),
)


def run_lint(targets: List[Path], root: Path) -> List[Finding]:
    modules = collect(targets, root)
    findings: List[Finding] = []
    for _family, fn in PASSES:
        findings.extend(fn(modules))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def load_baseline(path: Path) -> List[str]:
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    return list(data.get("findings", []))


def save_baseline(path: Path, findings: List[Finding]) -> None:
    payload = {
        "comment": "pmemlint baseline: reviewed pre-existing findings. "
                   "CI fails only on findings NOT in this list. "
                   "Regenerate with: python -m repro.analysis.lint "
                   "src/repro --update-baseline",
        "findings": sorted({f.fingerprint for f in findings}),
    }
    path.write_text(json.dumps(payload, indent=1) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="pmem data-plane invariant lint (pmemlint)")
    ap.add_argument("paths", nargs="+", help="files or directories")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE)
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding; exit 1 if any")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from this run and exit 0")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="print only new findings and the summary")
    args = ap.parse_args(argv)

    root = Path.cwd()
    targets = [Path(p) for p in args.paths]
    for t in targets:
        if not t.exists():
            print(f"pmemlint: no such path: {t}", file=sys.stderr)
            return 2
    findings = run_lint(targets, root)

    if args.update_baseline:
        save_baseline(args.baseline, findings)
        print(f"pmemlint: baseline updated: {len(findings)} finding(s) "
              f"-> {args.baseline}")
        return 0

    baseline = set() if args.no_baseline else \
        set(load_baseline(args.baseline))
    new = [f for f in findings if f.fingerprint not in baseline]
    old = [f for f in findings if f.fingerprint in baseline]
    stale = baseline - {f.fingerprint for f in findings}

    if old and not args.quiet:
        print(f"-- {len(old)} baselined finding(s) (not failing):")
        for f in old:
            print(f"   {f.render()}")
    if stale and not args.quiet:
        print(f"-- {len(stale)} stale baseline entr(ies) — no longer "
              f"fire; prune with --update-baseline:")
        for fp in sorted(stale):
            print(f"   {fp}")
    if new:
        print(f"-- {len(new)} NEW finding(s):")
        for f in new:
            print(f"   {f.render()}")
    print(f"pmemlint: {len(findings)} finding(s): {len(new)} new, "
          f"{len(old)} baselined, {len(stale)} stale baseline")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
