"""Jit'd wrapper: model layout [B,S,H,P] <-> kernel layout [B,H,S,P]."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_bhsp


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
        c: jax.Array, *, chunk: int = 256, interpret: bool = False):
    """x [B,S,H,P]; dt [B,S,H]; a [H]; b,c [B,S,G,N].

    Returns (y [B,S,H,P], final_state [B,H,P,N]) matching
    models.ssm.ssd_chunked's contract.
    """
    xt = x.transpose(0, 2, 1, 3)
    dtt = dt.transpose(0, 2, 1).astype(jnp.float32)
    bt = b.transpose(0, 2, 1, 3)
    ct = c.transpose(0, 2, 1, 3)
    y, st = ssd_bhsp(xt, dtt, a.astype(jnp.float32), bt, ct, chunk=chunk,
                     interpret=interpret)
    return y.transpose(0, 2, 1, 3), st.transpose(0, 1, 3, 2)
