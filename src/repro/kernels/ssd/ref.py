"""Pure-jnp oracle for the SSD kernel: sequential state-space recurrence.

h_t = exp(dt_t a_h) h_{t-1} + dt_t B_t (x_t)^T ;  y_t = C_t^T h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
            c: jax.Array):
    """x [B,H,S,P]; dt [B,H,S]; a [H]; b,c [B,G,S,N].

    Returns (y [B,H,S,P] f32, final state [B,H,N,P] f32).
    """
    B, H, S, P = x.shape
    G, N = b.shape[1], b.shape[3]
    rep = H // G
    bh = jnp.repeat(b, rep, axis=1)  # [B,H,S,N]
    ch = jnp.repeat(c, rep, axis=1)

    def step(h, xs):
        xt, dtt, bt, ct = xs  # [B,H,P],[B,H],[B,H,N],[B,H,N]
        decay = jnp.exp(dtt * a)  # [B,H]
        upd = jnp.einsum("bhn,bhp->bhnp", bt, xt * dtt[..., None])
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhnp,bhn->bhp", h, ct)
        return h, y

    xs = (x.transpose(2, 0, 1, 3).astype(jnp.float32),
          dt.transpose(2, 0, 1).astype(jnp.float32),
          bh.transpose(2, 0, 1, 3).astype(jnp.float32),
          ch.transpose(2, 0, 1, 3).astype(jnp.float32))
    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    hf, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 2, 0, 3), hf
