"""Mamba2 SSD (state-space duality) chunked-scan Pallas kernel.

Grid = (batch, heads, num_chunks) with the chunk axis innermost/sequential;
the running SSM state (one [N, P] tile) persists in VMEM scratch across
chunks. Within a chunk the intra-chunk term is a pair of [Q,Q]x[Q,P] MXU
matmuls (the "duality": the quadratic attention-like form), and the
inter-chunk term is two [Q,N]x[N,P] matmuls against the carried state —
exactly the decomposition from arXiv:2405.21060 mapped onto MXU tiles.

B/C are per-group; the index_map folds head -> group so grouped B/C tensors
are streamed without materializing the head-broadcast copies in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref,
                h_scr, *, q: int, nc: int):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)       # [q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)     # [1, 1, q] (row layout)
    a = a_ref[0].astype(jnp.float32)          # scalar decay coeff
    bb = b_ref[0, 0].astype(jnp.float32)      # [q, N]
    cc = c_ref[0, 0].astype(jnp.float32)      # [q, N]

    da = (dt * a).reshape(q)                  # [q] negative
    cs = jnp.cumsum(da)                       # [q]
    xdt = x * dt.reshape(q, 1)                # [q, P]

    # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i >= j
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(ii >= jj, jnp.exp(cs[:, None] - cs[None, :]), 0.0)
    scores = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [q,q]
    y = jax.lax.dot_general(scores * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # [q,P]

    # inter-chunk: y += (C * exp(cs)) @ h_prev
    h_prev = h_scr[...]                       # [N, P]
    c_dec = cc * jnp.exp(cs)[:, None]
    y = y + jax.lax.dot_general(c_dec, h_prev, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: h = exp(cs[-1]) h_prev + B^T diag(exp(cs[-1]-cs)) Xdt
    b_dec = bb * jnp.exp(cs[-1] - cs)[:, None]                        # [q,N]
    contrib = jax.lax.dot_general(b_dec, xdt, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    h_scr[...] = h_prev * jnp.exp(cs[-1]) + contrib

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(cj == nc - 1)
    def _emit_state():
        st_ref[0, 0] = h_scr[...].astype(st_ref.dtype)


def ssd_bhsp(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 256, interpret: bool = False):
    """x [B,H,S,P]; dt [B,H,S]; a [H]; b,c [B,G,S,N] (H % G == 0).

    Returns (y [B,H,S,P], final_state [B,H,N,P]).
    """
    B, H, S, P = x.shape
    G, N = b.shape[1], b.shape[3]
    q = min(chunk, S)
    assert S % q == 0 and H % G == 0
    nc = S // q
    rep = H // G
    dt2 = dt.reshape(B, H, nc, 1, q)  # row-major [1, q] tiles

    kernel = functools.partial(_ssd_kernel, q=q, nc=nc)
    grid = (B, H, nc)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, P), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, 1, 1, q),
                         lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1,), lambda b_, h_, c_: (h_,)),
            pl.BlockSpec((1, 1, q, N),
                         lambda b_, h_, c_, r=rep: (b_, h_ // r, c_, 0)),
            pl.BlockSpec((1, 1, q, N),
                         lambda b_, h_, c_, r=rep: (b_, h_ // r, c_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, P), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt2, a, b, c)
    return y, st
