"""Pure-jnp oracle for the grouped matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gmm_ref(x_sorted: jax.Array, w: jax.Array,
            block_expert: jax.Array, bt: int) -> jax.Array:
    """Gather each block's expert weights and matmul. [T,D]x[E,D,F]->[T,F]."""
    t, d = x_sorted.shape
    nblk = t // bt
    xb = x_sorted.reshape(nblk, bt, d)
    wb = w[block_expert]  # [nblk, D, F]
    return jnp.einsum("ntd,ndf->ntf", xb, wb,
                      preferred_element_type=jnp.float32) \
        .astype(x_sorted.dtype).reshape(t, -1)
