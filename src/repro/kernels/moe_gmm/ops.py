"""Sorted-token MoE expert FFN built on the gmm kernel.

``sort_tokens_by_expert`` produces the block-aligned sorted layout
(capacity-free: every token is kept; groups are padded to the token-block
size with zero rows routed to their own expert slot).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.moe_gmm.kernel import gmm


def sort_tokens_by_expert(x: jax.Array, expert_ids: jax.Array, n_experts: int,
                          bt: int = 128
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [T, D]; expert_ids [T] -> (x_sorted [Ts, D], block_expert [Ts//bt],
    inverse_perm [T]) where Ts pads each group to a bt multiple.

    Layout: each expert e gets cap = next multiple of bt >= its max count;
    we use a static worst-case cap = ceil(T / bt) * bt per expert would be
    huge, so instead tokens are sorted by expert and blocks may straddle a
    boundary only at padded rows: we pad with ghost rows (expert id = its
    block's majority) whose outputs are dropped by inverse_perm.

    Simplification used here (exactness preserved): sort by expert, then pad
    the *total* count to a bt multiple; a block containing a group boundary
    is split by assigning it to the *first* group and masking rows of other
    groups to zero so their contribution is recomputed in the next block.
    For exactness without masking complexity, ops uses per-expert static
    capacity = ceil(T/ n_experts * 2 / bt)*bt slots (cap-and-pad), which is
    also what the distributed ETP path produces.
    """
    t, d = x.shape
    cap = -(-t // bt) * bt  # per-expert capacity, block aligned (worst case)
    order = jnp.argsort(expert_ids, stable=True)
    x_sorted_raw = x[order]
    ids_sorted = expert_ids[order]
    # position of each sorted token within its expert group
    ranks = jnp.arange(t) - jnp.searchsorted(ids_sorted, ids_sorted,
                                             side="left")
    slots = ids_sorted * cap + ranks
    buf = jnp.zeros((n_experts * cap, d), x.dtype).at[slots].set(x_sorted_raw)
    block_expert = (jnp.arange(n_experts * cap // bt) * bt) // cap
    return buf, block_expert.astype(jnp.int32), (order, slots)


def unsort(y_buf: jax.Array, meta, t: int) -> jax.Array:
    order, slots = meta
    y_sorted = y_buf[slots]
    return jnp.zeros((t, y_buf.shape[-1]), y_buf.dtype).at[order] \
        .set(y_sorted)


@functools.partial(jax.jit,
                   static_argnames=("n_experts", "bt", "bf", "interpret"))
def moe_ffn_sorted(x: jax.Array, expert_ids: jax.Array, wi: jax.Array,
                   wg: jax.Array, wo: jax.Array, *, n_experts: int,
                   bt: int = 128, bf: int = 512,
                   interpret: bool = False) -> jax.Array:
    """Full expert FFN over sorted tokens. x [T,D]; w* [E,D,F]/[E,F,D]."""
    t = x.shape[0]
    buf, block_expert, meta = sort_tokens_by_expert(x, expert_ids, n_experts,
                                                    bt)
    h = gmm(buf, wi, block_expert, bt=bt, bf=bf, interpret=interpret)
    g = gmm(buf, wg, block_expert, bt=bt, bf=bf, interpret=interpret)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    y = gmm(h, wo, block_expert, bt=bt, bf=min(bf, wo.shape[-1]),
            interpret=interpret)
    return unsort(y, meta, t)
