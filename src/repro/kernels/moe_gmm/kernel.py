"""Grouped (ragged) expert matmul — megablox-style Pallas kernel.

Tokens arrive sorted by expert with every expert group padded to a multiple
of the token block ``bt`` (ops.py builds this layout), so each [bt, D] token
tile multiplies exactly one expert's weights. The expert id per token block
is a scalar-prefetch operand: the weight BlockSpec index_map reads
``block_expert[i]`` to stream the right [D, bf] expert tile into VMEM —
no gather/scatter inside the kernel, pure MXU work.

Tile sizes: bt x D and D x bf tiles are chosen 128-aligned by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(block_expert_ref, x_ref, w_ref, o_ref):
    del block_expert_ref  # consumed by the index maps
    o_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def gmm(x_sorted: jax.Array, w: jax.Array, block_expert: jax.Array, *,
        bt: int = 128, bf: int = 512, interpret: bool = False) -> jax.Array:
    """x_sorted [T, D] (expert-sorted, block-aligned groups); w [E, D, F];
    block_expert [T // bt] int32. Returns [T, F]."""
    t, d = x_sorted.shape
    e, _, f = w.shape
    bf = min(bf, f)
    assert t % bt == 0 and f % bf == 0
    grid = (t // bt, f // bf)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j, be: (i, 0)),
            pl.BlockSpec((1, d, bf), lambda i, j, be: (be[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((bt, bf), lambda i, j, be: (i, j)),
    )
    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((t, f), x_sorted.dtype),
        interpret=interpret,
    )(block_expert, x_sorted, w)
