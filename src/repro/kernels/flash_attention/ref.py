"""Pure-jnp oracle for the flash attention kernel (naive full softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                  window: int = 0, cap: float = 0.0) -> jax.Array:
    """q: [B,H,Sq,D]; k,v: [B,Kh,Sk,D]. Returns [B,H,Sq,D] (q.dtype)."""
    b, h, sq, d = q.shape
    kh = k.shape[1]
    group = h // kh
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * (d ** -0.5)
    if cap > 0:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)) \
        .astype(q.dtype)
