"""Jit'd wrapper: model layout [B,S,Kh,G,Dh] <-> kernel layout [B,H,S,D]."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "interpret", "bq", "bk"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, window: int = 0, cap: float = 0.0,
                    interpret: bool = False, bq: int = 512,
                    bk: int = 512) -> jax.Array:
    """q [B,S,H,Dh] (flat group-major heads); k,v [B,Sk,Kh,Dh]
    -> [B,S,H,Dh]."""
    b, s, h, dh = q.shape
    qh = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_bhsd(qh, kt, vt, causal=causal, window=window,
                             cap=cap, bq=bq, bk=bk, interpret=interpret)
    return o.transpose(0, 2, 1, 3)
