"""Flash attention Pallas TPU kernel.

Tiling: grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the KV-block
axis is the innermost ("arbitrary"/sequential) grid dim, so the online
softmax accumulators (m, l, acc) live in VMEM scratch and persist across KV
iterations. Q/K/V blocks are (bq, d) / (bk, d) VMEM tiles, d and the block
sizes chosen 128-aligned for the MXU by ops.py.

Supports: causal masking, sliding-window masking (with block-level skipping
of fully-masked tiles), logit softcap (gemma2/grok), and GQA via an
index_map that folds q-head -> kv-head (h // group).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, cap: float,
                  bq: int, bk: int, nk: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = kj * bk
    # Block-level skip: causal -> KV block strictly above the diagonal;
    # window -> KV block entirely older than the window reach.
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window > 0:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0, 0]                              # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]
        if cap > 0:
            s = cap * jnp.tanh(s / cap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        m_safe = jnp.maximum(m_new, -1e30)
        p = jnp.exp(s - m_safe)
        alpha = jnp.exp(jnp.maximum(m_prev, -1e30) - m_safe)
        l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool, window: int = 0, cap: float = 0.0,
                         bq: int = 512, bk: int = 512,
                         interpret: bool = False) -> jax.Array:
    """q: [B, H, Sq, D]; k, v: [B, Kh, Sk, D] with H % Kh == 0.

    Returns [B, H, Sq, D].
    """
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    assert h % kh == 0
    group = h // kh
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0
    nq, nk = sq // bq, sk // bk
    scale = d ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, cap=cap,
        bq=bq, bk=bk, nk=nk)
    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, i, j, g=group: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, i, j, g=group: (b_, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
