"""Jit'd wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru.kernel import rglru_blocked


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def rglru(log_a: jax.Array, gated: jax.Array, *, block: int = 256,
          interpret: bool = False) -> jax.Array:
    """log_a, gated [B,S,W] f32 -> h [B,S,W] f32."""
    return rglru_blocked(log_a.astype(jnp.float32),
                         gated.astype(jnp.float32), bs=block,
                         interpret=interpret)
