"""RG-LRU blocked linear-recurrence Pallas kernel.

h_t = a_t * h_{t-1} + b_t,  a_t = exp(log_a_t),
b_t = sqrt(1 - a_t^2) * gated_t.

Tiling: grid = (batch, channel_blocks, seq_blocks) with the sequence axis
innermost/sequential; the running hidden state h (one row of bw channels)
persists in VMEM scratch across sequence blocks. Within a block the
recurrence is solved with a log2(bs)-step inclusive scan on the VPU
(elementwise ops only — the recurrence is diagonal, so there is no MXU
work; the kernel exists to keep the whole scan in VMEM in one pass over
HBM, which is what makes it memory-bound-optimal on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(loga_ref, gated_ref, o_ref, h_scr, *, bs: int, bw: int):
    sj = pl.program_id(2)

    @pl.when(sj == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    log_a = loga_ref[0].astype(jnp.float32)          # [bs, bw]
    gated = gated_ref[0].astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12)) * gated

    # In-block inclusive scan (Blelloch-style doubling on dense arrays):
    # after k rounds, (A[t], B[t]) compose the last 2^k steps ending at t.
    av, bv = a, b
    shift = 1
    while shift < bs:
        a_prev = jnp.pad(av, ((shift, 0), (0, 0)), constant_values=1.0)[:bs]
        b_prev = jnp.pad(bv, ((shift, 0), (0, 0)))[:bs]
        bv = bv + av * b_prev
        av = av * a_prev
        shift *= 2
    # av[t] = prod a_{0..t}, bv[t] = h_t given h_{-1}=0; add carry term.
    h0 = h_scr[...]                                   # [1, bw]
    h = bv + av * h0
    o_ref[0] = h.astype(o_ref.dtype)
    h_scr[...] = h[-1:, :]


def rglru_blocked(log_a: jax.Array, gated: jax.Array, *, bs: int = 256,
                  bw: int = 512, interpret: bool = False) -> jax.Array:
    """log_a, gated: [B, S, W] (f32). Returns h [B, S, W] (f32)."""
    B, S, W = log_a.shape
    bs = min(bs, S)
    bw = min(bw, W)
    assert S % bs == 0 and W % bw == 0, (S, bs, W, bw)
    grid = (B, W // bw, S // bs)
    kernel = functools.partial(_rglru_kernel, bs=bs, bw=bw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda b, w, s: (b, s, w)),
            pl.BlockSpec((1, bs, bw), lambda b, w, s: (b, s, w)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda b, w, s: (b, s, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(log_a, gated)
