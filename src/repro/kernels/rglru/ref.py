"""Pure-jnp oracle for the RG-LRU scan kernel (sequential recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(log_a: jax.Array, gated: jax.Array) -> jax.Array:
    """Sequential h_t = a_t h_{t-1} + sqrt(1-a_t^2) gated_t.

    log_a, gated: [B, S, W] f32 -> h [B, S, W] f32.
    """
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12)) * gated

    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h

    _, h = jax.lax.scan(step, jnp.zeros_like(a[:, 0]),
                        (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return h.transpose(1, 0, 2)
