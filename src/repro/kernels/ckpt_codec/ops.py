"""Jit'd wrappers: flatten/pad arbitrary arrays through the tile codec."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ckpt_codec.kernel import TILE, decode_tiles, encode_tiles


def _to_tiles(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % TILE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, TILE), n


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_encode(new: jax.Array, base: jax.Array, *,
                 interpret: bool = False):
    """Any-shape arrays -> (q int8 [n_tiles, TILE], scales [n_tiles, 1])."""
    nt, _ = _to_tiles(new)
    bt, _ = _to_tiles(base)
    return encode_tiles(nt, bt, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "interpret"))
def delta_decode(q: jax.Array, scales: jax.Array, base: jax.Array, *,
                 shape: Tuple[int, ...], dtype=jnp.bfloat16,
                 interpret: bool = False) -> jax.Array:
    bt, n = _to_tiles(base)
    out = decode_tiles(q, scales, bt, dtype=dtype, interpret=interpret)
    return out.reshape(-1)[:n].reshape(shape)
