"""Pure-jnp / numpy oracle for the delta-int8 codec (also the host-side
implementation used by the live checkpoint path on CPU)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

TILE = 1024


def encode_ref(new, base):
    d = np.asarray(new, np.float32) - np.asarray(base, np.float32)
    absmax = np.max(np.abs(d), axis=-1, keepdims=True)
    scale = np.maximum(absmax / 127.0, 1e-12)
    q = np.clip(np.round(d / scale), -127, 127).astype(np.int8)
    return q, scale.astype(np.float32)


def decode_ref(q, scale, base, dtype=np.float32):
    d = q.astype(np.float32) * scale
    return (np.asarray(base, np.float32) + d).astype(dtype)
