"""Checkpoint delta + int8 quantization codec Pallas kernels.

The paper's node-local B-APM checkpointing story is bandwidth-bound; this
codec cuts checkpoint (and compressed-collective) bytes ~4x by storing
``int8 round((new - base) / scale)`` with one f32 absmax scale per tile.

encode: (new, base) -> (q int8, scales f32)   [tiled (1, TILE) blocks]
decode: (q, scales, base) -> new'

Tiles are (1, 1024) = 8 VPU lanes x 128 — layout-friendly on TPU and on
the host-side numpy fallback used by the live checkpoint path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024


def _encode_kernel(new_ref, base_ref, q_ref, scale_ref):
    d = new_ref[...].astype(jnp.float32) - base_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(d), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(d / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale.astype(jnp.float32)


def _decode_kernel(q_ref, scale_ref, base_ref, out_ref):
    d = q_ref[...].astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
    out_ref[...] = (base_ref[...].astype(jnp.float32) + d) \
        .astype(out_ref.dtype)


def encode_tiles(new: jax.Array, base: jax.Array, *,
                 interpret: bool = False):
    """new, base: [n_tiles, TILE] -> (q int8 [n,TILE], scales f32 [n,1])."""
    n = new.shape[0]
    return pl.pallas_call(
        _encode_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, TILE), lambda i: (i, 0)),
                  pl.BlockSpec((1, TILE), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, TILE), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, TILE), jnp.int8),
                   jax.ShapeDtypeStruct((n, 1), jnp.float32)],
        interpret=interpret,
    )(new, base)


def decode_tiles(q: jax.Array, scales: jax.Array, base: jax.Array, *,
                 dtype=jnp.bfloat16, interpret: bool = False) -> jax.Array:
    n = q.shape[0]
    return pl.pallas_call(
        _decode_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, TILE), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0)),
                  pl.BlockSpec((1, TILE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, TILE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, TILE), dtype),
        interpret=interpret,
    )(q, scales, base)
