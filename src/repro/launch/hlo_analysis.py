"""Trip-count-aware cost analysis over optimized HLO text.

XLA's ``HloCostAnalysis`` (surfaced as ``compiled.cost_analysis()``) visits
``while`` bodies ONCE — for a scan-over-layers model that undercounts FLOPs
and collective bytes by ~(layers x microbatches). This analyzer parses the
post-SPMD optimized HLO, recovers loop trip counts from loop-condition
constants, and accumulates per-instruction costs scaled by the enclosing
loops' trip product:

  flops        - 2 * prod(out dims) * prod(contracted lhs dims) per dot
  hbm bytes    - per top-level instruction: operand bytes + output bytes
                 (fusion internals excluded -> intermediates stay on-chip,
                  which matches XLA's fusion semantics)
  collectives  - result bytes + ring-model wire bytes per kind

All shapes in the SPMD module are per-device, so every number it returns is
per-device. Validated against hand-computed model FLOPs in tests.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^=]*?\))|(?:[\w]+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(s: str) -> List[int]:
    m = _SHAPE_RE.search(s)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: List[str]
    tail: str
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]


_COMMENT_RE = re.compile(r"/\*[^*]*\*/")


def _parse_stack_frames(text: str) -> Dict[int, str]:
    """stack_frame_id -> concatenated function-name chain, from the
    FunctionNames / FileLocations / StackFrames header tables."""
    fn_names: Dict[int, str] = {}
    file_locs: Dict[int, int] = {}
    frames: Dict[int, Tuple[int, int]] = {}
    section = None
    for line in text.splitlines()[:20000]:
        s = line.strip()
        if s in ("FunctionNames", "FileLocations", "StackFrames",
                 "FileNames"):
            section = s
            continue
        if not s or s.startswith(("HloModule", "%", "ENTRY")):
            if s and not s[0].isdigit():
                section = None
            if not s:
                continue
        if section == "FunctionNames":
            m = re.match(r'(\d+)\s+"(.*)"', s)
            if m:
                fn_names[int(m.group(1))] = m.group(2)
        elif section == "FileLocations":
            m = re.match(r"(\d+)\s+\{.*function_name_id=(\d+)", s)
            if m:
                file_locs[int(m.group(1))] = int(m.group(2))
        elif section == "StackFrames":
            m = re.match(
                r"(\d+)\s+\{file_location_id=(\d+)"
                r"(?:\s+parent_frame_id=(\d+))?", s)
            if m:
                frames[int(m.group(1))] = (int(m.group(2)),
                                           int(m.group(3) or 0))
    out: Dict[int, str] = {}

    def chain(fid: int, depth: int = 0) -> str:
        if fid == 0 or fid not in frames or depth > 12:
            return ""
        loc, parent = frames[fid]
        name = fn_names.get(file_locs.get(loc, -1), "")
        return chain(parent, depth + 1) + "/" + name

    for fid in frames:
        out[fid] = chain(fid)
    return out


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)
        stripped = line.strip()
        if cur is None:
            # computation header: "%name (args...) -> ret {" or "ENTRY %..."
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_RE.match(stripped)
                if m:
                    cur = Computation(m.group(1), [], {})
            continue
        if stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, op, rest = m.groups()
        # operand names = %refs before any attribute section
        paren_depth, cut = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                paren_depth += 1
            elif ch == ")":
                paren_depth -= 1
                if paren_depth == 0:
                    cut = i
                    break
        opnds = re.findall(r"%([\w.\-]+)", rest[:cut])
        tail = rest[cut:]
        instr = Instr(name, shape, op, opnds, tail, stripped)
        cur.instrs.append(instr)
        cur.shapes[name] = shape
    return comps


def _param_shapes_from_header(text: str) -> None:
    pass  # parameters appear as instructions ("%p = bf16[..] parameter(0)")


def _trip_count(cond: Computation) -> int:
    """Scan loops: counter compared LT/LE a constant. Take the max int
    constant in the condition computation (robust for jax scans)."""
    best = 1
    for ins in cond.instrs:
        if ins.op != "constant":
            continue
        mm = re.search(r"constant\((\d+)\)", ins.raw)
        if mm:
            best = max(best, int(mm.group(1)))
    return best


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(ins.shape):
        out_elems *= d
    lhs = shapes.get(ins.operands[0], "") if ins.operands else ""
    lhs_dims = _shape_dims(lhs)
    m = _CONTRACT_RE.search(ins.tail or "")
    contract = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def _conv_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    # rare in this codebase (conv1d is implemented with shifts); approximate
    # as 2 * out_elems * kernel_elems / out_channels-agnostic lower bound.
    out_elems = 1
    for d in _shape_dims(ins.shape):
        out_elems *= d
    rhs = shapes.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
    k = 1
    for d in _shape_dims(rhs):
        k *= d
    return 2.0 * out_elems * max(k, 1)


def analyze(text: str, n_devices: int) -> Dict:
    comps = parse_hlo(text)
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
    if entry is None:  # fall back: computation with a while or most instrs
        entry = max(comps, key=lambda c: len(comps[c].instrs))

    flops = 0.0
    bytes_hbm = 0.0      # pessimistic: every top-level op touches HBM
    bytes_fused = 0.0    # optimistic: elementwise chains fuse into producers
    coll = {k: {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0}
            for k in COLLECTIVES}
    # attribute bytes/flops to model regions via metadata op_name paths
    TAGS = (("attention", ("per_q_block", "kv_step", "_online_block",
                           "local_attention", "blockwise_attention",
                           "naive_attention", "attend", "_partial_attend")),
            ("norm", ("rms_norm", "layer_norm")),
            ("loss", ("chunked_ce", "log_softmax", "logsumexp")),
            ("moe", ("moe", "_dispatch", "_combine", "_expert_ffn",
                     "router")),
            ("ssm", ("ssd", "rglru")))
    bytes_by_tag = {t: 0.0 for t, _ in TAGS}
    bytes_by_tag["other"] = 0.0
    flops_by_tag = {t: 0.0 for t, _ in TAGS}
    flops_by_tag["other"] = 0.0

    frame_names = _parse_stack_frames(text)

    def _tag(ins) -> str:
        m = re.search(r'op_name="([^"]*)"', ins.raw)
        path = m.group(1) if m else ""
        fm = re.search(r"stack_frame_id=(\d+)", ins.raw)
        if fm:
            path = path + " " + frame_names.get(int(fm.group(1)), "")
        for t, keys in TAGS:
            if any(k in path for k in keys):
                return t
        return "other"

    visited_stack = set()
    # ops that necessarily move HBM bytes even under perfect fusion
    _MOVERS = ("dot", "convolution", "fusion", "copy", "scatter", "gather",
               "dynamic-update-slice", "dynamic-slice", "sort",
               "transpose", "reduce", "parameter")

    def fusion_operand_bytes(ins, comp) -> int:
        """Fusion operands that are only consumed via slice/gather INSIDE
        the fused computation contribute the sliced bytes, not the full
        operand (XLA reads just the slice region — critical for
        scan-over-stacked-layer-weights models)."""
        cc = re.search(r"calls=%?([\w.\-]+)", ins.tail or "")
        fused = comps.get(cc.group(1)) if cc else None
        total = 0
        for i, opnd in enumerate(ins.operands):
            full = _shape_bytes(comp.shapes.get(opnd, ""))
            if fused is None:
                total += full
                continue
            pname = None
            for fi in fused.instrs:
                if fi.op == "parameter" and f"parameter({i})" in fi.raw:
                    pname = fi.name
                    break
            if pname is None:
                total += full
                continue
            consumers = [fi for fi in fused.instrs if pname in fi.operands]
            if consumers and all(
                    fi.op in ("dynamic-slice", "slice", "gather")
                    for fi in consumers):
                total += sum(_shape_bytes(fi.shape) for fi in consumers)
            elif consumers and all(
                    fi.op == "dynamic-update-slice" and
                    fi.operands and fi.operands[0] == pname
                    for fi in consumers):
                # in-place update of a big buffer: traffic = update region
                total += sum(
                    _shape_bytes(fused.shapes.get(fi.operands[1], ""))
                    for fi in consumers if len(fi.operands) > 1)
            else:
                total += full
        return total

    def fusion_out_bytes(ins, comp) -> int:
        """A fusion whose root is dynamic-update-slice writes only the
        update region (buffer aliased in place)."""
        cc = re.search(r"calls=%?([\w.\-]+)", ins.tail or "")
        fused = comps.get(cc.group(1)) if cc else None
        if fused:
            for fi in fused.instrs:
                if fi.raw.startswith("ROOT") and \
                        fi.op == "dynamic-update-slice" and \
                        len(fi.operands) > 1:
                    return _shape_bytes(fused.shapes.get(fi.operands[1], ""))
        return _shape_bytes(ins.shape)

    def visit(comp_name: str, mult: float, count_bytes: bool = True):
        nonlocal flops, bytes_hbm, bytes_fused
        if comp_name not in comps or comp_name in visited_stack:
            return
        visited_stack.add(comp_name)
        comp = comps[comp_name]
        for ins in comp.instrs:
            op = ins.op
            if op == "fusion":
                # dots can be fused; count their FLOPs (bytes accounted at
                # the fusion boundary below).
                cc = re.search(r"calls=%?([\w.\-]+)", ins.tail or "")
                if cc:
                    visit(cc.group(1), mult, count_bytes=False)
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", ins.tail or "")
                cond = re.search(r"condition=%?([\w.\-]+)", ins.tail or "")
                tm = _TRIP_RE.search(ins.raw)
                if tm:
                    trips = int(tm.group(1))
                elif cond and cond.group(1) in comps:
                    trips = _trip_count(comps[cond.group(1)])
                else:
                    trips = 1
                if body:
                    visit(body.group(1), mult * trips)
                if cond:
                    visit(cond.group(1), mult * (trips + 1))
                continue
            if op == "conditional":
                for m in re.finditer(
                        r"(?:branch_computations=\{([^}]*)\}|"
                        r"true_computation=%?([\w.\-]+)|"
                        r"false_computation=%?([\w.\-]+))", ins.tail or ""):
                    for g in m.groups():
                        if g:
                            for nm in re.findall(r"%?([\w.\-]+)", g):
                                visit(nm, mult)
                continue
            if op in ("call", "async-start"):
                cc = re.search(r"to_apply=%?([\w.\-]+)", ins.tail or "")
                if cc:
                    visit(cc.group(1), mult)
            # ---- costs ----
            if op == "dot":
                f = _dot_flops(ins, comp.shapes)
                flops += mult * f
                flops_by_tag[_tag(ins)] += mult * f
            elif op == "convolution":
                f = _conv_flops(ins, comp.shapes)
                flops += mult * f
                flops_by_tag[_tag(ins)] += mult * f
            if count_bytes and op not in ("reshape", "bitcast", "tuple",
                                          "get-tuple-element", "constant",
                                          "while", "conditional", "call",
                                          "parameter"):
                out_b = _shape_bytes(ins.shape)
                if op in ("dynamic-slice", "gather"):
                    # reads only the sliced rows (~= output), not the operand
                    total = 2 * out_b
                elif op == "dynamic-update-slice":
                    # reads+writes the update slice, not the whole buffer
                    upd = _shape_bytes(comp.shapes.get(
                        ins.operands[1], "")) if len(ins.operands) > 1 else 0
                    total = 2 * upd
                elif op == "fusion":
                    total = fusion_operand_bytes(ins, comp) + \
                        fusion_out_bytes(ins, comp)
                else:
                    opb = sum(_shape_bytes(comp.shapes.get(o, ""))
                              for o in ins.operands)
                    total = opb + out_b
                bytes_hbm += mult * total
                if op in _MOVERS:
                    bytes_fused += mult * total
                    bytes_by_tag[_tag(ins)] += mult * total
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in COLLECTIVES:
                b = _shape_bytes(ins.shape)
                line_tail = ins.tail or ""
                gm = _GROUPS_RE.search(line_tail)
                if gm:
                    n = len([x for x in gm.group(1).split(",") if x.strip()])
                else:
                    gi = _GROUPS_IOTA_RE.search(line_tail)
                    n = int(gi.group(2)) if gi else n_devices
                n = max(n, 2)
                ring = (n - 1) / n
                factor = {"all-gather": ring, "reduce-scatter": ring,
                          "all-reduce": 2 * ring, "all-to-all": ring,
                          "collective-permute": 1.0}[base_op]
                coll[base_op]["count"] += mult
                coll[base_op]["bytes"] += mult * b
                coll[base_op]["wire_bytes"] += mult * b * factor
                bytes_fused += mult * 2 * b  # collectives also touch HBM
        visited_stack.discard(comp_name)

    visit(entry, 1.0)
    return {"flops": flops, "bytes": bytes_fused,
            "bytes_unfused": bytes_hbm, "collectives": coll,
            "wire_bytes": sum(c["wire_bytes"] for c in coll.values()),
            "bytes_by_tag": bytes_by_tag, "flops_by_tag": flops_by_tag}
