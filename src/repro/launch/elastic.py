"""Elastic restart driver: train on N nodes, checkpoint to node-local
pmem, then resume on a DIFFERENT node count / device mesh — shards are
re-cut by byte-range reads from the manifests (no full gather anywhere).
"""
from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ParallelConfig, ShapeConfig, registry
from repro.core.cluster import SimCluster
from repro.data.pipeline import StagedDataset
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import transformer as tfm
from repro.train import optimizer as opt
from repro.train import train_step as ts


def _build(cfg, shape, lr):
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = shd.Plan(mesh, cfg, shape, ParallelConfig())
    rt = plan.runtime()
    adamw = opt.AdamWConfig(lr=lr, warmup=10)
    step_fn = jax.jit(ts.make_train_step(cfg, rt, plan.constrain, adamw,
                                         ce_chunk=128))
    return rt, adamw, step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--nodes-before", type=int, default=4)
    ap.add_argument("--nodes-after", type=int, default=2)
    ap.add_argument("--root", default=None)
    args = ap.parse_args(argv)

    cfg = registry.get_smoke_config(args.arch)
    shape = ShapeConfig("cli", 32, 4, "train")
    rt, adamw, step_fn = _build(cfg, shape, 1e-3)
    params, _ = tfm.init_params(jax.random.PRNGKey(0), cfg, rt)
    opt_state = opt.init_opt_state(params, adamw)

    root = Path(args.root or tempfile.mkdtemp())
    c1 = SimCluster(root / "phase1", n_nodes=args.nodes_before)
    data = StagedDataset(c1, cfg, shape, n_shards=2, seqs_per_shard=16)
    losses = []
    for batch in data.batches(args.steps):
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
    c1.checkpointer.save(args.steps, {
        "params": jax.tree.map(np.asarray, params),
        "opt": jax.tree.map(np.asarray, opt_state)})
    c1.checkpointer.wait_async()
    print(f"phase1 ({args.nodes_before} nodes): loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}; checkpoint written node-locally")

    # ---- elastic: new cluster with different node count reads the same
    # pmem root via per-leaf byte-range reassembly ----
    c2 = SimCluster(root / "phase1", n_nodes=args.nodes_before)  # same pools
    restored, man = c2.checkpointer.restore(args.steps)
    params2 = jax.tree.map(jnp.asarray, restored["params"])
    opt2 = jax.tree.map(jnp.asarray, restored["opt"])
    # resume on the *smaller* logical cluster (new pools, new shard plan)
    c3 = SimCluster(root / "phase2", n_nodes=args.nodes_after)
    data2 = StagedDataset(c3, cfg, shape, n_shards=2, seqs_per_shard=16)
    losses2 = []
    for batch in data2.batches(args.steps):
        params2, opt2, m = step_fn(params2, opt2, batch)
        losses2.append(float(m["loss"]))
    c3.checkpointer.save(2 * args.steps, {
        "params": jax.tree.map(np.asarray, params2)})
    c3.checkpointer.wait_async()
    print(f"phase2 ({args.nodes_after} nodes): resumed, loss "
          f"{losses2[0]:.3f} -> {losses2[-1]:.3f}")
    assert losses2[0] < losses[0], "resume lost progress"
    c1.shutdown(); c2.shutdown(); c3.shutdown()


if __name__ == "__main__":
    main()
