"""End-to-end training driver: --arch <id> [--smoke] on the local devices.

Builds the model + sharded train step for the available mesh, wires the
pmem cluster (staged data, async node-local checkpoints, heartbeats), and
runs the loop. With --smoke it trains the reduced config for a few hundred
steps on CPU — the (b)-deliverable end-to-end example.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, ParallelConfig, ShapeConfig, registry
from repro.core.cluster import SimCluster
from repro.data.pipeline import StagedDataset
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import transformer as tfm
from repro.train import loop as train_loop
from repro.train import optimizer as opt
from repro.train import train_step as ts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--delta-ckpt", action="store_true")
    ap.add_argument("--fault-at", type=int, default=None)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--root", default="/tmp/repro_train")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args(argv)

    cfg = registry.get_smoke_config(args.arch) if args.smoke \
        else registry.get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    n_dev = len(jax.devices())
    mesh = make_mesh((1, n_dev), ("data", "model")) if n_dev > 1 \
        else make_mesh((1, 1), ("data", "model"))
    plan = shd.Plan(mesh, cfg, shape, ParallelConfig(attn_impl="blockwise"))
    rt = plan.runtime()

    params, specs = tfm.init_params(jax.random.PRNGKey(0), cfg, rt)
    adamw = opt.AdamWConfig(lr=args.lr, warmup=10)
    opt_state = opt.init_opt_state(params, adamw)
    step_fn = jax.jit(ts.make_train_step(cfg, rt, plan.constrain, adamw,
                                         ce_chunk=128))

    cluster = SimCluster(Path(args.root) / str(int(time.time())),
                         n_nodes=args.nodes, delta=args.delta_ckpt)
    data = StagedDataset(cluster, cfg, shape, n_shards=4,
                         seqs_per_shard=max(args.batch * 2, 16))
    lc = train_loop.LoopConfig(steps=args.steps,
                               ckpt_every=args.ckpt_every,
                               delta_ckpt=args.delta_ckpt)
    t0 = time.time()
    state = train_loop.run(step_fn, params, opt_state,
                           data.batches(args.steps), cluster, lc,
                           fault_at=args.fault_at)
    dt = time.time() - t0
    print(f"arch={cfg.name} steps={state.step} "
          f"loss {state.losses[0]:.3f} -> {state.losses[-1]:.3f} "
          f"({dt:.1f}s, ckpt avg {np.mean(state.ckpt_seconds or [0]):.3f}s, "
          f"recoveries={state.recovered_at})")
    assert state.losses[-1] < state.losses[0], "loss did not decrease"
    cluster.shutdown()
    return state


if __name__ == "__main__":
    main()
