"""Production mesh builders (assignment-mandated shapes).

Functions, not module-level constants, so importing never touches jax
device state.

``jax.sharding.AxisType`` only exists on newer JAX releases (>= 0.5);
on 0.4.x meshes every axis is implicitly "auto", so the kwarg must be
omitted entirely. ``_mesh_axis_kwargs`` centralises that version probe so
every mesh in the repo builds on both API variants.
"""
from __future__ import annotations

import jax


def _mesh_axis_kwargs(n_axes: int, sharding_mod=None) -> dict:
    """kwargs for ``jax.make_mesh`` marking all ``n_axes`` axes as Auto.

    Returns ``{}`` when the installed JAX predates
    ``jax.sharding.AxisType`` (e.g. 0.4.x), where Auto is the implicit
    default. ``sharding_mod`` is injectable for compat tests.
    """
    sharding = sharding_mod if sharding_mod is not None else jax.sharding
    if hasattr(sharding, "AxisType"):
        return {"axis_types": (sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_axis_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary (test-scale) mesh with the same axis conventions."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_mesh_axis_kwargs(len(axes)))
