import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces the compiled artifact's memory analysis (proves
HBM fit), cost analysis (FLOPs / bytes for the roofline), and the collective
schedule (parsed from the optimized HLO) -> one JSON per cell under
artifacts/dryrun/. benchmarks/roofline.py turns these into EXPERIMENTS.md
tables.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, base, registry
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis
from repro.launch import specs as specmod
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.train import optimizer as opt
from repro.train import train_step as ts

# --- hardware constants (TPU v5e-class target; see EXPERIMENTS.md) ---
HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link
    "hbm_bytes": 16e9,           # per chip
}

def _sharded_bytes(shapes_tree, shardings_tree) -> int:
    """Exact per-device bytes of a sharded pytree (via shard_shape)."""
    total = 0
    for sds, sh in zip(jax.tree.leaves(shapes_tree),
                       jax.tree.leaves(
                           shardings_tree,
                           is_leaf=lambda x: isinstance(x, NamedSharding))):
        shard = sh.shard_shape(sds.shape)
        n = 1
        for d in shard:
            n *= d
        total += n * sds.dtype.itemsize
    return total


def analytic_memory(cfg: base.ModelConfig, shape: base.ShapeConfig,
                    mesh, mb: int, arg_bytes: int) -> dict:
    """Per-device peak model: exact argument bytes + analytic transients.

    Transients (train): remat stores one residual per layer per microbatch
    + ~6 activation-sized f32 workspaces + one gathered layer's params.
    """
    tp = mesh.shape["model"]
    dp = mesh.size // tp
    s, b = shape.seq_len, shape.global_batch
    d = cfg.d_model
    layers = cfg.n_layers + cfg.n_enc_layers
    if shape.kind == "train":
        b_micro = max(b // dp // mb, 1)
        resid = layers * b_micro * s * d * 2
        work = 8 * b_micro * s * d * 4
        gbytes = 2 if cfg.param_count() > 4e11 else 4
        grads = gbytes * cfg.param_count(tp, padded=True) // tp // dp
        transient = resid + work + grads
    elif shape.kind == "prefill":
        b_loc = max(b // dp, 1)
        transient = 10 * b_loc * s * d * 2
    else:
        transient = int(0.5 * arg_bytes) + 64 * d * 4  # cache double-buffer
    peak = arg_bytes + transient
    return {"arg_bytes_exact": arg_bytes, "transient_model": transient,
            "peak_model": peak, "fits_16GB_model": bool(peak <= 16e9)}


def pick_microbatches(cfg: base.ModelConfig, shape: base.ShapeConfig,
                      dp: int) -> int:
    """Heuristic: keep per-microbatch stored activations under ~3 GB/device
    (scan-remat stores one residual per layer)."""
    if shape.kind != "train":
        return 1
    b_loc = max(shape.global_batch // dp, 1)
    layers = cfg.n_layers + (cfg.n_enc_layers or 0)
    act = layers * b_loc * shape.seq_len * cfg.d_model * 2
    mb = 1
    while act / mb > 3e9 and mb < b_loc:
        mb *= 2
    return mb


def build_and_compile(arch: str, shape_name: str, multi_pod: bool,
                      parallel: base.ParallelConfig, mb_override=None):
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = shd.Plan(mesh, cfg, shape, parallel)
    rt = plan.runtime()
    n_dev = mesh.size
    dp = n_dev // mesh.shape["model"]
    batch_axes = plan.batch

    params_shapes, pspecs = tfm.abstract_params(cfg, rt)
    # FSDP: shard params over the data axes too when a TP-only shard would
    # not leave room for activations (>4 GB/device of params). For decode,
    # FSDP means an all-gather of the full model EVERY TOKEN — only do it
    # when TP-sharded params + cache genuinely can't fit (perf iteration 2,
    # EXPERIMENTS.md §Perf: arctic decode was collective-bound purely on
    # these gathers).
    param_bytes_tp = 2 * cfg.param_count(mesh.shape["model"], padded=True) \
        / mesh.shape["model"]
    overrides = None
    if cfg.moe is not None and shape.kind == "decode":
        # decode MoE: experts 2D-sharded (model x data on FFN hidden) ->
        # fully resident, zero per-token weight gathers; dense part is small
        fsdp = False
        overrides = {"expert_f": "__batch__"}
    else:
        # >4 GB/device of TP-sharded params leaves no room for activations
        # (train) or KV caches (prefill/decode) on a 16 GB chip.
        fsdp = param_bytes_tp > 4e9
    param_sh = shd.tree_shardings(params_shapes, pspecs, mesh, zero1=fsdp,
                                  overrides=overrides)

    t0 = time.time()
    if shape.kind == "train":
        mb = mb_override or pick_microbatches(cfg, shape, dp)
        adamw = opt.AdamWConfig(
            moments_dtype="int8" if cfg.param_count() > 1.2e11 else "float32")
        opt_shapes = jax.eval_shape(
            lambda p: opt.init_opt_state(p, adamw), params_shapes)
        opt_specs = opt.opt_state_specs(pspecs, adamw)
        opt_sh = shd.tree_shardings(opt_shapes, opt_specs, mesh, zero1=True)
        batch, _ = specmod.input_specs(cfg, shape, rt)
        batch_sh = {
            k: NamedSharding(mesh, shd._fit_pspec(
                P(batch_axes, *([None] * (v.ndim - 1))), v.shape, mesh))
            for k, v in batch.items()}
        # ZeRO-2: keep the f32 grad accumulator data-sharded
        grad_sh = shd.tree_shardings(params_shapes, pspecs, mesh, zero1=True)
        accum = jnp.bfloat16 if cfg.param_count() > 4e11 else jnp.float32
        step = ts.make_train_step(cfg, rt, plan.constrain, adamw,
                                  microbatches=mb,
                                  ce_chunk=parallel.ce_chunk,
                                  grad_shardings=grad_sh,
                                  accum_dtype=accum)
        jitted = jax.jit(step, in_shardings=(param_sh, opt_sh, batch_sh),
                         out_shardings=(param_sh, opt_sh, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_shapes, opt_shapes, batch)
        arg_bytes = _sharded_bytes((params_shapes, opt_shapes, batch),
                                   (param_sh, opt_sh, batch_sh))
        extra = {"microbatches": mb, "moments": adamw.moments_dtype,
                 "fsdp": fsdp}
    elif shape.kind == "prefill":
        batch, _ = specmod.input_specs(cfg, shape, rt)
        batch_sh = {
            k: NamedSharding(mesh, shd._fit_pspec(
                P(batch_axes, *([None] * (v.ndim - 1))), v.shape, mesh))
            for k, v in batch.items()}

        def prefill_step(params, b):
            return tfm.prefill(params, cfg, rt, b["tokens"],
                               prefix_embeds=b.get("prefix_embeds"),
                               enc_frames=b.get("enc_frames"))

        cache_shapes = jax.eval_shape(prefill_step, params_shapes, batch)[1]
        # logical specs for produced caches match init_cache's
        _, cache_specs = specmod.abstract_cache(
            cfg, rt, shape.global_batch,
            shape.seq_len if cfg.enc_dec else 0)
        cache_sh = shd.tree_shardings(cache_shapes, cache_specs, mesh)
        logits_sh = NamedSharding(mesh, shd._fit_pspec(
            P(batch_axes, "model"),
            (shape.global_batch, cfg.padded_vocab), mesh))
        jitted = jax.jit(prefill_step, in_shardings=(param_sh, batch_sh),
                         out_shardings=(logits_sh, cache_sh))
        lowered = jitted.lower(params_shapes, batch)
        arg_bytes = _sharded_bytes((params_shapes, batch, cache_shapes),
                                   (param_sh, batch_sh, cache_sh))
        extra = {"fsdp": fsdp}
    else:  # decode
        inputs, cache_specs = specmod.input_specs(cfg, shape, rt)
        cache_sh = shd.tree_shardings(inputs["cache"], cache_specs, mesh)
        tok_sh = NamedSharding(mesh, shd._fit_pspec(
            P(batch_axes), (shape.global_batch,), mesh))
        logits_sh = NamedSharding(mesh, shd._fit_pspec(
            P(batch_axes, "model"),
            (shape.global_batch, cfg.padded_vocab), mesh))

        def serve_step(params, cache, tokens, pos):
            return tfm.decode_step(params, cfg, rt, cache, tokens, pos)

        jitted = jax.jit(
            serve_step,
            in_shardings=(param_sh, cache_sh, tok_sh,
                          NamedSharding(mesh, P())),
            out_shardings=(logits_sh, cache_sh), donate_argnums=(1,))
        lowered = jitted.lower(params_shapes, inputs["cache"],
                               inputs["tokens"], inputs["pos"])
        arg_bytes = _sharded_bytes((params_shapes, inputs["cache"]),
                                   (param_sh, cache_sh))
        extra = {"fsdp": fsdp}
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # JAX 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    if os.environ.get("DRYRUN_SAVE_HLO"):
        Path(os.environ["DRYRUN_SAVE_HLO"]).write_text(hlo)
    # trip-count-aware analysis (XLA's cost_analysis counts while bodies
    # once -> ~layers x microbatches undercount; see hlo_analysis.py)
    ana = hlo_analysis.analyze(hlo, n_dev)
    coll = ana["collectives"]

    flops_dev = float(ana["flops"])
    bytes_dev = float(ana["bytes"])
    wire = float(ana["wire_bytes"])
    # roofline terms (seconds)
    t_comp = flops_dev / HW["peak_flops_bf16"]
    t_mem = bytes_dev / HW["hbm_bw"]
    t_coll = wire / HW["ici_bw"]
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]

    n_par = cfg.param_count()
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_act * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_act * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2.0 * n_act * tokens
    model_flops_dev = model_flops / n_dev

    dev_bytes = mem.argument_size_in_bytes + mem.temp_size_in_bytes \
        + mem.output_size_in_bytes - mem.alias_size_in_bytes
    amem = analytic_memory(cfg, shape, mesh, extra.get("microbatches", 1),
                           arg_bytes)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": shape.kind,
        "params": n_par, "active_params": n_act,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "xla_raw": {"flops": float(cost.get("flops", 0.0)),
                    "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        "collectives": coll,
        "collective_wire_bytes": wire,
        "bytes_by_tag": ana.get("bytes_by_tag", {}),
        "flops_by_tag": ana.get("flops_by_tag", {}),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "xla_cpu_peak": dev_bytes,
            **amem,
            "fits_16GB": amem["fits_16GB_model"],
        },
        "roofline": {
            "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
            "dominant": dominant,
            "model_flops_per_device": model_flops_dev,
            "useful_compute_ratio": model_flops_dev / max(flops_dev, 1.0),
            "roofline_fraction": model_flops_dev / HW["peak_flops_bf16"] /
            max(t_comp, t_mem, t_coll, 1e-12),
        },
        "timing": {"lower_s": t_lower, "compile_s": t_compile},
        "seq_parallel": parallel.seq_parallel,
        **extra,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--mb", type=int, default=None)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--attn-impl", default="blockwise")
    ap.add_argument("--remat", default="block", choices=["block", "dots",
                                                         "none"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = list(registry.cells(args.arch, args.shape))
    parallel = base.ParallelConfig(seq_parallel=args.seq_parallel,
                                   attn_impl=args.attn_impl,
                                   remat=args.remat)
    failures = 0
    for cell in cells:
        for multi in meshes:
            mesh_name = "2x16x16" if multi else "16x16"
            tag = f"_{args.tag}" if args.tag else ""
            fname = outdir / (f"{cell.arch}_{cell.shape.name}_"
                              f"{mesh_name}{tag}.json")
            if cell.skip:
                json.dump({"arch": cell.arch, "shape": cell.shape.name,
                           "mesh": mesh_name, "skipped": cell.skip},
                          open(fname, "w"), indent=1)
                print(f"[skip] {cell.name} ({mesh_name}): {cell.skip}")
                continue
            print(f"[cell] {cell.name} ({mesh_name}) ...", flush=True)
            try:
                res = build_and_compile(cell.arch, cell.shape.name, multi,
                                        parallel, args.mb)
                json.dump(res, open(fname, "w"), indent=1)
                r = res["roofline"]
                print(f"  ok: flops/dev={res['flops_per_device']:.3e} "
                      f"dom={r['dominant']} "
                      f"roofline={r['roofline_fraction']:.3f} "
                      f"fits={res['memory']['fits_16GB']} "
                      f"compile={res['timing']['compile_s']:.1f}s",
                      flush=True)
            except Exception as e:
                failures += 1
                print(f"  FAIL: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
