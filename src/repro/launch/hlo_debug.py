"""Top HBM/FLOP contributors of a saved HLO dump (perf-iteration tool).

Usage: PYTHONPATH=src python -m repro.launch.hlo_debug /tmp/cell.hlo
"""
from __future__ import annotations

import re
import sys
from collections import Counter

from repro.launch.hlo_analysis import (_TRIP_RE, _shape_bytes, _trip_count,
                                       parse_hlo)


def breakdown(path: str, top: int = 18):
    text = open(path).read()
    comps = parse_hlo(text)
    entry = [n for n in comps if "main" in n][0]
    by_instr = Counter()

    def visit(name, mult):
        comp = comps.get(name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.op == "while":
                body = re.search(r"body=%?([\w.\-]+)", ins.tail or "")
                cond = re.search(r"condition=%?([\w.\-]+)", ins.tail or "")
                tm = _TRIP_RE.search(ins.raw)
                trips = int(tm.group(1)) if tm else (
                    _trip_count(comps[cond.group(1)])
                    if cond and cond.group(1) in comps else 1)
                if body:
                    visit(body.group(1), mult * trips)
                continue
            if ins.op in ("reshape", "bitcast", "tuple", "get-tuple-element",
                          "constant", "conditional", "call", "parameter"):
                continue
            out_b = _shape_bytes(ins.shape)
            if ins.op in ("dynamic-slice", "gather"):
                total = 2 * out_b
            elif ins.op == "dynamic-update-slice":
                upd = _shape_bytes(comp.shapes.get(ins.operands[1], "")) \
                    if len(ins.operands) > 1 else 0
                total = 2 * upd
            else:
                opb = sum(_shape_bytes(comp.shapes.get(o, ""))
                          for o in ins.operands)
                total = opb + out_b
            meta = re.search(r'op_name="([^"]{0,90})', ins.raw)
            key = (f"{ins.op} {ins.shape[:36]} x{mult:.0f} :: "
                   f"{meta.group(1)[-60:] if meta else ''}")
            by_instr[key] += total * mult

    visit(entry, 1.0)
    print(f"total bytes (unfused model): {sum(by_instr.values()):.3e}")
    for k, b in by_instr.most_common(top):
        print(f"{b:.3e}  {k}")


if __name__ == "__main__":
    breakdown(sys.argv[1], int(sys.argv[2]) if len(sys.argv) > 2 else 18)
