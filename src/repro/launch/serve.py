"""Serving driver: --arch <id> --smoke — batched prefill+decode with pmem
KV spill/resume demo (deliverable (b), serving flavor)."""
from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import registry
from repro.core.cluster import SimCluster
from repro.models import transformer as tfm
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--root", default=None)
    args = ap.parse_args(argv)

    cfg = registry.get_smoke_config(args.arch)
    max_seq = args.prompt_len + args.gen + 8
    rt = tfm.ModelRuntime(tp=1, attn_impl="naive", max_seq=max_seq,
                          remat=False)
    params, _ = tfm.init_params(jax.random.PRNGKey(0), cfg, rt)
    root = Path(args.root or tempfile.mkdtemp())
    cluster = SimCluster(root, n_nodes=1)
    eng = ServeEngine(cfg, rt, params, store=cluster.stores["node0"],
                      tiered=cluster.tiered)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    kw = {}
    if cfg.enc_dec:
        kw["enc_frames"] = rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32)
    t0 = time.time()
    first = eng.prefill(prompts, **kw)
    t_prefill = time.time() - t0
    t0 = time.time()
    out = eng.decode(first, args.gen)
    t_decode = time.time() - t0
    # demonstrate pmem persistence of serving state: spill through the
    # TieredIO write-back cache, warm it back via prefetch, resume.
    eng.spill("session0")
    eng.prefetch_sessions(["session0"]).result()
    eng.resume("session0")
    more = eng.decode(out[:, -1], 4)
    print(f"arch={cfg.name} batch={args.batch} prefill={t_prefill:.2f}s "
          f"decode={args.gen}tok/{t_decode:.2f}s "
          f"({args.batch * args.gen / max(t_decode, 1e-9):.1f} tok/s) "
          f"spill/resume ok, +4 more tokens: {more[:, 1:].shape}")
    cluster.shutdown()


if __name__ == "__main__":
    main()
