"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

Mirrors the shannon/kernels dry-run pattern: weak-type-correct, shardable,
zero allocation. Modality frontends are stubs per the assignment —
``enc_frames`` / ``prefix_embeds`` are precomputed embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DECODE, ModelConfig, ShapeConfig
from repro.models import transformer as tfm

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig
                      ) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    text = s - cfg.prefix_len
    batch = {
        "tokens": SDS((b, text), jnp.int32),
        "labels": SDS((b, s), jnp.int32),
        "loss_mask": SDS((b, s), jnp.float32),
    }
    if cfg.enc_dec:
        batch["enc_frames"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.prefix_len:
        batch["prefix_embeds"] = SDS((b, cfg.prefix_len, cfg.d_model),
                                     jnp.bfloat16)
    return batch


def abstract_cache(cfg: ModelConfig, rt: tfm.ModelRuntime, batch: int,
                   enc_len: int = 0):
    """(ShapeDtypeStruct cache tree, logical-axes specs) without allocation."""
    holder = {}

    def go():
        c, s = tfm.init_cache(cfg, rt, batch, enc_len)
        holder["specs"] = s
        return c

    shapes = jax.eval_shape(go)
    return shapes, holder["specs"]


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                       rt: tfm.ModelRuntime):
    b = shape.global_batch
    enc_len = shape.seq_len if cfg.enc_dec else 0
    cache, cache_specs = abstract_cache(cfg, rt, b, enc_len)
    return {
        "tokens": SDS((b,), jnp.int32),
        "pos": SDS((), jnp.int32),
        "cache": cache,
    }, cache_specs


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                rt: tfm.ModelRuntime) -> Tuple[Dict[str, Any], Any]:
    """Returns (specs dict, cache logical specs or None)."""
    if shape.kind == DECODE:
        return decode_input_specs(cfg, shape, rt)
    return train_batch_specs(cfg, shape), None
