"""Simulated multi-node cluster wiring (one directory per node's B-APM).

Binds together pools, object stores, the data scheduler, the external
store, checkpointing and resilience — the "systemware" stack of paper
Fig. 7 — for tests, examples, and benchmarks. On real hardware the same
objects are constructed per-host with the local pmem mount.
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, Optional

from repro.core.checkpoint import DistributedCheckpointer
from repro.core.data_scheduler import DataScheduler, ExternalStore
from repro.core.dataset_exchange import DatasetCatalog
from repro.core.object_store import DistributedStore, PMemObjectStore
from repro.core.pmem import PMemPool
from repro.core.resilience import FailureRecovery, Heartbeat
from repro.core.tiered_io import TieredIO
from repro.core.tiering import DLMCache
from repro.core.workflow import WorkflowScheduler
from repro.obs.plane import TelemetryPlane


class SimCluster:
    def __init__(self, root: Path, n_nodes: int = 4,
                 pmem_capacity: int = 1 << 32,
                 external_bandwidth: Optional[float] = None,
                 buddy: bool = True, delta: bool = False,
                 dlm_capacity: int = 1 << 28, slots: int = 2,
                 wire_codec=None, telemetry: bool = True):
        self.root = Path(root)
        self.node_ids = [f"node{i}" for i in range(n_nodes)]
        self.pools: Dict[str, PMemPool] = {
            nid: PMemPool(self.root / "pmem", nid,
                          capacity_bytes=pmem_capacity)
            for nid in self.node_ids}
        # telemetry plane: one metrics registry + one crash-persistent
        # flight-recorder ring per node pool. telemetry=False keeps the
        # registry (cheap DRAM counters) but records no pmem events —
        # the baseline leg of the overhead bench.
        self.obs = TelemetryPlane(self.pools, enabled=telemetry)
        self.stores: Dict[str, PMemObjectStore] = {
            nid: PMemObjectStore(pool) for nid, pool in self.pools.items()}
        self.external = ExternalStore(self.root / "external",
                                      bandwidth_bytes_s=external_bandwidth)
        self.scheduler = DataScheduler(self.stores, self.external,
                                       obs=self.obs)
        self.view = DistributedStore(self.stores)
        self.checkpointer = DistributedCheckpointer(
            self.stores, self.scheduler, self.external, buddy=buddy,
            delta=delta, slots=slots, obs=self.obs)
        self.heartbeat = Heartbeat(self.stores)
        # the unified async I/O engine (checkpoint + KV tiering + staging)
        self.dlm = DLMCache(self.stores[self.node_ids[0]],
                            capacity_bytes=dlm_capacity, obs=self.obs)
        # ``wire_codec=True`` (or a spec dict) turns on the delta-int8
        # wire codec for every replicate/drain/repair transfer
        self.tiered = TieredIO(self.checkpointer, self.scheduler, self.dlm,
                               wire_codec=wire_codec, obs=self.obs)
        self.recovery = FailureRecovery(self.checkpointer, self.heartbeat,
                                        tiered=self.tiered)
        # the persistent dataset exchange: catalog replication rides the
        # TieredIO exchange channel, leased datasets pin the DLM cache
        self.catalog = DatasetCatalog(self.stores)
        self.tiered.attach_catalog(self.catalog)
        self.workflows = WorkflowScheduler(self.stores, self.scheduler,
                                           self.external,
                                           tiered=self.tiered,
                                           catalog=self.catalog,
                                           obs=self.obs)
        # multi-tenant serve tier: sessions as leased catalog datasets
        # (import here: serve/ sits above core/ in the layer order)
        from repro.serve.sessions import SessionManager
        self.sessions = SessionManager(self.tiered, self.catalog,
                                       obs=self.obs)

    def start_repair_daemon(self, **kw):
        """Start the continuous background repair daemon (owned by the
        FailureRecovery monitor): node deaths detected via heartbeats
        trigger incremental, rate-limited repair sweeps — including
        drain-tier rehydration — WITHOUT waiting for a recovery point.
        ``kill_node`` is the matching fault-injection hook: the daemon
        notices the unreachable pool on its next poll. Returns the
        daemon (``wait_for``/``covers``/``report`` are its ledger)."""
        return self.recovery.start_daemon(**kw)

    def stop_repair_daemon(self) -> None:
        self.recovery.stop_daemon()

    def kill_node(self, nid: str) -> None:
        """Simulate a node failure: its pmem becomes unreachable."""
        import shutil
        import time
        pool = self.pools[nid]
        pool.fail()  # in-flight async writers now fail fast
        # an async writer may still be mid-create; retry until clean.
        # Raw directory removal IS the fault being injected — the one
        # sanctioned bypass of the PMemRegion discipline.
        for _ in range(50):
            shutil.rmtree(pool.root, ignore_errors=True)  # pmemlint: disable=raw-pool-path
            if not pool.root.exists():
                break
            time.sleep(0.02)
        # monitor sees it dead because heartbeats stop / are gone

    def repair(self, lost_nodes, **kw) -> dict:
        """Restore the replication factor after ``kill_node``: quiesce
        in-flight I/O (a replicate that died with the node must not be
        mistaken for pending work), then re-replicate every acked
        object the loss reduced to a single copy (TieredIO.repair).
        FailureRecovery and WorkflowScheduler.resume run this
        automatically; this is the standalone entry point for tests,
        benchmarks and operator tooling."""
        self.tiered.quiesce()
        return self.tiered.repair(lost_nodes, **kw)

    def shutdown(self) -> None:
        self.recovery.stop_daemon()
        self.tiered.shutdown()
        self.scheduler.shutdown()
        # clean shutdown: drop a metrics snapshot on every live pool.
        # After a crash this never runs — the flight-recorder rings are
        # then the diagnosis (python -m repro.obs.report).
        self.obs.persist_snapshot()
