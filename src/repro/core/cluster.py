"""Simulated multi-node cluster wiring (one directory per node's B-APM).

Binds together pools, object stores, the data scheduler, the external
store, checkpointing and resilience — the "systemware" stack of paper
Fig. 7 — for tests, examples, and benchmarks. On real hardware the same
objects are constructed per-host with the local pmem mount.
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, Optional

from repro.core.checkpoint import DistributedCheckpointer
from repro.core.data_scheduler import DataScheduler, ExternalStore
from repro.core.object_store import DistributedStore, PMemObjectStore
from repro.core.pmem import PMemPool
from repro.core.resilience import FailureRecovery, Heartbeat
from repro.core.workflow import WorkflowScheduler


class SimCluster:
    def __init__(self, root: Path, n_nodes: int = 4,
                 pmem_capacity: int = 1 << 32,
                 external_bandwidth: Optional[float] = None,
                 buddy: bool = True, delta: bool = False):
        self.root = Path(root)
        self.node_ids = [f"node{i}" for i in range(n_nodes)]
        self.pools: Dict[str, PMemPool] = {
            nid: PMemPool(self.root / "pmem", nid,
                          capacity_bytes=pmem_capacity)
            for nid in self.node_ids}
        self.stores: Dict[str, PMemObjectStore] = {
            nid: PMemObjectStore(pool) for nid, pool in self.pools.items()}
        self.external = ExternalStore(self.root / "external",
                                      bandwidth_bytes_s=external_bandwidth)
        self.scheduler = DataScheduler(self.stores, self.external)
        self.view = DistributedStore(self.stores)
        self.checkpointer = DistributedCheckpointer(
            self.stores, self.scheduler, self.external, buddy=buddy,
            delta=delta)
        self.heartbeat = Heartbeat(self.stores)
        self.recovery = FailureRecovery(self.checkpointer, self.heartbeat)
        self.workflows = WorkflowScheduler(self.stores, self.scheduler,
                                           self.external)

    def kill_node(self, nid: str) -> None:
        """Simulate a node failure: its pmem becomes unreachable."""
        import shutil
        shutil.rmtree(self.pools[nid].root)
        # monitor sees it dead because heartbeats stop / are gone

    def shutdown(self) -> None:
        self.scheduler.shutdown()
