"""Failure detection, straggler mitigation, recovery orchestration.

Heartbeats are small records in each node's pmem pool (surviving the
node's own crash for post-mortem, and readable by the monitor over the
fabric — the paper's remote B-APM access). Stragglers are detected from
per-step duration statistics; mitigation = stage-in work-stealing (the
data scheduler already steals from the deepest queue) plus a rebalance
hook the training loop can use.

``FailureRecovery`` glues it together: dead node -> restore from buddy
replicas -> elastic restart on the survivors (checkpoint.restore handles
re-sharding).
"""
from __future__ import annotations

import statistics
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.checkpoint import DistributedCheckpointer
from repro.core.object_store import PMemObjectStore


class Heartbeat:
    def __init__(self, stores: Dict[str, PMemObjectStore]):
        self.stores = stores

    def beat(self, nid: str, step: int) -> None:
        try:
            self.stores[nid].pool.put_json(
                "hb/heartbeat.json", {"ts": time.time(), "step": step})
        except IOError:
            pass  # unreachable pmem == the node is dead; it stops beating

    def read(self, nid: str) -> Optional[dict]:
        try:
            return self.stores[nid].pool.get_json("hb/heartbeat.json")
        except (FileNotFoundError, IOError):
            return None

    def dead_nodes(self, timeout_s: float, now: Optional[float] = None
                   ) -> List[str]:
        now = now or time.time()
        dead = []
        for nid in self.stores:
            hb = self.read(nid)
            if hb is None or now - hb["ts"] > timeout_s:
                dead.append(nid)
        return dead


class StragglerDetector:
    """Flags nodes whose step times exceed k x median of the fleet."""

    def __init__(self, threshold: float = 1.5, window: int = 16):
        self.threshold = threshold
        self.window = window
        self._times: Dict[str, List[float]] = {}

    def record(self, nid: str, step_seconds: float) -> None:
        hist = self._times.setdefault(nid, [])
        hist.append(step_seconds)
        del hist[:-self.window]

    def stragglers(self) -> List[str]:
        if len(self._times) < 2:
            return []
        medians = {n: statistics.median(v) for n, v in self._times.items()
                   if v}
        fleet = statistics.median(medians.values())
        return [n for n, m in medians.items()
                if m > self.threshold * fleet]


class FailureRecovery:
    def __init__(self, ckpt: DistributedCheckpointer, hb: Heartbeat,
                 timeout_s: float = 10.0, tiered=None):
        self.ckpt = ckpt
        self.hb = hb
        self.timeout_s = timeout_s
        self.tiered = tiered          # Optional[TieredIO]
        self.inflight_errors: List[Exception] = []
        # how the last recovery picked its step: {"skipped_by_ack": n,
        # "probed": m} — steps ruled out on the manifest ack map alone
        # vs. steps that needed an actual restore attempt
        self.last_restore_stats: dict = {}
        # the last recovery's RepairChannel report: every acked object
        # the loss reduced to a single copy, re-replicated + re-acked
        self.last_repair_report: dict = {}

    def quiesce_inflight(self) -> List[Exception]:
        """Consume every in-flight TieredIO future before reading the
        checkpoint index: a save that committed must become visible, and
        a drain/replicate that died with the node must be swallowed (its
        error is kept for diagnostics, never raised)."""
        if self.tiered is None:
            return []
        errors = self.tiered.quiesce()
        self.inflight_errors.extend(errors)
        return errors

    def check_and_recover(self, now: Optional[float] = None,
                          repair: bool = True):
        """Returns None if healthy, else (restored_tree, manifest,
        dead_nodes) — restored from the newest checkpoint whose ack map
        marks it recoverable for the dead set (steps that died between
        commit and replica ack are skipped on metadata alone), with dead
        nodes' shards served by their buddies.

        With ``repair`` (default) the recovery then restores the
        replication factor: every acked checkpoint shard / dataset / DLM
        object the loss reduced to a single surviving copy is
        re-replicated to a fresh live buddy and re-acked
        (``TieredIO.repair``; report in ``last_repair_report``) — so the
        resumed run tolerates the NEXT node loss too, instead of running
        on silently-single copies."""
        dead = self.hb.dead_nodes(self.timeout_s, now)
        if not dead:
            return None
        self.quiesce_inflight()
        if self.ckpt.latest_step() is None:
            raise RuntimeError(f"nodes {dead} dead and no checkpoint exists")
        tree, manifest = self.ckpt.restore_latest_recoverable(
            lost_nodes=dead)
        self.last_restore_stats = dict(self.ckpt.last_restore_stats)
        self.last_repair_report = {}
        if repair and self.tiered is not None:
            self.last_repair_report = self.tiered.repair(dead)
        return tree, manifest, dead
