"""Failure detection, straggler mitigation, recovery orchestration.

Heartbeats are small records in each node's pmem pool (surviving the
node's own crash for post-mortem, and readable by the monitor over the
fabric — the paper's remote B-APM access). Stragglers are detected from
per-step duration statistics; mitigation = stage-in work-stealing (the
data scheduler already steals from the deepest queue) plus a rebalance
hook the training loop can use.

``FailureRecovery`` glues it together: dead node -> restore from buddy
replicas -> elastic restart on the survivors (checkpoint.restore handles
re-sharding). The monitor loop also OWNS the continuous ``RepairDaemon``
(``start_daemon``/``stop_daemon``): the daemon shrinks the single-copy
window by repairing in the background between recovery points, and
``check_and_recover`` consults its already-repaired ledger instead of
re-scanning from scratch.
"""
from __future__ import annotations

import statistics
import time
from typing import Dict, List, Optional, Set

from repro.core.checkpoint import DistributedCheckpointer
from repro.core.object_store import PMemObjectStore


class Heartbeat:
    def __init__(self, stores: Dict[str, PMemObjectStore]):
        self.stores = stores
        # monitor-side first-seen clock per node that has NOT yet written
        # a heartbeat: a just-joined / just-restarted node must get a
        # grace window before the monitor declares it dead and repairs
        # around it. State lives in the monitor (this object), never in
        # the observed node's pmem.
        self._first_seen: Dict[str, float] = {}

    def beat(self, nid: str, step: int) -> None:
        try:
            self.stores[nid].pool.put_json(
                "hb/heartbeat.json", {"ts": time.time(), "step": step})
        except IOError:
            # Not a swallowed durability failure: an unreachable pmem
            # means the node is dead, and a dead node STOPPING its
            # heartbeat is exactly the signal the monitor consumes.
            pass  # pmemlint: disable=silent-swallow

    def read(self, nid: str) -> Optional[dict]:
        try:
            return self.stores[nid].pool.get_json("hb/heartbeat.json")
        except (FileNotFoundError, IOError):
            return None

    def dead_nodes(self, timeout_s: float, now: Optional[float] = None,
                   grace_s: Optional[float] = None) -> List[str]:
        """Nodes the monitor considers dead: pool unreachable, heartbeat
        older than ``timeout_s``, or — for a node that has never beaten —
        first seen by THIS monitor more than ``grace_s`` (default
        ``timeout_s``) ago. The grace window exists because a freshly
        joined or restarted node has a reachable pool but no heartbeat
        record yet; declaring it dead on sight would trigger a spurious
        repair sweep around a healthy node."""
        now = now or time.time()
        grace = timeout_s if grace_s is None else grace_s
        dead = []
        for nid in self.stores:
            pool = self.stores[nid].pool
            if not getattr(pool, "alive", True):
                dead.append(nid)  # pmem unreachable: unambiguously dead
                continue
            try:
                hb = pool.get_json("hb/heartbeat.json")
            except FileNotFoundError:
                hb = None  # pool reachable, node just never beat (yet)
            except IOError:
                dead.append(nid)
                continue
            if hb is not None:
                self._first_seen.pop(nid, None)
                if now - hb["ts"] > timeout_s:
                    dead.append(nid)
                continue
            first = self._first_seen.setdefault(nid, now)
            if now - first > grace:
                dead.append(nid)
        return dead


class StragglerDetector:
    """Flags nodes whose step times exceed k x median of the fleet."""

    def __init__(self, threshold: float = 1.5, window: int = 16):
        self.threshold = threshold
        self.window = window
        self._times: Dict[str, List[float]] = {}

    def record(self, nid: str, step_seconds: float) -> None:
        hist = self._times.setdefault(nid, [])
        hist.append(step_seconds)
        del hist[:-self.window]

    def forget(self, nid: str) -> None:
        """Drop a removed node's history. A dead node's stale step times
        would otherwise keep skewing the fleet median forever — slow
        final steps from the victim can flag healthy survivors, and a
        fast victim deflates the median the survivors are judged by."""
        self._times.pop(nid, None)

    def stragglers(self) -> List[str]:
        if len(self._times) < 2:
            return []
        medians = {n: statistics.median(v) for n, v in self._times.items()
                   if v}
        fleet = statistics.median(medians.values())
        return [n for n, m in medians.items()
                if m > self.threshold * fleet]


class FailureRecovery:
    def __init__(self, ckpt: DistributedCheckpointer, hb: Heartbeat,
                 timeout_s: float = 10.0, tiered=None,
                 straggler: Optional[StragglerDetector] = None):
        self.ckpt = ckpt
        self.hb = hb
        self.timeout_s = timeout_s
        self.tiered = tiered          # Optional[TieredIO]
        self.straggler = straggler    # forgotten on node loss, if given
        self.inflight_errors: List[Exception] = []
        # how the last recovery picked its step: {"skipped_by_ack": n,
        # "probed": m} — steps ruled out on the manifest ack map alone
        # vs. steps that needed an actual restore attempt
        self.last_restore_stats: dict = {}
        # the last recovery's RepairChannel report: every acked object
        # the loss reduced to a single copy, re-replicated + re-acked
        self.last_repair_report: dict = {}
        # dead nodes already restored+repaired: check_and_recover in a
        # polling loop must act on NEW deaths only, not re-restore the
        # same dead set forever (the daemon runs it in exactly that loop)
        self._handled_dead: Set[str] = set()
        # the continuous repair daemon, owned by this monitor
        self.daemon = None
        self.daemon_wait_s = 60.0

    # ---- continuous repair daemon (owned by the monitor loop) --------
    def start_daemon(self, *, poll_s: float = 0.05, max_inflight: int = 2,
                     priority: int = 4, **kw):
        """Start the background ``RepairDaemon`` on this monitor's
        heartbeat + TieredIO engine. Recovery points then consult the
        daemon's already-repaired ledger instead of re-scanning."""
        assert self.tiered is not None, "daemon needs a TieredIO engine"
        if self.daemon is None:
            from repro.core.tiered_io import RepairDaemon
            self.daemon = RepairDaemon(
                self.tiered, self.hb, timeout_s=self.timeout_s,
                poll_s=poll_s, max_inflight=max_inflight,
                priority=priority, **kw)
            self.tiered.repair_daemon = self.daemon
        self.daemon.start()
        return self.daemon

    def stop_daemon(self) -> None:
        if self.daemon is not None:
            self.daemon.stop()

    def quiesce_inflight(self) -> List[Exception]:
        """Consume every in-flight TieredIO future before reading the
        checkpoint index: a save that committed must become visible, and
        a drain/replicate that died with the node must be swallowed (its
        error is kept for diagnostics, never raised)."""
        if self.tiered is None:
            return []
        errors = self.tiered.quiesce()
        self.inflight_errors.extend(errors)
        return errors

    def check_and_recover(self, now: Optional[float] = None,
                          repair: bool = True):
        """Returns None if healthy OR if every currently-dead node was
        already handled by a previous call (this method runs in a loop
        under the monitor/daemon — the same loss must trigger exactly
        one restore/repair, not one per poll), else (restored_tree,
        manifest, dead_nodes) — restored from the newest checkpoint
        whose ack map marks it recoverable for the dead set (steps that
        died between commit and replica ack are skipped on metadata
        alone), with dead nodes' shards served by their buddies.

        With ``repair`` (default) the recovery then restores the
        replication factor: every acked checkpoint shard / dataset / DLM
        object the loss reduced to a single surviving copy is
        re-replicated to a fresh live buddy and re-acked
        (``TieredIO.repair``; report in ``last_repair_report``) — so the
        resumed run tolerates the NEXT node loss too, instead of running
        on silently-single copies. When the continuous RepairDaemon is
        running and its ledger already covers the dead set, its merged
        report is used instead of a redundant re-scan."""
        dead = self.hb.dead_nodes(self.timeout_s, now)
        # a node that rejoined (no longer dead) may die again later and
        # must then be handled afresh
        self._handled_dead &= set(dead)
        new = [n for n in dead if n not in self._handled_dead]
        if not new:
            return None
        if self.straggler is not None:
            for nid in new:
                self.straggler.forget(nid)
        self.quiesce_inflight()
        if self.ckpt.latest_step() is None:
            raise RuntimeError(f"nodes {dead} dead and no checkpoint exists")
        tree, manifest = self.ckpt.restore_latest_recoverable(
            lost_nodes=dead)
        self.last_restore_stats = dict(self.ckpt.last_restore_stats)
        self.last_repair_report = {}
        if repair and self.tiered is not None:
            daemon = self.daemon or getattr(self.tiered, "repair_daemon",
                                            None)
            report = None
            if daemon is not None:
                if daemon.running:
                    daemon.wait_for(dead, timeout=self.daemon_wait_s)
                if daemon.covers(dead):
                    report = daemon.report()  # ledger: already repaired
            if report is None:
                report = self.tiered.repair(dead)
                # transient copy failures (e.g. a transfer racing the
                # loss) are collected, not raised; since this dead set
                # is about to be marked handled — no later poll will
                # retry it — re-run the sweep now (re-planning from the
                # current acks) before accepting residual errors
                for _ in range(2):
                    if not report.get("errors"):
                        break
                    report = self.tiered.repair(dead)
            self.last_repair_report = report
        self._handled_dead |= set(dead)
        return tree, manifest, dead
