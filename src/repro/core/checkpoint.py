"""Distributed node-local checkpointing on B-APM (paper §V item 8 + §III).

Design (DESIGN.md §2, §7):
  * every node writes ONLY its own shards to its OWN pmem pool ->
    checkpoint bandwidth scales linearly with node count (the paper's
    Table I claim; measured in benchmarks/bench_io_scaling.py);
  * two shadow slots + atomic manifest rename -> a crash mid-write always
    leaves the previous checkpoint intact;
  * optional incremental (delta + int8) encoding via the ckpt_codec kernel
    math -> ~4x fewer bytes for slowly-changing state;
  * async drain to the external store and buddy replication via the data
    scheduler -> the training loop never blocks on the slow tier, and any
    single node loss is recoverable;
  * manifests record GLOBAL shapes + per-node row ranges -> restore can
    re-shard onto a DIFFERENT node count / mesh (elastic restart) using
    byte-range reads only.

Shard layout: each leaf is split along dim 0 across nodes when divisible
(row ranges recorded); non-divisible leaves go to node (hash % n).
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.data_scheduler import DataScheduler, ExternalStore
from repro.core.object_store import (PMemObjectStore, _flatten, _unflatten)
from repro.kernels.ckpt_codec.ref import decode_ref, encode_ref

TILE = 1024


@dataclass
class ShardInfo:
    node: str
    start_row: int
    n_rows: int


def plan_shards(path: str, shape: Tuple[int, ...],
                nodes: Sequence[str]) -> List[ShardInfo]:
    n = len(nodes)
    if shape and shape[0] >= n and shape[0] % n == 0:
        rows = shape[0] // n
        return [ShardInfo(nodes[i], i * rows, rows) for i in range(n)]
    owner = nodes[zlib.crc32(path.encode()) % n]
    return [ShardInfo(owner, 0, shape[0] if shape else 1)]


class DistributedCheckpointer:
    def __init__(self, stores: Dict[str, PMemObjectStore],
                 scheduler: Optional[DataScheduler] = None,
                 external: Optional[ExternalStore] = None,
                 buddy: bool = True, delta: bool = False, slots: int = 2):
        self.stores = stores
        self.nodes = sorted(stores)
        self.scheduler = scheduler
        self.external = external
        self.buddy = buddy
        self.delta = delta
        if delta and slots < 2:
            raise ValueError(
                "delta checkpointing needs slots >= 2: the full base "
                "must survive while deltas rotate through other slots")
        self.slots = slots
        self._pending: List = []
        self._slot_counter: Optional[int] = None

    # ------------------------------------------------------------------
    def _meta_store(self) -> PMemObjectStore:
        return self.stores[self.nodes[0]]

    def _meta_put_json(self, name: str, obj) -> None:
        """Replicate small metadata (manifests, latest-pointer) to every
        live node's pool, so losing any single node — including the
        first — never loses the checkpoint index."""
        wrote = 0
        for nid in self._live_nodes():
            try:
                self.stores[nid].pool.put_json(name, obj)
                wrote += 1
            except IOError:
                continue
        if not wrote:
            raise IOError(f"no reachable pool for metadata {name}")

    def _meta_get_json(self, name: str):
        err: Optional[Exception] = None
        for nid in self.nodes:
            try:
                return self.stores[nid].pool.get_json(name)
            except (IOError, FileNotFoundError) as e:
                err = e
        raise err if err is not None else FileNotFoundError(name)

    def _alloc_slot(self, avoid: Optional[int] = None) -> int:
        """Round-robin slot rotation. Raw ``step % slots`` degenerates to
        a single slot whenever the checkpoint stride shares a factor with
        ``slots`` (e.g. ckpt_every=2), which would void the shadow-slot
        crash guarantee; a per-save ordinal cannot. Initialised from the
        last committed manifest so restarts keep rotating.

        ``avoid`` pins a slot that must NOT be overwritten — the slot
        holding the active delta base. With slots=2 every delta save then
        reuses the non-base slot; a crash mid-delta-write falls back to
        the full base (caught by ``_check_slot_step``) instead of
        destroying the base and orphaning the whole chain."""
        if self._slot_counter is None:
            step = self.latest_step()
            if step is None:
                self._slot_counter = 0
            else:
                try:
                    last = self._meta_get_json(
                        f"ckpt/manifest_step{step}.json")["slot"]
                except (IOError, FileNotFoundError, KeyError):
                    last = -1
                self._slot_counter = (last + 1) % self.slots
        slot = self._slot_counter
        if avoid is not None and slot == avoid:
            slot = (slot + 1) % self.slots
        self._slot_counter = (slot + 1) % self.slots
        return slot

    def buddy_of(self, nid: str, ring: Optional[Sequence[str]] = None
                 ) -> str:
        ring = list(ring) if ring else self.nodes
        i = ring.index(nid)
        return ring[(i + 1) % len(ring)]

    def _live_nodes(self) -> List[str]:
        """Nodes whose pmem is reachable — a checkpoint after a node
        loss proceeds on the survivors (elastic save ring)."""
        live = [n for n in self.nodes
                if getattr(self.stores[n].pool, "alive", True)]
        return live or self.nodes

    # ------------------------------------------------------------------
    def save(self, step: int, tree, *, base_step: Optional[int] = None,
             drain: bool = False,
             post_commit: Optional[List] = None) -> dict:
        """Write one checkpoint. ``base_step`` enables delta encoding
        against that step's full checkpoint. Returns the global manifest.

        Post-commit drain/replicate futures are appended to
        ``post_commit`` when given (the TieredIO engine tracks them per
        save ticket), else to the internal ``_pending`` list serviced by
        ``wait_async``."""
        leaves = dict(_flatten(tree))
        avoid = None
        if base_step is not None and self.delta:
            # never rotate onto the slot holding the delta base
            avoid = self._meta_get_json(
                f"ckpt/manifest_step{base_step}.json")["slot"]
        slot = self._alloc_slot(avoid)
        ring = self._live_nodes()
        manifest: Dict[str, Any] = {
            "step": step, "slot": slot, "ts": time.time(),
            "delta_base": base_step, "leaves": {}, "nodes": ring}
        per_node: Dict[str, Dict[str, np.ndarray]] = {
            nid: {} for nid in ring}
        for path, arr in leaves.items():
            arr = np.asarray(arr)
            shards = plan_shards(path, arr.shape, ring)
            manifest["leaves"][path] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "shards": [[s.node, s.start_row, s.n_rows] for s in shards]}
            for s in shards:
                part = arr[s.start_row:s.start_row + s.n_rows] \
                    if arr.ndim else arr
                per_node[s.node][path] = part

        obj = f"ckpt/slot{slot}"
        for nid in ring:
            payload = per_node[nid]
            if base_step is not None and self.delta:
                payload = self._encode_delta(nid, payload, base_step)
            self.stores[nid].put(obj, payload, version=0,
                                 meta={"step": step})
        # commit point AFTER all node writes are flushed:
        self._meta_put_json(f"ckpt/manifest_step{step}.json", manifest)
        self._meta_put_json("ckpt/latest.json", {"step": step})
        # async post-commit work (never blocks the step loop)
        sink = self._pending if post_commit is None else post_commit
        if self.scheduler is not None:
            if self.buddy and len(ring) > 1:
                for nid in ring:
                    sink.append(self.scheduler.replicate(
                        nid, obj, self.buddy_of(nid, ring)))
            if drain and self.external is not None:
                for nid in ring:
                    sink.append(self.scheduler.drain(
                        nid, obj, f"ckpt_step{step}_{nid}",
                        expect_meta={"step": step}))
        return manifest

    def wait_async(self) -> None:
        for f in self._pending:
            f.result()
        self._pending = []

    # ------------------------------------------------------------------
    def _encode_delta(self, nid, payload, base_step):
        base_man = self._meta_get_json(
            f"ckpt/manifest_step{base_step}.json")
        base_slot = base_man["slot"]
        self._check_slot_step(self.stores[nid], f"ckpt/slot{base_slot}",
                              base_step)
        base = self.stores[nid].get(f"ckpt/slot{base_slot}")
        base_leaves = dict(_flatten(base))
        out = {}
        for path, arr in payload.items():
            b = base_leaves.get(path.replace("/", "/"))
            key = path
            flat_b = dict(_flatten({key: b})) if b is not None else {}
            if b is None or np.asarray(b).shape != arr.shape:
                out[path] = arr
                continue
            new_f = np.asarray(arr, np.float32).reshape(-1)
            base_f = np.asarray(b, np.float32).reshape(-1)
            pad = (-len(new_f)) % TILE
            if pad:
                new_f = np.pad(new_f, (0, pad))
                base_f = np.pad(base_f, (0, pad))
            q, scale = encode_ref(new_f.reshape(-1, TILE),
                                  base_f.reshape(-1, TILE))
            out[path + ".__dq"] = q
            out[path + ".__ds"] = scale
        return out

    def _decode_delta(self, nid, payload, base_step, manifest,
                      via_replica: bool = False):
        base_man = self._meta_get_json(
            f"ckpt/manifest_step{base_step}.json")
        base_name = f"ckpt/slot{base_man['slot']}"
        store = self.stores[nid]
        if via_replica:
            # replicas were placed on the buddy within the ring the BASE
            # manifest was saved under, not today's full node list
            base_ring = base_man.get("nodes") or self.nodes
            store = self.stores[self.buddy_of(nid, base_ring)]
            base_name = f"replica/{nid}/{base_name}"
        self._check_slot_step(store, base_name, base_step)
        base = store.get(base_name)
        base_leaves = dict(_flatten(base))
        out = {}
        for path, arr in payload.items():
            if path.endswith(".__ds"):
                continue
            if path.endswith(".__dq"):
                real = path[:-len(".__dq")]
                scale = payload[real + ".__ds"]
                b = base_leaves[real]
                ent = manifest["leaves"][real]
                dec = decode_ref(arr, scale,
                                 np.pad(np.asarray(b, np.float32)
                                        .reshape(-1),
                                        (0, (-np.asarray(b).size) % TILE))
                                 .reshape(-1, TILE),
                                 dtype=np.dtype(ent["dtype"]))
                shard_shape = list(np.asarray(b).shape)
                out[real] = dec.reshape(-1)[:np.asarray(b).size] \
                    .reshape(shard_shape)
            else:
                out[path] = arr
        return out

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        try:
            return self._meta_get_json("ckpt/latest.json")["step"]
        except (IOError, FileNotFoundError):
            return None

    def available_steps(self) -> List[int]:
        """All committed checkpoint steps (manifest present on any
        reachable node), ascending."""
        steps = set()
        prefix, suffix = "ckpt/manifest_step", ".json"
        for nid in self.nodes:
            for name in self.stores[nid].pool.list("ckpt/"):
                if name.startswith(prefix) and name.endswith(suffix):
                    steps.add(int(name[len(prefix):-len(suffix)]))
        return sorted(steps)

    def restore_latest_recoverable(self, *, lost_nodes: Sequence[str] = ()):
        """Walk committed steps newest-first and restore the first one
        whose shards (or buddy replicas, for ``lost_nodes``) are all
        readable. A node can die between a checkpoint's commit and its
        replication finishing; that checkpoint is then unrecoverable and
        recovery must fall back to the previous one."""
        last_err: Optional[Exception] = None
        for step in reversed(self.available_steps()):
            try:
                return self.restore(step, lost_nodes=lost_nodes)
            except (IOError, FileNotFoundError, KeyError) as e:
                last_err = e
        raise IOError(
            f"no recoverable checkpoint with lost_nodes={list(lost_nodes)}"
        ) from last_err

    @staticmethod
    def _check_slot_step(store: PMemObjectStore, name: str,
                         step: int) -> None:
        """Slots are shadow-rotated, so an old manifest can point at a
        slot that a NEWER checkpoint has since overwritten. The per-node
        object records the step it was written for; a mismatch must fail
        the restore (restore_latest_recoverable then walks further back)
        rather than silently mixing steps."""
        got = store.manifest(name).get("meta", {}).get("step")
        if got != step:
            raise IOError(
                f"{name} holds step {got}, wanted {step} (slot reused)")

    def restore(self, step: Optional[int] = None, *,
                lost_nodes: Sequence[str] = (),
                nodes_subset: Optional[Sequence[str]] = None):
        """Reassemble the global pytree. Tolerates lost nodes (via buddy
        replicas) and arbitrary re-sharding (byte-range reads)."""
        if step is None:
            step = self.latest_step()
        manifest = self._meta_get_json(
            f"ckpt/manifest_step{step}.json")
        slot = manifest["slot"]
        obj = f"ckpt/slot{slot}"
        ring = manifest.get("nodes") or self.nodes
        cache: Dict[str, Dict[str, np.ndarray]] = {}

        def node_payload(nid: str) -> Dict[str, np.ndarray]:
            if nid not in cache:
                src, name = nid, obj
                if nid in lost_nodes:
                    src = self.buddy_of(nid, ring)
                    name = f"replica/{nid}/{obj}"
                    if not self.stores[src].exists(name):
                        raise IOError(f"no replica of {nid} on {src}")
                # CRC-verified read + step check against the SAME object
                # manifest: torn or reused-slot data fails here rather
                # than reassembling a mixed-step tree
                tree_part, obj_man = self.stores[src].get_with_manifest(
                    name)
                got = obj_man.get("meta", {}).get("step")
                if got != step:
                    raise IOError(f"{name} holds step {got}, wanted "
                                  f"{step} (slot reused)")
                payload = dict(_flatten(tree_part))
                if manifest.get("delta_base") is not None and self.delta:
                    payload = self._decode_delta(
                        nid, payload, manifest["delta_base"], manifest,
                        via_replica=(nid in lost_nodes))
                cache[nid] = payload
            return cache[nid]

        leaves = {}
        for path, ent in manifest["leaves"].items():
            shape = tuple(ent["shape"])
            dtype = np.dtype(ent["dtype"])
            if len(ent["shards"]) == 1:
                nid, start, nrows = ent["shards"][0]
                leaves[path] = node_payload(nid)[path].reshape(shape) \
                    .astype(dtype)
            else:
                parts = []
                for nid, start, nrows in ent["shards"]:
                    parts.append(node_payload(nid)[path])
                leaves[path] = np.concatenate(parts, axis=0) \
                    .reshape(shape).astype(dtype)
        return _unflatten(leaves), manifest

    def restore_shard(self, step: int, path: str, start_row: int,
                      n_rows: int) -> np.ndarray:
        """Elastic restore primitive: read an arbitrary row range of one
        leaf straight from the owning nodes' pmem (byte-granular)."""
        manifest = self._meta_get_json(
            f"ckpt/manifest_step{step}.json")
        ent = manifest["leaves"][path]
        slot = manifest["slot"]
        dtype = np.dtype(ent["dtype"])
        pieces = []
        want_lo, want_hi = start_row, start_row + n_rows
        for nid, s0, nr in ent["shards"]:
            lo, hi = max(want_lo, s0), min(want_hi, s0 + nr)
            if lo >= hi:
                continue
            self._check_slot_step(self.stores[nid], f"ckpt/slot{slot}",
                                  step)
            piece = self.stores[nid].read_leaf_slice(
                f"ckpt/slot{slot}", path, lo - s0, hi - lo)
            pieces.append(piece)
        return np.concatenate(pieces, axis=0).astype(dtype)
