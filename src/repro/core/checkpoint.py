"""Distributed node-local checkpointing on B-APM (paper §V item 8 + §III).

Design (DESIGN.md §2, §7):
  * every node writes ONLY its own shards to its OWN pmem pool ->
    checkpoint bandwidth scales linearly with node count (the paper's
    Table I claim; measured in benchmarks/bench_io_scaling.py);
  * two shadow slots + atomic manifest rename -> a crash mid-write always
    leaves the previous checkpoint intact;
  * optional incremental (delta + int8) encoding via the ckpt_codec kernel
    math -> ~4x fewer bytes for slowly-changing state;
  * async drain to the external store and buddy replication via the data
    scheduler -> the training loop never blocks on the slow tier, and any
    single node loss is recoverable;
  * manifests record GLOBAL shapes + per-node row ranges -> restore can
    re-shard onto a DIFFERENT node count / mesh (elastic restart) using
    byte-range reads only.

Shard layout: each leaf is split along dim 0 across nodes when divisible
(row ranges recorded); non-divisible leaves go to node (hash % n).
"""
from __future__ import annotations

import threading
import time
import zlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.annotations import metadata_only
from repro.core.data_scheduler import (DataScheduler, ExternalStore,
                                       SupersededError)
from repro.core.dataset_exchange import ack_targets
from repro.core.meta_log import MetaLog
from repro.core.object_store import (PMemObjectStore, _flatten, _unflatten,
                                     is_wire_object, wire_leaves)
from repro.kernels.ckpt_codec.ref import decode_ref, encode_ref

TILE = 1024


def _fold_ckpt_acks(state: dict, ev: dict) -> None:
    """MetaLog reducer for the checkpoint ack registry. State maps
    ``str(step)`` (JSON object keys are strings — snapshot round-trips
    must be identity) to the ack record the old per-step JSON held:
    ``{"step", "ts", "acks": {nid: {kind: rec}}, "ring", "delta_base"}``.

    ``seed`` RESETS the step's record (same incarnation semantics as the
    old seed-overwrites-file write: a re-save after recovery must not
    resurrect acks describing the previous incarnation's slots);
    ``ack`` upserts one (nid, kind) entry; ``adopt`` migrates a legacy
    pre-log JSON record wholesale. Records are copy-on-write so readers
    holding a previous dict keep a consistent snapshot."""
    op = ev["op"]
    if op == "seed":
        state[str(ev["step"])] = {
            "step": ev["step"], "ts": ev["ts"], "acks": {},
            "ring": ev.get("ring"), "delta_base": ev.get("delta_base")}
    elif op == "adopt":
        state.setdefault(str(ev["step"]), ev["rec_map"])
    elif op == "ack":
        key = str(ev["step"])
        rec_map = state.get(key) or {"step": ev["step"], "acks": {}}
        acks = {nid: dict(kinds)
                for nid, kinds in (rec_map.get("acks") or {}).items()}
        acks.setdefault(ev["nid"], {})[ev["kind"]] = ev["rec"]
        state[key] = {**rec_map, "acks": acks}


def _merge_acks(maps: Sequence[Dict[str, Dict[str, dict]]]
                ) -> Dict[str, Dict[str, dict]]:
    """Union per-node ack maps from divergent manifest copies; for the
    same (node, kind) the newest record (by its own ``ts``) wins."""
    merged: Dict[str, Dict[str, dict]] = {}
    for m in maps:
        for nid, kinds in m.items():
            if not isinstance(kinds, dict):
                continue
            cur = merged.setdefault(nid, {})
            for kind, rec in kinds.items():
                if kind not in cur or \
                        rec.get("ts", 0) > cur[kind].get("ts", 0):
                    cur[kind] = rec
    return merged


@dataclass
class ShardInfo:
    node: str
    start_row: int
    n_rows: int


def plan_shards(path: str, shape: Tuple[int, ...],
                nodes: Sequence[str]) -> List[ShardInfo]:
    n = len(nodes)
    if shape and shape[0] >= n and shape[0] % n == 0:
        rows = shape[0] // n
        return [ShardInfo(nodes[i], i * rows, rows) for i in range(n)]
    owner = nodes[zlib.crc32(path.encode()) % n]
    return [ShardInfo(owner, 0, shape[0] if shape else 1)]


class DistributedCheckpointer:
    def __init__(self, stores: Dict[str, PMemObjectStore],
                 scheduler: Optional[DataScheduler] = None,
                 external: Optional[ExternalStore] = None,
                 buddy: bool = True, delta: bool = False, slots: int = 2,
                 obs=None):
        self.stores = stores
        self.obs = obs
        self.nodes = sorted(stores)
        self.scheduler = scheduler
        self.external = external
        self.buddy = buddy
        self.delta = delta
        if delta and slots < 2:
            raise ValueError(
                "delta checkpointing needs slots >= 2: the full base "
                "must survive while deltas rotate through other slots")
        self.slots = slots
        self._pending: List = []
        self._slot_counter: Optional[int] = None
        # replicate/drain fan-out is owned by a TieredIO ReplicationChannel
        # (attached by the engine, or created lazily for standalone use);
        # its ack writes serialise on this lock.
        self.replication = None
        self._ack_lock = threading.Lock()
        # the ack registry lives in one append-only replicated pmem log
        # (ckpt/ackslog): a seed or ack is a ~100-byte APPEND to every
        # live pool, not a rewrite of a per-step JSON file; the folded
        # head state plays the role of the old per-step cache. Lazy:
        # first use replays the log (cold processes pay one scan).
        self._ack_log: Optional[MetaLog] = None
        # step -> slot, so hot save paths (delta base avoidance) don't
        # re-read the full base manifest from every pool; _slot_pin
        # protects the active delta base from cache trimming
        self._slot_cache: Dict[int, int] = {}
        self._slot_pin: Optional[int] = None
        # restore-scan counters live in the telemetry registry (reset
        # per restore_latest_recoverable call); ``last_restore_stats``
        # keeps the old dict-shaped read surface as an alias view
        from repro.obs.metrics import Registry, StatsView
        reg = obs.registry if obs is not None else Registry()
        self._restore_counters = {
            "skipped_by_ack": reg.counter("restore.skipped_by_ack"),
            "probed": reg.counter("restore.probed")}
        self.last_restore_stats = StatsView(self._restore_counters)

    # ------------------------------------------------------------------
    def _meta_store(self) -> PMemObjectStore:
        return self.stores[self.nodes[0]]

    def _meta_put_json(self, name: str, obj) -> None:
        """Replicate small metadata (manifests, latest-pointer) to every
        live node's pool, so losing any single node — including the
        first — never loses the checkpoint index."""
        wrote = 0
        for nid in self._live_nodes():
            try:
                self.stores[nid].pool.put_json(name, obj)
                wrote += 1
            except IOError:
                continue
        if not wrote:
            raise IOError(f"no reachable pool for metadata {name}")

    @metadata_only
    def _meta_get_json(self, name: str):
        """Resolve metadata across ALL reachable pools, not just the
        first one that answers: a rejoined node (say node0 back from the
        dead with a stale ``ckpt/latest.json``) must never shadow newer
        replicated metadata. The winner is the copy with the highest
        ``step`` (then newest ``ts``); per-node ack maps are additionally
        UNION-merged across copies, because acks recorded while some pool
        was down only exist on the pools that were live at ack time."""
        copies: List[dict] = []
        err: Optional[Exception] = None
        for nid in self.nodes:
            try:
                copies.append(self.stores[nid].pool.get_json(name))
            except (IOError, FileNotFoundError, ValueError) as e:
                # ValueError covers a torn/truncated JSON copy: put_json
                # commits atomically, so a malformed file is media
                # damage on ONE pool — the surviving copies still win
                err = e
        if not copies:
            raise err if err is not None else FileNotFoundError(name)

        def rank(c) -> Tuple[float, float]:
            step = c.get("step") if isinstance(c, dict) else None
            ts = c.get("ts") if isinstance(c, dict) else None
            return (step if isinstance(step, (int, float)) else float("-inf"),
                    ts if isinstance(ts, (int, float)) else float("-inf"))

        best = max(copies, key=rank)
        if isinstance(best, dict) and isinstance(best.get("acks"), dict):
            # merge ack maps ONLY from copies of the same incarnation
            # (same step+ts): a re-saved step's stale record, stranded
            # on a pool that was down at seed time, must not resurrect
            # acks describing the previous incarnation's slots
            best_rank = rank(best)
            best = dict(best)
            best["acks"] = _merge_acks(
                [c["acks"] for c in copies if isinstance(c, dict)
                 and isinstance(c.get("acks"), dict)
                 and rank(c) == best_rank])
        return best

    def _alloc_slot(self, avoid: Optional[int] = None) -> int:
        """Round-robin slot rotation. Raw ``step % slots`` degenerates to
        a single slot whenever the checkpoint stride shares a factor with
        ``slots`` (e.g. ckpt_every=2), which would void the shadow-slot
        crash guarantee; a per-save ordinal cannot. Initialised from the
        last committed manifest so restarts keep rotating.

        ``avoid`` pins a slot that must NOT be overwritten — the slot
        holding the active delta base. With slots=2 every delta save then
        reuses the non-base slot; a crash mid-delta-write falls back to
        the full base (caught by ``_check_slot_step``) instead of
        destroying the base and orphaning the whole chain."""
        if self._slot_counter is None:
            step = self.latest_step()
            if step is None:
                self._slot_counter = 0
            else:
                try:
                    last = self._meta_get_json(
                        f"ckpt/manifest_step{step}.json")["slot"]
                except (IOError, FileNotFoundError, KeyError):
                    last = -1
                self._slot_counter = (last + 1) % self.slots
        slot = self._slot_counter
        if avoid is not None and slot == avoid:
            slot = (slot + 1) % self.slots
        self._slot_counter = (slot + 1) % self.slots
        return slot

    def buddy_of(self, nid: str, ring: Optional[Sequence[str]] = None
                 ) -> str:
        ring = list(ring) if ring else self.nodes
        i = ring.index(nid)
        return ring[(i + 1) % len(ring)]

    def _live_nodes(self) -> List[str]:
        """Nodes whose pmem is reachable — a checkpoint after a node
        loss proceeds on the survivors (elastic save ring)."""
        live = [n for n in self.nodes
                if getattr(self.stores[n].pool, "alive", True)]
        return live or self.nodes

    # ------------------------------------------------------------------
    def save(self, step: int, tree, *, base_step: Optional[int] = None,
             drain: bool = False,
             post_commit: Optional[List] = None,
             trace: Optional[dict] = None) -> dict:
        """Write one checkpoint. ``base_step`` enables delta encoding
        against that step's full checkpoint. Returns the global manifest.

        Post-commit drain/replicate futures are appended to
        ``post_commit`` when given (the TieredIO engine tracks them per
        save ticket), else to the internal ``_pending`` list serviced by
        ``wait_async``."""
        leaves = dict(_flatten(tree))
        avoid = None
        if base_step is not None and self.delta:
            # never rotate onto the slot holding the delta base (cached
            # at save time; cross-pool manifest read only after restart)
            with self._ack_lock:
                avoid = self._slot_cache.get(base_step)
            if avoid is None:
                avoid = self._meta_get_json(
                    f"ckpt/manifest_step{base_step}.json")["slot"]
                with self._ack_lock:
                    # every _slot_cache write holds _ack_lock (lockset
                    # invariant): ack-recording worker threads trim the
                    # cache concurrently with the save path
                    self._slot_cache[base_step] = avoid
        slot = self._alloc_slot(avoid)
        ring = self._live_nodes()
        manifest: Dict[str, Any] = {
            "step": step, "slot": slot, "ts": time.time(),
            "delta_base": base_step, "leaves": {}, "nodes": ring}
        if trace:
            # correlation context minted at the save_async boundary:
            # stamped into the durable manifest and carried by the
            # replication channel into every per-node ack record, so a
            # post-crash ring replay reconnects this checkpoint's
            # replicate -> drain -> ack lifecycle as one trace
            manifest["trace"] = trace
        per_node: Dict[str, Dict[str, np.ndarray]] = {
            nid: {} for nid in ring}
        for path, arr in leaves.items():
            arr = np.asarray(arr)
            shards = plan_shards(path, arr.shape, ring)
            manifest["leaves"][path] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "shards": [[s.node, s.start_row, s.n_rows] for s in shards]}
            for s in shards:
                part = arr[s.start_row:s.start_row + s.n_rows] \
                    if arr.ndim else arr
                per_node[s.node][path] = part

        obj = f"ckpt/slot{slot}"
        for nid in ring:
            payload = per_node[nid]
            if base_step is not None and self.delta:
                # avoid IS the base slot — no per-node manifest re-read
                payload = self._encode_delta(nid, payload, base_step,
                                             avoid)
            self.stores[nid].put(obj, payload, version=0,
                                 meta={"step": step})
        # commit point AFTER all node writes are flushed. The ack map
        # lives in a small sibling record (ckpt/acks_step<N>.json) so
        # each ack rewrites ~a hundred bytes, not the whole leaves
        # index; its absence marks a pre-ack legacy step (always probed).
        self._meta_put_json(f"ckpt/manifest_step{step}.json", manifest)
        self._meta_put_json("ckpt/latest.json",
                            {"step": step, "ts": manifest["ts"]})
        with self._ack_lock:
            # seed (and invalidate any stale state of) the ack record
            # for this step: a re-save after recovery must not resurrect
            # acks that described the previous incarnation's slots (the
            # seed event RESETS the step's entry in the log fold).
            # ring + delta_base recorded here too: the recoverability
            # ranking then needs only the folded log state per skipped
            # step, and can follow the delta chain without manifests.
            self._acklog().append(
                {"op": "seed", "step": step, "ts": manifest["ts"],
                 "ring": ring, "delta_base": manifest["delta_base"]})
            self._slot_cache[step] = slot
            # pin what the next delta will read: the base just used, or
            # this full save (the likely next base)
            self._slot_pin = base_step if (
                base_step is not None and self.delta) else step
            extra = [k for k in sorted(self._slot_cache)
                     if k != self._slot_pin]
            while len(self._slot_cache) > max(self.slots, 2) + 1 and extra:
                self._slot_cache.pop(extra.pop(0))
        # async post-commit work (never blocks the step loop): the
        # replicate/drain fan-out lives in the TieredIO replication
        # channel, which records per-node acks into the manifest.
        sink = self._pending if post_commit is None else post_commit
        chan = self._replication_channel()
        if chan is not None:
            chan.submit(manifest, drain=drain, sink=sink)
        return manifest

    def _replication_channel(self):
        """The attached TieredIO ReplicationChannel, or a lazily-created
        default one so a standalone checkpointer (benchmarks, elastic
        relaunch) still replicates with acks. Import is function-local:
        tiered_io imports this module at top level."""
        if self.replication is None and self.scheduler is not None:
            from repro.core.tiered_io import ReplicationChannel
            self.replication = ReplicationChannel(self, self.scheduler)
        return self.replication

    # ---- per-node acknowledgement map --------------------------------
    @staticmethod
    def _ack_name(step: int) -> str:
        # legacy pre-log location, still read as a fallback for steps
        # saved before the registry moved into ckpt/ackslog
        return f"ckpt/acks_step{step}.json"

    def _acklog(self) -> MetaLog:
        if self._ack_log is None:
            self._ack_log = MetaLog(self.stores, self.nodes,
                                    "ckpt/ackslog",
                                    fold=_fold_ckpt_acks, obs=self.obs)
        return self._ack_log

    def record_ack(self, step: int, nid: str, kind: str,
                   info: Optional[dict] = None) -> None:
        """Record one completed replicate ("replica") or drain ("drain")
        for ``nid`` at ``step``: one small entry APPENDED to the
        replicated ack log (ckpt/ackslog) — ~100 bytes per ack, not a
        rewrite of the step's whole ack map. Called from scheduler
        worker threads on task completion; appends serialise on
        ``_ack_lock`` and the log's seq-union replay merges entries
        across pool copies, so concurrent acks and partial pool outages
        never lose acks."""
        rec = dict(info or {})
        rec["ts"] = time.time()
        with self._ack_lock:
            log = self._acklog()
            if log.state().get(str(step)) is None:
                # an ack for a step saved before the log existed:
                # migrate the legacy JSON record into the log first so
                # the new entry lands on a complete base
                try:
                    legacy = self._meta_get_json(self._ack_name(step))
                    log.append({"op": "adopt", "step": step,
                                "rec_map": legacy})
                except (IOError, FileNotFoundError):
                    pass
            log.append({"op": "ack", "step": step, "nid": nid,
                        "kind": kind, "rec": rec})

    @metadata_only
    def ack_record(self, step: int) -> Optional[dict]:
        """The full ack record for ``step`` — ``{"step", "ts", "acks",
        "ring", "delta_base"}`` — from the ack log's folded state, with
        the legacy per-step JSON (``ckpt/acks_step<N>.json``) as a
        read-only fallback for pre-log deployments. None when the step
        never seeded a record (pre-ack legacy save): consumers treat
        that as nothing-promised/always-probe."""
        rec = self._acklog().state().get(str(step))
        if rec is not None:
            return rec
        try:
            return self._meta_get_json(self._ack_name(step))
        except (IOError, FileNotFoundError):
            return None

    @metadata_only
    def acks(self, step: int) -> Dict[str, Dict[str, dict]]:
        """The merged per-node ack map for ``step`` ({} if unknown)."""
        rec_map = self.ack_record(step)
        if rec_map is None:
            return {}
        return dict(rec_map.get("acks") or {})

    def wait_async(self) -> None:
        """Join pending post-commit replicate/drain work, raising real
        errors. A ``SupersededError`` is benign here: the source slot
        was reused by a NEWER save before the queued transfer read it,
        and that save queued its own replicate — dropping the stale one
        loses nothing (same filter as the TieredIO joins)."""
        for f in self._pending:
            try:
                f.result()
            except SupersededError:
                pass
        self._pending = []

    # ------------------------------------------------------------------
    def _encode_delta(self, nid, payload, base_step, base_slot):
        self._check_slot_step(self.stores[nid], f"ckpt/slot{base_slot}",
                              base_step)
        base = self.stores[nid].get(f"ckpt/slot{base_slot}")
        base_leaves = dict(_flatten(base))
        out = {}
        for path, arr in payload.items():
            b = base_leaves.get(path.replace("/", "/"))
            key = path
            flat_b = dict(_flatten({key: b})) if b is not None else {}
            if b is None or np.asarray(b).shape != arr.shape:
                out[path] = arr
                continue
            new_f = np.asarray(arr, np.float32).reshape(-1)
            base_f = np.asarray(b, np.float32).reshape(-1)
            pad = (-len(new_f)) % TILE
            if pad:
                new_f = np.pad(new_f, (0, pad))
                base_f = np.pad(base_f, (0, pad))
            q, scale = encode_ref(new_f.reshape(-1, TILE),
                                  base_f.reshape(-1, TILE))
            out[path + ".__dq"] = q
            out[path + ".__ds"] = scale
        return out

    def _drained_leaves(self, nid: str,
                        step: int) -> Optional[Dict[str, np.ndarray]]:
        """The external drained copy of ``nid``'s shard at ``step`` as
        flat ``{path: array}`` leaves — the last-resort recovery tier,
        consulted ONLY when the recorded drain ack says it exists (no
        blind external probes). Returns None when there is no usable
        ack/external copy. The external name carries the step, so
        identity is pinned by construction (the drain task's expect_meta
        verified it at drain time). Zero-copy drains land as wire
        payloads (decoded here, CRC-verified against the carried
        manifest — encoded ones through the wire codec); legacy pickled
        trees flatten."""
        if self.external is None:
            return None
        rec = self.acks(step).get(nid, {}).get("drain")
        if not rec:
            return None
        ext = rec.get("external") or f"ckpt_step{step}_{nid}"
        try:
            obj = self.external.get(ext)
        except (IOError, OSError, FileNotFoundError):
            return None
        if is_wire_object(obj):
            return wire_leaves(obj)
        return dict(_flatten(obj))

    def _base_leaves(self, nid: str, base_step: int,
                     lost_nodes: Sequence[str] = ()
                     ) -> Dict[str, np.ndarray]:
        """A delta chain's base payload for ``nid`` as flat leaves,
        walking the same recovery tiers as the shard itself: node-local
        slot, then the ack-recorded replica targets (repair may have
        re-placed the copy) with the base ring's buddy as the legacy
        fallback, then the ack-recorded external drained copy."""
        base_man = self._meta_get_json(
            f"ckpt/manifest_step{base_step}.json")
        base_name = f"ckpt/slot{base_man['slot']}"
        if nid not in lost_nodes:
            self._check_slot_step(self.stores[nid], base_name, base_step)
            return dict(_flatten(self.stores[nid].get(base_name)))
        base_ring = base_man.get("nodes") or self.nodes
        rep = f"replica/{nid}/{base_name}"
        cands = [t for t in
                 ack_targets(self.acks(base_step)
                             .get(nid, {}).get("replica"))
                 if t not in lost_nodes]
        legacy = self.buddy_of(nid, base_ring)
        if legacy not in cands and legacy not in lost_nodes:
            cands.append(legacy)
        for holder in cands:
            try:
                if self.stores[holder].exists(rep):
                    self._check_slot_step(self.stores[holder], rep,
                                          base_step)
                    return dict(_flatten(self.stores[holder].get(rep)))
            except IOError:
                continue  # holder pool unreadable too — keep walking
        drained = self._drained_leaves(nid, base_step)
        if drained is not None:
            return drained
        raise IOError(f"no readable base (step {base_step}) for {nid}: "
                      f"pmem lost, replica lost, no drain ack")

    def _decode_delta(self, nid, payload, base_step, manifest,
                      lost_nodes: Sequence[str] = ()):
        base_leaves = self._base_leaves(nid, base_step, lost_nodes)
        out = {}
        for path, arr in payload.items():
            if path.endswith(".__ds"):
                continue
            if path.endswith(".__dq"):
                real = path[:-len(".__dq")]
                scale = payload[real + ".__ds"]
                b = base_leaves[real]
                ent = manifest["leaves"][real]
                dec = decode_ref(arr, scale,
                                 np.pad(np.asarray(b, np.float32)
                                        .reshape(-1),
                                        (0, (-np.asarray(b).size) % TILE))
                                 .reshape(-1, TILE),
                                 dtype=np.dtype(ent["dtype"]))
                shard_shape = list(np.asarray(b).shape)
                out[real] = dec.reshape(-1)[:np.asarray(b).size] \
                    .reshape(shard_shape)
            else:
                out[path] = arr
        return out

    # ------------------------------------------------------------------
    @metadata_only
    def latest_step(self) -> Optional[int]:
        try:
            return self._meta_get_json("ckpt/latest.json")["step"]
        except (IOError, FileNotFoundError):
            return None

    @metadata_only
    def available_steps(self) -> List[int]:
        """All committed checkpoint steps (manifest present on any
        reachable node), ascending."""
        steps = set()
        prefix, suffix = "ckpt/manifest_step", ".json"
        for nid in self.nodes:
            for name in self.stores[nid].pool.list("ckpt/"):
                if name.startswith(prefix) and name.endswith(suffix):
                    steps.add(int(name[len(prefix):-len(suffix)]))
        return sorted(steps)

    def restore_latest_recoverable(self, *, lost_nodes: Sequence[str] = (),
                                   use_acks: bool = True):
        """Walk committed steps newest-first and restore the first one
        whose shards (or buddy replicas, for ``lost_nodes``) are all
        readable. A node can die between a checkpoint's commit and its
        replication finishing; that checkpoint is then unrecoverable and
        recovery must fall back to the previous one.

        With ``use_acks`` (default), steps are ranked by acknowledged
        durability first: a step whose ack map shows a lost shard owner
        without a completed replica ack — or whose replica landed on
        another lost node — is skipped on metadata alone, WITHOUT any
        store reads. Probing (attempting the restore) happens only for
        steps the acks mark plausible, or for pre-ack legacy manifests.
        ``last_restore_stats`` records the skipped/probed split
        (benchmarks/bench_replication.py measures the gap vs probe-all).
        """
        last_err: Optional[Exception] = None
        # per-call scan counters: registry instruments reset at entry;
        # ``last_restore_stats`` is the permanent read-through view
        stats = self._restore_counters
        for c in stats.values():
            c.set(0)
        for step in reversed(self.available_steps()):
            if use_acks and lost_nodes and \
                    not self._acks_plausible(step, lost_nodes):
                stats["skipped_by_ack"].inc()
                continue
            stats["probed"].inc()
            try:
                return self.restore(step, lost_nodes=lost_nodes)
            except (IOError, FileNotFoundError, KeyError) as e:
                last_err = e
        raise IOError(
            f"no recoverable checkpoint with lost_nodes={list(lost_nodes)}"
        ) from last_err

    @metadata_only
    def _acks_plausible(self, step: int,
                        lost_nodes: Sequence[str]) -> bool:
        """Metadata-only recoverability check — ONE small JSON read:
        every lost node that held shards at ``step`` (i.e. was in the
        save ring the ack record captured) must have an acknowledged
        replica on a surviving node, OR an acknowledged drain to the
        external store (the drain tier survives any pmem loss). Steps
        without an ack record (pre-ack saves, or the record lost with
        its pools) stay plausible — the probing restore is then the
        arbiter."""
        rec_map = self.ack_record(step)
        if rec_map is None:
            return True
        ring = rec_map.get("ring") or self.nodes
        acks = rec_map.get("acks") or {}
        for nid in lost_nodes:
            if nid not in ring:
                continue  # held no shards at this step
            if acks.get(nid, {}).get("drain") and self.external is not None:
                continue  # external drained copy outlives any pmem loss
            targets = ack_targets(acks.get(nid, {}).get("replica"))
            if not targets:
                return False  # died between commit and replica ack
            if all(t in lost_nodes for t in targets):
                return False  # every acked replica on another dead node
        base = rec_map.get("delta_base")
        if base is not None and base < step:  # bases are strictly older
            # a delta restore also reads the base chain: rank by ITS
            # acks too, or the probe pays for an undecodable step
            return self._acks_plausible(base, lost_nodes)
        return True

    @staticmethod
    def _check_slot_step(store: PMemObjectStore, name: str,
                         step: int) -> None:
        """Slots are shadow-rotated, so an old manifest can point at a
        slot that a NEWER checkpoint has since overwritten. The per-node
        object records the step it was written for; a mismatch must fail
        the restore (restore_latest_recoverable then walks further back)
        rather than silently mixing steps."""
        got = store.manifest(name).get("meta", {}).get("step")
        if got != step:
            raise IOError(
                f"{name} holds step {got}, wanted {step} (slot reused)")

    def restore(self, step: Optional[int] = None, *,
                lost_nodes: Sequence[str] = (),
                nodes_subset: Optional[Sequence[str]] = None):
        """Reassemble the global pytree. Tolerates lost nodes (via buddy
        replicas) and arbitrary re-sharding (byte-range reads). Full
        (non-delta) shards are read leaf-by-leaf via byte-range
        ``get_leaf`` against one manifest snapshot per holder — no
        whole-shard payload is ever materialized, and encoded replicas
        decode per leaf on demand."""
        if step is None:
            step = self.latest_step()
        manifest = self._meta_get_json(
            f"ckpt/manifest_step{step}.json")
        leaves = self._assemble(step, manifest, None, lost_nodes)
        return _unflatten(leaves), manifest

    def restore_leaves(self, step: int, paths: Sequence[str], *,
                       lost_nodes: Sequence[str] = ()
                       ) -> Dict[str, np.ndarray]:
        """Partial-shard restore: assemble ONLY the named leaves, each
        read as a byte range from whichever tier holds its shards (own
        slot, ack-recorded replica, drained copy) — the sibling leaves
        are never touched. This is the enabler for N->M warm resize:
        a resizing job pulls exactly the rows/leaves its new layout
        needs while the old processes drain. On a delta-encoded step
        the needed nodes' payloads are decoded first (a delta leaf is
        not byte-addressable until decoded against its base); only the
        requested leaves are returned either way."""
        manifest = self._meta_get_json(
            f"ckpt/manifest_step{step}.json")
        missing = set(paths) - set(manifest["leaves"])
        if missing:
            raise KeyError(
                f"step {step} has no leaves {sorted(missing)}")
        return self._assemble(step, manifest, set(paths), lost_nodes)

    def _assemble(self, step: int, manifest: dict,
                  paths: Optional[set], lost_nodes: Sequence[str]
                  ) -> Dict[str, np.ndarray]:
        slot = manifest["slot"]
        obj = f"ckpt/slot{slot}"
        ring = manifest.get("nodes") or self.nodes
        acks = self.acks(step)  # one metadata read for all shards
        delta = manifest.get("delta_base") is not None and self.delta
        src_cache: Dict[str, tuple] = {}
        payload_cache: Dict[str, Dict[str, np.ndarray]] = {}

        def source(nid: str) -> tuple:
            """Resolve WHERE nid's shard lives, once per node:
            ``("pmem", holder, name, obj_man)`` — step-checked against
            the holder's object manifest — or ``("flat", leaves)`` from
            the drain tier. Raises when every recorded copy is gone."""
            if nid in src_cache:
                return src_cache[nid]
            s = self._locate_shard(nid, obj, step, acks, ring,
                                   lost_nodes)
            if s is None:
                # drain-tier recovery: shard AND replica died — the
                # recorded drain ack says an external copy exists
                # (never probed blindly)
                flat = self._drained_leaves(nid, step)
                if flat is None:
                    raise IOError(
                        f"no replica of {nid} on "
                        f"{self.buddy_of(nid, ring)} and no "
                        f"acknowledged drain for step {step}")
                s = ("flat", flat)
            src_cache[nid] = s
            return s

        def node_payload(nid: str) -> Dict[str, np.ndarray]:
            # whole-shard materialization: only the delta path needs it
            # (every delta leaf decodes against the full base anyway)
            if nid not in payload_cache:
                s = source(nid)
                if s[0] == "flat":
                    payload = dict(s[1])
                else:
                    _, holder, name, _man = s
                    tree_part, _ = self.stores[holder] \
                        .get_with_manifest(name)
                    payload = dict(_flatten(tree_part))
                if delta:
                    payload = self._decode_delta(
                        nid, payload, manifest["delta_base"], manifest,
                        lost_nodes=lost_nodes)
                payload_cache[nid] = payload
            return payload_cache[nid]

        def leaf_part(nid: str, path: str) -> np.ndarray:
            if delta:
                return node_payload(nid)[path]
            s = source(nid)
            if s[0] == "flat":
                return s[1][path]
            _, holder, name, obj_man = s
            # byte-range read of ONE leaf against the step-checked
            # manifest snapshot: siblings untouched, CRC verified,
            # encoded replicas decoded on demand
            return self.stores[holder].get_leaf(name, path, man=obj_man)

        leaves = {}
        for path, ent in manifest["leaves"].items():
            if paths is not None and path not in paths:
                continue
            shape = tuple(ent["shape"])
            dtype = np.dtype(ent["dtype"])
            if len(ent["shards"]) == 1:
                nid, start, nrows = ent["shards"][0]
                leaves[path] = leaf_part(nid, path).reshape(shape) \
                    .astype(dtype)
            else:
                parts = []
                for nid, start, nrows in ent["shards"]:
                    parts.append(leaf_part(nid, path))
                leaves[path] = np.concatenate(parts, axis=0) \
                    .reshape(shape).astype(dtype)
        return leaves

    def _locate_shard(self, nid: str, obj: str, step: int, acks: dict,
                      ring: Sequence[str],
                      lost_nodes: Sequence[str]) -> Optional[tuple]:
        """The pmem holder of ``nid``'s shard: the node's own slot, or —
        for a lost node — a replica from the ack-recorded targets
        (repair may have moved it off the ring buddy), then the ring
        buddy for pre-ack legacy steps. The holder's object manifest is
        read ONCE here, step-checked (torn or reused-slot data fails
        rather than reassembling a mixed-step tree) and returned so
        every per-leaf read is served against the same snapshot. None
        when every pmem copy is gone (caller consults the drain tier)."""
        if nid not in lost_nodes:
            man = self.stores[nid].manifest(obj)
            got = man.get("meta", {}).get("step")
            if got != step:
                raise IOError(f"{obj} holds step {got}, wanted "
                              f"{step} (slot reused)")
            return ("pmem", nid, obj, man)
        name = f"replica/{nid}/{obj}"
        cands = [t for t in
                 ack_targets(acks.get(nid, {}).get("replica"))
                 if t not in lost_nodes]
        legacy = self.buddy_of(nid, ring)
        if legacy not in cands and legacy not in lost_nodes:
            cands.append(legacy)
        for src in cands:
            try:
                if self.stores[src].exists(name):
                    man = self.stores[src].manifest(name)
                    got = man.get("meta", {}).get("step")
                    if got != step:
                        raise IOError(
                            f"{name} holds step {got}, wanted {step} "
                            f"(slot reused)")
                    return ("pmem", src, name, man)
            except IOError:
                continue  # that holder's pool died too
        return None

    def restore_shard(self, step: int, path: str, start_row: int,
                      n_rows: int, *,
                      lost_nodes: Sequence[str] = ()) -> np.ndarray:
        """Elastic restore primitive: read an arbitrary row range of one
        leaf straight from the owning nodes' pmem (byte-granular).
        With ``lost_nodes``, a dead owner's rows come from its
        ack-recorded replica (which may be codec-encoded — only the
        covering tiles are decoded) or, failing that, its drained copy."""
        manifest = self._meta_get_json(
            f"ckpt/manifest_step{step}.json")
        ent = manifest["leaves"][path]
        slot = manifest["slot"]
        obj = f"ckpt/slot{slot}"
        ring = manifest.get("nodes") or self.nodes
        dtype = np.dtype(ent["dtype"])
        acks = self.acks(step) if lost_nodes else {}
        pieces = []
        want_lo, want_hi = start_row, start_row + n_rows
        for nid, s0, nr in ent["shards"]:
            lo, hi = max(want_lo, s0), min(want_hi, s0 + nr)
            if lo >= hi:
                continue
            s = self._locate_shard(nid, obj, step, acks, ring,
                                   lost_nodes)
            if s is not None:
                _, holder, name, _man = s
                piece = self.stores[holder].read_leaf_slice(
                    name, path, lo - s0, hi - lo)
            else:
                flat = self._drained_leaves(nid, step)
                if flat is None:
                    raise IOError(
                        f"no copy of {nid}'s rows [{lo}, {hi}) for "
                        f"step {step}: pmem lost, replica lost, no "
                        f"drain ack")
                piece = np.asarray(flat[path])[lo - s0:hi - s0]
            pieces.append(piece)
        return np.concatenate(pieces, axis=0).astype(dtype)
