"""MetaLog: an append-only, replicated, pmem-resident record log.

The metadata plane's storage primitive (ROADMAP item 3). Every ack,
lease, catalog record and journal entry used to be a read-merge-rewrite
of a whole JSON blob replicated to every pool — O(state) bytes per
update, quadratic over a workload's lifetime. The paper's pitch for
byte-addressable persistent memory is exactly the opposite access
pattern: small persistent APPENDS (store + CLWB + SFENCE), not file
rewrites. ``MetaLog`` provides it:

  * **Entries** are fixed-header, length-prefixed, CRC-guarded JSON
    payloads appended via ``PMemRegion`` byte-range writes. Each entry
    carries a monotonically increasing ``seq``. The file header records
    a ``committed_tail``: an append writes entry bytes, flushes, THEN
    advances the tail and flushes again — bytes past the committed tail
    (a torn append) are invisible to replay by construction.
  * **Replication**: each entry is appended to a copy of the log on
    every live pool (same discipline as the old per-record JSON). A pool
    that is down misses entries; replay UNIONS entries by ``seq`` across
    all readable copies, so anything acked on any surviving pool is
    recovered. A pool that rejoins behind is reseeded with a snapshot of
    the current state before the next append lands on it.
  * **Replay** is deterministic: state = newest snapshot (or the
    ``base`` legacy loader for pre-log deployments), then every event
    with ``seq`` greater than the snapshot's, in ``seq`` order, through
    the caller's ``fold(state, event)`` reducer — the same reducer that
    maintains the in-memory head state live, so replay reproduces
    exactly the dict the old cross-pool merge functions returned.
  * **Per-pool read cursors**: the writer remembers (epoch, offset) per
    pool copy and reads only the new tail bytes when syncing — a
    foreign append (another process) is absorbed incrementally, never
    by re-scanning the whole log.
  * **Compaction** folds the prefix into one snapshot entry once the
    tail passes a size/entry threshold. Crash-safe in two phases: the
    snapshot file is written and flushed (acked) on every live pool
    FIRST, and only then atomically renamed over the live log (the
    prefix trim). A crash between the phases leaves the old log intact
    everywhere (the orphan snapshot file is ignored by replay and
    reclaimed by the next compaction); a crash mid-rename leaves each
    pool with either the old or the new log — both replay correctly,
    and the union across pools loses nothing.

Concurrency: one writer per log per process (appends serialise on an
internal lock). Cross-process single-writer discipline is the callers'
documented contract (see ``DatasetCatalog``); the seq-union replay keeps
concurrent FOREIGN appends from being lost, but does not order them.
"""
from __future__ import annotations

import copy
import json
import os
import struct
import threading
import time
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: file header: magic(6) | version(u16) | committed_tail(u64) | epoch(u64)
_HDR = struct.Struct("<6sHQQ")
HDR_SIZE = 64  # header slot is padded: entries start 64-byte aligned
_MAGIC = b"MLOG1\x00"
_VERSION = 1
#: offset of committed_tail inside the header (little-endian u64)
_TAIL_OFF = 8

#: entry header: payload_len(u32) | crc32(u32) | seq(u64) | kind(u8) pad(7)
_ENTRY = struct.Struct("<IIQB7x")

KIND_EVENT = 0
KIND_SNAPSHOT = 1

#: initial region size for a fresh log file (doubles as it grows)
MIN_CAPACITY = 1 << 15


def _pack_entry(seq: int, kind: int, payload: bytes) -> bytes:
    return _ENTRY.pack(len(payload), zlib.crc32(payload), seq,
                       kind) + payload


def _u64le(value: int) -> np.ndarray:
    return np.frombuffer(struct.pack("<Q", value), dtype=np.uint8)


class MetaLog:
    """One replicated append-only log with a folded head state.

    ``fold(state, event)`` is the caller's reducer: it applies one event
    dict to the mutable ``state`` dict, both live (on append) and during
    replay — determinism of the reducer IS the determinism of replay
    (events carry their own ``ts``, stamped once at append time).
    ``base()`` (optional) loads the pre-log legacy state a cold replay
    starts from when no snapshot entry exists yet — the migration hook
    for surfaces that used to live in replicated JSON records.
    """

    def __init__(self, stores, nodes: Sequence[str], name: str, *,
                 fold: Callable[[dict, dict], None],
                 base: Optional[Callable[[], dict]] = None,
                 compact_entries: int = 2048,
                 compact_bytes: int = 1 << 20, obs=None):
        self.stores = stores
        self._obs = obs
        self.nodes = sorted(nodes)
        self.name = name
        self._fold = fold
        self._base = base
        self.compact_entries = compact_entries
        self.compact_bytes = compact_bytes
        self._lock = threading.RLock()
        self._state: Optional[dict] = None
        self._applied = 0        # highest seq folded into _state
        self._next_seq = 1
        self._entries_since_snap = 0
        # nid -> (epoch, committed_tail) as last seen by this writer
        self._cursors: Dict[str, Tuple[int, int]] = {}
        # pools whose log copy holds every entry this writer knows of
        self._synced: set = set()
        self.stats = {"appends": 0, "compactions": 0, "reseeds": 0,
                      "replay_bytes": 0, "snapshot_bytes": 0}
        # append/replay/compaction wall-clock histograms (shared across
        # every MetaLog on the same plane: acks, catalog, journals)
        from repro.obs.metrics import Registry
        reg = obs.registry if obs is not None else Registry()
        self._t_append = reg.histogram("metalog.append_s")
        self._t_replay = reg.histogram("metalog.replay_s")
        self._t_compact = reg.histogram("metalog.compact_s")

    # ---- plumbing -----------------------------------------------------
    def _pool(self, nid: str):
        return self.stores[nid].pool

    def _live(self) -> List[str]:
        live = [n for n in self.nodes
                if getattr(self._pool(n), "alive", True)]
        return live or self.nodes

    # ---- per-pool file access ----------------------------------------
    def _read_header(self, region) -> Tuple[int, int]:
        raw = bytes(region.read(0, _HDR.size))
        magic, version, tail, epoch = _HDR.unpack(raw)
        if magic != _MAGIC or version != _VERSION:
            raise IOError(f"{self.name}: bad log header")
        return tail, epoch

    def _read_entries(self, region, start: int, tail: int,
                      skip_snap_upto: int = -1
                      ) -> Tuple[List[Tuple[int, int, Optional[dict]]],
                                 int]:
        """Parse entries in [start, tail): (seq, kind, payload) triples
        plus the bytes actually read. Stops at the first corrupt entry —
        everything before the committed tail was flushed before the tail
        advanced, so corruption here means media damage, not a torn
        append; salvage the readable prefix.

        A snapshot entry's header ``seq`` equals its ``upto``, so a
        snapshot already dominated by a better copy (``seq <=
        skip_snap_upto``) is skipped WITHOUT reading its payload — the
        replay of N replicated copies costs one snapshot body plus N
        sets of headers, not N bodies. Skipped snapshots surface as
        ``(seq, KIND_SNAPSHOT, None)`` placeholders (cursor accounting
        still needs their position)."""
        out: List[Tuple[int, int, Optional[dict]]] = []
        nread = 0
        off = start
        while off + _ENTRY.size <= tail:
            ln, crc, seq, kind = _ENTRY.unpack(
                bytes(region.read(off, _ENTRY.size)))
            nread += _ENTRY.size
            end = off + _ENTRY.size + ln
            if end > tail:
                break
            if kind == KIND_SNAPSHOT and seq <= skip_snap_upto:
                out.append((seq, kind, None))
                off = end
                continue
            payload = bytes(region.read(off + _ENTRY.size, ln))
            nread += ln
            if zlib.crc32(payload) != crc:
                break
            try:
                out.append((seq, kind, json.loads(payload)))
            except ValueError:
                break
            off = end
        return out, nread

    def _write_fresh(self, nid: str, name: str,
                     blobs: Sequence[bytes]) -> Tuple[int, int]:
        """Create/overwrite region ``name`` on ``nid`` holding exactly
        ``blobs`` as its committed entries. Returns (epoch, tail)."""
        pool = self._pool(nid)
        body = b"".join(blobs)
        tail = HDR_SIZE + len(body)
        cap = MIN_CAPACITY
        while cap < tail:
            cap *= 2
        if pool.exists(name):
            pool.delete(name)
        region = pool.create(name, cap)
        epoch = int.from_bytes(os.urandom(8), "little")
        hdr = _HDR.pack(_MAGIC, _VERSION, HDR_SIZE, epoch)
        region.write(0, np.frombuffer(hdr.ljust(HDR_SIZE, b"\x00"),
                                      dtype=np.uint8))
        if body:
            region.write(HDR_SIZE, np.frombuffer(body, dtype=np.uint8))
        region.flush()
        # commit: advance the tail only after the entry bytes are durable
        region.write(_TAIL_OFF, _u64le(tail))
        region.flush()
        return epoch, tail

    def _append_pool(self, nid: str, blob: bytes) -> None:
        pool = self._pool(nid)
        epoch, tail = self._cursors[nid]
        new_tail = tail + len(blob)
        region = pool.open(self.name)
        if new_tail > region.nbytes:
            cap = max(region.nbytes, MIN_CAPACITY)
            while cap < new_tail:
                cap *= 2
            region = pool.extend(self.name, cap)
        # B-APM append discipline: entry bytes -> flush -> tail -> flush.
        # Torn writes land past the committed tail and never replay.
        region.write(tail, np.frombuffer(blob, dtype=np.uint8))
        region.flush()
        region.write(_TAIL_OFF, _u64le(new_tail))
        region.flush()
        self._cursors[nid] = (epoch, new_tail)

    def _snapshot_blob(self) -> bytes:
        payload = json.dumps({"state": self._state, "upto": self._applied},
                             separators=(",", ":")).encode()
        return _pack_entry(self._applied, KIND_SNAPSHOT, payload)

    def _reseed(self, nid: str) -> None:
        """Bring a behind/rejoined pool up to date: rewrite its log copy
        as one snapshot of the current state (everything it missed,
        folded). Atomic swap via the compaction rename path."""
        self._ensure_open()
        tmp = self.name + ".reseed"
        epoch, tail = self._write_fresh(nid, tmp, [self._snapshot_blob()])
        self._pool(nid).rename(tmp, self.name)
        self._cursors[nid] = (epoch, tail)
        self._synced.add(nid)
        self.stats["reseeds"] += 1

    # ---- replay -------------------------------------------------------
    def _scan_pool(self, nid: str, skip_snap_upto: int = -1
                   ) -> Tuple[List[Tuple[int, int, Optional[dict]]],
                              Optional[int], int]:
        """All committed entries of one pool copy + (epoch, tail).
        ``epoch is None`` means the pool has no log file at all."""
        pool = self._pool(nid)
        if not pool.exists(self.name):
            return [], None, 0
        region = pool.open(self.name)
        tail, epoch = self._read_header(region)
        entries, nread = self._read_entries(region, HDR_SIZE, tail,
                                            skip_snap_upto)
        self.stats["replay_bytes"] += HDR_SIZE + nread
        return entries, epoch, tail

    def _cold_read(self) -> None:
        """Replay from pool copies: newest snapshot (else legacy base),
        then the seq-union of newer events in order. Copies are scanned
        longest-first so shorter replicas' identical snapshots are
        skipped by header alone."""
        t0 = time.time()
        self.stats["replay_bytes"] = 0
        best_snap: Optional[dict] = None
        events: Dict[int, dict] = {}
        per_pool: Dict[str, Tuple[int, List[int]]] = {}

        def tail_of(nid: str) -> int:
            try:
                pool = self._pool(nid)
                if not pool.exists(self.name):
                    return -1
                return self._read_header(pool.open(self.name))[0]
            except (IOError, OSError):
                return -1

        for nid in sorted(self.nodes, key=tail_of, reverse=True):
            seen = best_snap["upto"] if best_snap is not None else -1
            try:
                entries, epoch, tail = self._scan_pool(nid, seen)
            except (IOError, OSError):
                continue
            if epoch is None:
                continue  # no file yet: reseeded before its first append
            self._cursors[nid] = (epoch, tail)
            snap_upto, seqs = 0, []
            for seq, kind, payload in entries:
                if kind == KIND_SNAPSHOT:
                    upto = seq if payload is None \
                        else payload.get("upto", 0)
                    snap_upto = max(snap_upto, upto)
                    if payload is not None and (
                            best_snap is None
                            or upto > best_snap["upto"]):
                        best_snap = payload
                else:
                    seqs.append(seq)
                    events.setdefault(seq, payload)
            per_pool[nid] = (snap_upto, seqs)
        if best_snap is not None:
            state = copy.deepcopy(best_snap["state"])
            applied = best_snap["upto"]
        else:
            state = copy.deepcopy(self._base()) if self._base else {}
            applied = 0
        for seq in sorted(events):
            if seq <= applied:
                continue
            self._fold(state, events[seq])
            applied = seq
        snap_floor = best_snap["upto"] if best_snap is not None else 0
        self._state = state
        self._applied = applied
        self._next_seq = applied + 1
        self._entries_since_snap = sum(1 for s in events if s > snap_floor)
        # a pool is synced iff its own copy covers every applied seq
        # contiguously from its snapshot — anything less must be
        # reseeded before the next append lands on it
        self._synced = set()
        for nid, (snap_upto, seqs) in per_pool.items():
            covered = snap_upto
            for seq in sorted(set(seqs)):
                if seq == covered + 1:
                    covered = seq
                elif seq > covered + 1:
                    break
            if covered == applied:
                self._synced.add(nid)
        self._t_replay.observe(time.time() - t0)

    def _ensure_open(self) -> None:
        if self._state is None:
            self._cold_read()

    def _sync_foreign(self) -> None:
        """Absorb entries appended by another process since our cursors
        (per-pool cursor reads — only NEW tail bytes are parsed)."""
        for nid in self._live():
            cur = self._cursors.get(nid)
            try:
                pool = self._pool(nid)
                if not pool.exists(self.name):
                    continue
                region = pool.open(self.name)
                tail, epoch = self._read_header(region)
                if cur is not None and epoch == cur[0]:
                    if tail <= cur[1]:
                        continue
                    fresh, _n = self._read_entries(region, cur[1], tail,
                                                   self._applied)
                else:
                    # epoch changed (foreign compaction/reseed replaced
                    # the file): re-read this copy wholesale
                    fresh, _n = self._read_entries(region, HDR_SIZE,
                                                   tail, self._applied)
            except (IOError, OSError):
                continue
            for seq, kind, payload in fresh:
                if kind == KIND_SNAPSHOT:
                    if payload is not None and \
                            payload.get("upto", 0) > self._applied:
                        self._state = copy.deepcopy(payload["state"])
                        self._applied = payload["upto"]
                elif seq > self._applied:
                    self._fold(self._state, payload)
                    self._applied = seq
            self._cursors[nid] = (epoch, tail)
            self._next_seq = max(self._next_seq, self._applied + 1)

    # ---- public API ---------------------------------------------------
    def state(self) -> dict:
        """The folded head state (callers treat it as read-only)."""
        with self._lock:
            self._ensure_open()
            return self._state

    def append(self, event: dict) -> int:
        """Durably append one event to every live pool copy and fold it
        into the head state. Returns the entry's seq. Raises IOError
        when no pool accepted the entry (nothing was persisted)."""
        t0 = time.time()
        with self._lock:
            self._ensure_open()
            self._sync_foreign()
            if "ts" not in event:
                event = {**event, "ts": time.time()}
            seq = self._next_seq
            blob = _pack_entry(seq, KIND_EVENT, json.dumps(
                event, separators=(",", ":")).encode())
            wrote = 0
            live = self._live()
            for nid in self.nodes:
                if nid not in live:
                    # a dead pool misses this entry: it must be reseeded
                    # (snapshot of the full state) if it ever rejoins
                    self._synced.discard(nid)
            for nid in live:
                try:
                    if nid not in self._synced:
                        self._reseed(nid)
                    self._append_pool(nid, blob)
                    wrote += 1
                except (IOError, OSError, AttributeError):
                    self._synced.discard(nid)
            if not wrote:
                raise IOError(f"no reachable pool for meta log "
                              f"{self.name}")
            self._next_seq = seq + 1
            self._fold(self._state, event)
            self._applied = seq
            self._entries_since_snap += 1
            self.stats["appends"] += 1
            if self._entries_since_snap >= self.compact_entries or \
                    self._tail_bytes() >= self.compact_bytes:
                self.compact()
            self._t_append.observe(time.time() - t0)
            return seq

    def _tail_bytes(self) -> int:
        return max((t for _e, t in self._cursors.values()), default=0)

    def compact(self, *, _crash_after_snapshot: bool = False) -> None:
        """Fold the whole prefix into one snapshot entry. Two phases:

        1. the snapshot file is written + flushed on every live pool
           (the durable ack — at this point the folded state survives
           any crash alongside the still-intact log);
        2. the snapshot file is atomically renamed over the live log on
           each pool (the prefix trim).

        ``_crash_after_snapshot`` stops between the phases (tests only:
        simulates the worst-case crash window)."""
        t0 = time.time()
        with self._lock:
            self._ensure_open()
            blob = self._snapshot_blob()
            tmp = self.name + ".cnew"
            seeded: Dict[str, Tuple[int, int]] = {}
            live = self._live()
            for nid in self.nodes:
                if nid not in live:
                    self._synced.discard(nid)
            for nid in live:
                try:
                    seeded[nid] = self._write_fresh(nid, tmp, [blob])
                except (IOError, OSError):
                    continue
            if not seeded:
                raise IOError(f"no reachable pool to compact "
                              f"{self.name}")
            self.stats["snapshot_bytes"] = HDR_SIZE + len(blob)
            if _crash_after_snapshot:
                return
            for nid, cursor in seeded.items():
                try:
                    self._pool(nid).rename(tmp, self.name)
                except (IOError, OSError):
                    self._synced.discard(nid)
                    continue
                self._cursors[nid] = cursor
                self._synced.add(nid)
            self._entries_since_snap = 0
            self.stats["compactions"] += 1
            self._t_compact.observe(time.time() - t0)

    def replay(self) -> dict:
        """A FRESH deterministic replay from the pool copies (ignoring
        the in-memory head state) — the recovery-scan path. Returns the
        replayed state; ``stats['replay_bytes']`` records the bytes
        read (the bench asserts compaction keeps this bounded)."""
        other = MetaLog(self.stores, self.nodes, self.name,
                        fold=self._fold, base=self._base, obs=self._obs)
        replayed = other.state()
        with self._lock:
            # stats writes elsewhere hold the append lock; a replay
            # racing a foreground append must not tear the dict
            self.stats["replay_bytes"] = other.stats["replay_bytes"]
        return replayed
