"""Workflow scheduling over the Persistent Dataset Exchange (§V-A, §VI).

A workflow is a DAG of jobs, executed through the paper's Fig. 8
sequence: allocate nodes -> stage inputs into node pmem (burst buffer)
-> launch -> leave retained outputs in pmem for dependent jobs (in-situ
sharing, no external round-trip) -> drain final outputs -> reclaim.
This scheduler runs that sequence CONCURRENTLY and RECOVERABLY:

  * every ready job dispatches onto a ``DataScheduler`` worker the
    moment its inputs are staged — independent branches of the DAG (and
    independent workflows, each under its own namespace) genuinely
    overlap instead of the old ``ready[0]`` serial walk;
  * placement is data-affine BY BYTES: a job lands on the node holding
    the largest share of its input bytes (catalog manifests for
    datasets, store manifests for raw objects), tie-broken toward the
    least-loaded node so input-free jobs spread out;
  * all intermediates go through the ``DatasetCatalog``: versioned,
    lineage-stamped, replica-acked, lease-protected. ``cleanup`` is the
    catalog's refcount/lease GC, not a blanket scrub;
  * progress persists in a **workflow journal**
    (``wf/<id>/journal.log``, an append-only ``MetaLog`` replicated to
    every live pool). After a node loss, ``resume`` replays ONLY
    the jobs whose retained outputs the catalog's replica acks mark
    unrecoverable — completed jobs with surviving bytes (home or acked
    replica) are never re-invoked, and the decision reads zero objects,
    mirroring ``restore_latest_recoverable``. Resume also restores the
    replication factor first (``TieredIO.repair``): surviving datasets
    down to a single copy regain an acked buddy, so a SECOND loss
    still resumes without replays;
  * final-output drains are joined at the end of ``run``: a failed
    drain fails the workflow (``SupersededError`` stays benign).

Journal format (``wf/<id>/journal.log`` — entry-per-event, appended):

  {"op": "begin",  "workflow": id, "ts": ...}      run/resume started
  {"op": "job",    "name": job, "entry": {...}, "ts": ...}
                                                   one job's terminal
                                                   state (appended at
                                                   completion/failure —
                                                   never rewrites the
                                                   other entries)
  {"op": "status", "status": done|failed, "ts": ...}

Job entries carry what the old whole-journal rewrite recorded per job:
``{"status": "done", "nodes": [...], "outputs": {name: version},
"retained": [names], "drain": [names], "ts": ...}`` (or ``{"status":
"failed", "error": ...}``). ``journal(wf)`` replays the log into the
same merged dict shape as before — ``{"workflow", "ts", "status",
"jobs": {job: entry}}`` — with the latest entry per job winning (log
order replaces the old per-``ts`` cross-pool merge); a legacy
``wf/<id>/journal.json`` from a pre-log run is read as the replay
base. A resume appends a fresh ``begin`` and new ``job`` events; prior
entries stay in the log — harmless, since replay decisions re-check
recoverability against the catalog acks, never trust the journal alone.
"""
from __future__ import annotations

import copy
import itertools
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.annotations import metadata_only
from repro.core.data_scheduler import (DataScheduler, ExternalStore,
                                       SupersededError)
from repro.core.dataset_exchange import (DatasetCatalog, EXTERNAL_INPUT,
                                         Lease, live_pools,
                                         read_json_copies)
from repro.core.meta_log import MetaLog
from repro.core.object_store import DistributedStore, PMemObjectStore

#: default lease TTL for a job's hold on its inputs while it runs
JOB_LEASE_TTL_S = 600.0


def _fold_journal(state: dict, ev: dict) -> None:
    """MetaLog reducer for workflow journals — rebuilds the merged
    journal dict (``{"workflow", "ts", "status", "jobs"}``); the latest
    ``job`` entry per job name wins (log order)."""
    op = ev["op"]
    if op == "begin":
        state["workflow"] = ev["workflow"]
        state["status"] = "running"
        state.setdefault("jobs", {})
    elif op == "status":
        state["status"] = ev["status"]
    elif op == "job":
        state.setdefault("jobs", {})[ev["name"]] = ev["entry"]
    state["ts"] = ev["ts"]


@dataclass
class JobSpec:
    name: str
    fn: Callable[["JobContext"], Dict[str, Any]]
    inputs: Tuple[str, ...] = ()        # dataset names (from deps or external)
    after: Tuple[str, ...] = ()         # job-name dependencies
    retain: Tuple[str, ...] = ()        # outputs kept in pmem for deps
    drain: Tuple[str, ...] = ()         # outputs drained to external at end
    n_nodes: int = 1
    memory_mode: str = "slm"            # slm | dlm (paper §V-A item 9)


@dataclass
class JobContext:
    job: JobSpec
    nodes: List[str]
    stores: Dict[str, PMemObjectStore]
    view: DistributedStore
    workflow: str = "default"
    catalog: Optional[DatasetCatalog] = None
    external: Optional[ExternalStore] = None

    def read(self, name: str, workflow: Optional[str] = None):
        """Resolve an input: catalog dataset (this workflow's namespace,
        or an explicit cross-workflow import), then raw pmem object
        (staged external input / pre-placed data)."""
        wf = workflow or self.workflow
        if self.catalog is not None and self.catalog.available(name, wf):
            try:
                return self.catalog.get(name, wf)
            except KeyError:
                pass  # reclaimed under us — fall back to raw pmem
        return self.view.get(name, prefer=self.nodes[0])


class WorkflowResult(dict):
    """``run``'s return value: job name -> outputs dict, plus the
    workflow id and (after ``resume``) the skipped/replayed split."""

    def __init__(self, workflow_id: str):
        super().__init__()
        self.workflow_id = workflow_id
        self.skipped: List[str] = []    # done jobs NOT re-invoked
        self.replayed: List[str] = []   # jobs re-run because outputs lost
        self.repair_report: dict = {}   # resume's TieredIO.repair report


class WorkflowScheduler:
    def __init__(self, stores: Dict[str, PMemObjectStore],
                 scheduler: DataScheduler, external: ExternalStore,
                 tiered=None, catalog: Optional[DatasetCatalog] = None,
                 obs=None):
        self.stores = stores
        self.obs = obs
        self.nodes = sorted(stores)
        self.dsched = scheduler
        self.external = external
        self.tiered = tiered
        self.catalog = catalog if catalog is not None \
            else DatasetCatalog(stores)
        self.view = DistributedStore(stores)
        self.events: List[Tuple[float, str, str]] = []  # (ts, kind, detail)
        self._ev_lock = threading.Lock()
        self._lock = threading.Lock()
        self._wf_seq = itertools.count()
        self._node_load: Dict[str, int] = {n: 0 for n in self.nodes}
        self._staged: Set[Tuple[str, str]] = set()   # (node, object name)
        self._workflows: Set[str] = set()            # namespaces run here
        self._jlogs: Dict[str, MetaLog] = {}         # wf -> journal log
        self._jlog_lock = threading.RLock()

    def _log(self, kind: str, detail: str) -> None:
        with self._ev_lock:
            self.events.append((time.time(), kind, detail))
        if self.obs is not None:
            # mirror the in-DRAM event feed onto the flight recorder so
            # a post-crash replay sees the workflow lifecycle too
            self.obs.event(f"wf.{kind}", detail=detail)

    # ---- journal (append-only MetaLog, replicated) -------------------
    @staticmethod
    def _journal_name(wf: str) -> str:
        """Legacy pre-log journal object (replay base only)."""
        return f"wf/{wf}/journal.json"

    def _live(self) -> List[str]:
        return live_pools(self.stores, self.nodes)

    @metadata_only
    def _legacy_journal(self, wf: str) -> dict:
        """Merged pre-log ``journal.json`` copies (the old read path) —
        the replay base for workflows begun before the MetaLog port."""
        try:
            copies = read_json_copies(self.stores, self.nodes,
                                      self._journal_name(wf))
        except (IOError, FileNotFoundError):
            return {}
        best = dict(max(copies, key=lambda c: c.get("ts", 0)))
        jobs: Dict[str, dict] = {}
        for c in copies:
            for jname, e in (c.get("jobs") or {}).items():
                if jname not in jobs or \
                        e.get("ts", 0) > jobs[jname].get("ts", 0):
                    jobs[jname] = e
        best["jobs"] = jobs
        return best

    def _jlog(self, wf: str) -> MetaLog:
        with self._jlog_lock:
            log = self._jlogs.get(wf)
            if log is None:
                log = MetaLog(self.stores, self.nodes,
                              f"wf/{wf}/journal.log", fold=_fold_journal,
                              base=lambda: self._legacy_journal(wf),
                              obs=self.obs)
                self._jlogs[wf] = log
            return log

    def _journal_append(self, wf: str, ev: dict) -> None:
        with self._jlog_lock:
            self._jlog(wf).append(ev)

    @metadata_only
    def journal(self, wf: str) -> dict:
        """The workflow journal folded from its replicated MetaLog:
        per-job entries in log order (latest event per job wins), the
        merged legacy ``journal.json`` as replay base for pre-log runs.
        Raises ``FileNotFoundError`` if no journal exists anywhere."""
        state = self._jlog(wf).state()
        if not state.get("workflow") and not state.get("jobs"):
            raise FileNotFoundError(self._journal_name(wf))
        return copy.deepcopy(state)

    # ---- placement: byte-weighted data affinity ----------------------
    def _place(self, job: JobSpec, wf: str) -> List[str]:
        """Nodes holding the largest share of the job's input BYTES
        (dataset sizes from catalog records, raw objects from store
        manifests — not input count), tie-broken toward the node with
        the fewest jobs in flight so input-free jobs spread out."""
        live = self._live()
        score: Dict[str, int] = {n: 0 for n in live}
        for obj in job.inputs:
            try:
                rec = self.catalog.record(obj, wf)
            except (KeyError, IOError, FileNotFoundError):
                rec = None
            if rec is not None and not rec.get("reclaimed"):
                nb = max(int(rec.get("nbytes", 0)), 1)
                home = rec.get("home")
                target = (rec.get("acks") or {}) \
                    .get("replica", {}).get("target")
                if home in score:
                    score[home] += nb
                elif target in score:  # home died: affinity follows replica
                    score[target] += nb
                continue
            for nid in self.view.locate(obj):
                if nid in score:
                    try:
                        score[nid] += max(
                            self.stores[nid].nbytes_of(obj), 1)
                    except (IOError, FileNotFoundError):
                        score[nid] += 1
        with self._lock:
            load = dict(self._node_load)
        ranked = sorted(live,
                        key=lambda n: (-score[n], load.get(n, 0), n))
        return ranked[:job.n_nodes]

    # ---- stage-in through TieredIO -----------------------------------
    def _stage_inputs(self, job: JobSpec, nodes: List[str],
                      wf: str) -> List:
        futs: List = []
        warm: List[str] = []
        for obj in job.inputs:
            if self.catalog.available(obj, wf):
                try:
                    producer = self.catalog.record(obj, wf)["lineage"]["job"]
                except (KeyError, IOError, FileNotFoundError):
                    producer = None
                self._log("in_situ", f"{wf}:{obj} in catalog "
                          f"(produced by {producer})")
                warm.append(obj)
                continue
            if self.view.locate(obj):
                self._log("in_situ", f"{obj} already in pmem")
                continue
            if not self.external.exists(obj):
                raise KeyError(f"input {obj} nowhere to be found")
            if self.tiered is not None:
                futs.extend(self.tiered.stage_in(nodes[0], [obj],
                                                 prefix=""))
            else:
                futs.append(self.dsched.stage_in(nodes[0], obj, obj))
            self._staged.add((nodes[0], obj))
            self._log("stage_in", f"{obj} -> {nodes[0]}")
        if warm and job.memory_mode == "dlm" and self.tiered is not None \
                and self.tiered.catalog is self.catalog:
            # DLM-mode job: warm the DRAM cache with its catalog inputs
            # so the first read hits DRAM, not pmem
            futs.append(self.tiered.prefetch_datasets(warm, wf))
            self._log("prefetch", f"{wf}:{','.join(warm)} -> dlm cache")
        return futs

    # ---- job body (runs on a DataScheduler worker) -------------------
    def _make_task(self, job: JobSpec, nodes: List[str], wf: str,
                   lineage: List[List], trace: int = 0):
        obs = self.obs

        def task():
            sp = None
            if obs is not None and trace:
                sp = obs.begin("wf.job", node=nodes[0], trace=trace,
                               job=job.name, workflow=wf)
            ctx = JobContext(job, nodes, self.stores, self.view,
                             workflow=wf, catalog=self.catalog,
                             external=self.external)
            try:
                outputs = job.fn(ctx) or {}
            except Exception:
                if obs is not None:
                    obs.end(sp, status="error")
                raise
            versions: Dict[str, int] = {}
            # outputs spread across the job's nodes; every one becomes a
            # catalog dataset (versioned + lineage-stamped + replicated)
            for i, (name, tree) in enumerate(sorted(outputs.items())):
                node = nodes[i % len(nodes)]
                retained = name in job.retain or name in job.drain
                rec = self.catalog.publish(
                    name, tree, workflow=wf, producer=job.name,
                    inputs=lineage, node=node, retained=retained)
                versions[name] = rec["version"]
                if name in job.retain:
                    self._log("retain", f"{wf}:{name}@v{rec['version']} "
                              f"on {rec['home']}")
            if obs is not None:
                obs.end(sp, outputs=len(outputs))
            return outputs, versions
        return task

    def _lineage_refs(self, job: JobSpec, wf: str,
                      leases: List[Lease]) -> List[List]:
        refs = [[l.name, l.workflow, l.version] for l in leases]
        leased = {l.name for l in leases}
        refs += [[EXTERNAL_INPUT, obj, 0] for obj in job.inputs
                 if obj not in leased]
        return refs

    # ---- Fig. 8 lifecycle, concurrent -------------------------------
    def run(self, jobs: Sequence[JobSpec], *,
            workflow: Optional[str] = None,
            max_concurrent: Optional[int] = None,
            _pre_done: Optional[Dict[str, dict]] = None) -> WorkflowResult:
        """Execute the DAG: every job whose dependencies are done (and
        inputs staged) dispatches onto a DataScheduler worker; jobs on
        different nodes run concurrently. ``max_concurrent=1`` recovers
        the old serial walk (bench_workflow.py measures the gap).
        Multiple ``run`` calls may execute concurrently — each workflow
        is namespaced and journaled independently."""
        wf = workflow if workflow is not None \
            else f"wf{next(self._wf_seq)}"
        wf_trace = 0
        if self.obs is not None:
            from repro.obs.trace import new_id
            wf_trace = new_id()  # one trace id spans the whole DAG
        with self._lock:
            self._workflows.add(wf)
        by_name = {j.name: j for j in jobs}
        if len(by_name) != len(jobs):
            raise ValueError("duplicate job names in workflow")
        result = WorkflowResult(wf)
        journal = {"workflow": wf, "status": "running", "jobs": {}}
        self._journal_append(wf, {"op": "begin", "workflow": wf})
        for jname, entry in (_pre_done or {}).items():
            journal["jobs"][jname] = entry
            result[jname] = {}  # outputs live in the catalog, not DRAM
            result.skipped.append(jname)
            self._journal_append(wf, {"op": "job", "name": jname,
                                      "entry": entry})

        cap = max_concurrent if max_concurrent else len(self.nodes)
        pending = [j for j in jobs if j.name not in journal["jobs"]]
        staging: Dict[str, Tuple[JobSpec, List[str], List]] = {}
        inflight: Dict[str, Tuple[Any, JobSpec, List[str],
                                  List[Lease]]] = {}
        drains: List[Tuple[str, Any]] = []
        done: Set[str] = set(journal["jobs"])

        def fail(jname: str, exc: Exception):
            journal["status"] = "failed"
            entry = {"status": "failed", "error": str(exc),
                     "ts": time.time()}
            journal.setdefault("jobs", {})[jname] = entry
            self._journal_append(wf, {"op": "job", "name": jname,
                                      "entry": entry})
            self._journal_append(wf, {"op": "status", "status": "failed"})
            # join the rest so no worker is left mutating state after
            # the caller sees the failure
            for name, (fut, _j, nodes, leases) in inflight.items():
                try:
                    fut.result(timeout=60)
                except Exception:  # noqa: BLE001 — first error wins
                    pass
                self._release(nodes, leases)
            # jobs still staging hold node_load (taken at allocate) but
            # no leases yet; their stage futures are joined so nothing
            # keeps writing pmem after the caller sees the failure
            for _j, nodes, futs in staging.values():
                for f in futs:
                    try:
                        f.result(timeout=60)
                    except Exception:  # noqa: BLE001
                        pass
                self._release(nodes, [])
            raise RuntimeError(
                f"workflow {wf}: job {jname} failed") from exc

        while pending or staging or inflight:
            progressed = False
            # (2-3) allocate + stage inputs for every ready job
            for job in list(pending):
                if len(staging) + len(inflight) >= cap:
                    break
                if not all(a in done for a in job.after):
                    continue
                pending.remove(job)
                nodes = self._place(job, wf)
                with self._lock:
                    self._node_load[nodes[0]] = \
                        self._node_load.get(nodes[0], 0) + 1
                self._log("allocate", f"{wf}:{job.name} -> {nodes} "
                          f"mode={job.memory_mode}")
                try:
                    stage_futs = self._stage_inputs(job, nodes, wf)
                except Exception as e:  # noqa: BLE001 — input missing
                    self._release(nodes, [])
                    fail(job.name, e)
                staging[job.name] = (job, nodes, stage_futs)
                progressed = True
            # (4-7) launch jobs whose stage-in finished
            for name in list(staging):
                job, nodes, futs = staging[name]
                if not all(f.done() for f in futs):
                    continue
                del staging[name]
                stage_err = None
                for f in futs:
                    try:
                        f.result()
                    except Exception as e:  # noqa: BLE001
                        stage_err = e
                if stage_err is not None:
                    self._release(nodes, [])  # allocate's load increment
                    fail(name, stage_err)
                # lease every catalog input for the job's duration: GC
                # cannot reclaim them mid-run, eviction keeps them warm
                leases = []
                for obj in job.inputs:
                    if self.catalog.available(obj, wf):
                        try:
                            leases.append(self.catalog.acquire(
                                obj, workflow=wf,
                                owner=f"{wf}/{job.name}",
                                ttl_s=JOB_LEASE_TTL_S))
                        except KeyError:
                            pass  # reclaimed between check and acquire:
                            # the job's read falls back like _stage_inputs
                task = self._make_task(
                    job, nodes, wf, self._lineage_refs(job, wf, leases),
                    trace=wf_trace)
                self._log("launch", f"{wf}:{job.name}")
                inflight[name] = (self.dsched.run_job(nodes[0], task),
                                  job, nodes, leases)
                progressed = True
            # (8) reap completions: journal, drains, lease release
            for name in list(inflight):
                fut, job, nodes, leases = inflight[name]
                if not fut.done():
                    continue
                del inflight[name]
                self._release(nodes, leases)
                if fut.exception() is not None:
                    fail(name, fut.exception())
                outputs, versions = fut.result()
                result[name] = outputs
                done.add(name)
                entry = {
                    "status": "done", "nodes": nodes,
                    "outputs": versions,
                    "retained": sorted(job.retain),
                    "drain": sorted(job.drain), "ts": time.time()}
                journal["jobs"][name] = entry
                self._journal_append(wf, {"op": "job", "name": name,
                                          "entry": entry})
                for oname in job.drain:
                    try:
                        rec = self.catalog.record(oname, wf,
                                                  versions.get(oname))
                    except (KeyError, IOError, FileNotFoundError) as e:
                        fail(name, e)
                    drains.append((oname, self.dsched.drain(
                        rec["home"], rec["object"], oname,
                        version=rec["version"])))
                    self._log("drain",
                              f"{wf}:{oname} {rec['home']} -> external")
                progressed = True
            if not progressed:
                if not staging and not inflight:
                    raise RuntimeError("workflow deadlock (cyclic or "
                                       "missing deps?)")
                time.sleep(0.002)
        # join final-output drains: a failed drain fails the workflow
        # instead of vanishing (SupersededError stays benign — the
        # newer version's own drain covers it)
        drain_errors: List[Tuple[str, Exception]] = []
        for oname, f in drains:
            try:
                f.result()
            except SupersededError:
                pass
            except Exception as e:  # noqa: BLE001 — re-raised below
                drain_errors.append((oname, e))
        if drain_errors:
            journal["status"] = "failed"
            self._journal_append(wf, {"op": "status", "status": "failed"})
            oname, err = drain_errors[0]
            raise RuntimeError(
                f"workflow {wf}: drain of final output {oname} "
                f"failed") from err
        journal["status"] = "done"
        self._journal_append(wf, {"op": "status", "status": "done"})
        return result

    def _release(self, nodes: List[str], leases: List[Lease]) -> None:
        with self._lock:
            self._node_load[nodes[0]] = \
                max(0, self._node_load.get(nodes[0], 0) - 1)
        for lease in leases:
            self.catalog.release(lease)

    # ---- resume after node loss --------------------------------------
    def resume(self, jobs: Sequence[JobSpec], workflow: str, *,
               lost_nodes: Sequence[str] = (),
               max_concurrent: Optional[int] = None,
               repair: bool = True) -> WorkflowResult:
        """Replay a journaled workflow after a node loss, re-running
        ONLY the jobs whose retained outputs are unrecoverable. The
        decision comes from the catalog's placement + replica acks —
        zero object-store probes: a done job whose outputs all survive
        (home alive, or acked replica on a survivor) is marked done from
        the journal and its function is NEVER re-invoked; consumers read
        the surviving copy (replica fallback) through the catalog.

        With ``repair`` (default) the resume first restores the
        replication factor (``TieredIO.repair``): surviving datasets the
        loss reduced to a single copy regain an acked buddy before the
        replay runs, so a SECOND loss during or after the resumed run is
        still recoverable without replays. The replay decision itself is
        unchanged by repair (both read the same acks); the repair's
        object reads are the copies it makes, never probes. When the
        continuous RepairDaemon is running and its ledger already covers
        ``lost_nodes``, its merged report is used instead of a redundant
        re-scan (the daemon repaired in the background between the loss
        and this resume). Report in ``result.repair_report``."""
        try:
            journal = self.journal(workflow)
        except (IOError, FileNotFoundError):
            journal = {"jobs": {}}
        with self._lock:
            self._workflows.add(workflow)
        repair_report: dict = {}
        if repair and lost_nodes and self.tiered is not None:
            # swallow foreground transfers that died with the node in
            # EITHER branch: a failed future left tracked would fail a
            # later strict join() on a successfully-resumed run
            self.tiered.quiesce()
            daemon = getattr(self.tiered, "repair_daemon", None)
            if daemon is not None and daemon.running:
                daemon.wait_for(lost_nodes, timeout=60.0)
            if daemon is not None and daemon.covers(lost_nodes):
                repair_report = daemon.report()
                self._log("repair",
                          f"{workflow}: daemon ledger covers "
                          f"{sorted(lost_nodes)} "
                          f"({repair_report.get('sweeps', 0)} sweeps) — "
                          f"no re-scan")
            else:
                repair_report = self.tiered.repair(lost_nodes)
                self._log(
                    "repair",
                    f"{workflow}: "
                    f"{len(repair_report.get('repaired', ()))} objects "
                    f"re-replicated after losing {sorted(lost_nodes)}")
        names = {j.name for j in jobs}
        pre_done: Dict[str, dict] = {}
        replayed: List[str] = []
        for jname, entry in journal.get("jobs", {}).items():
            if entry.get("status") != "done" or jname not in names:
                continue
            lost = [o for o in entry.get("retained", ())
                    if not self.catalog.recoverable(
                        o, workflow, entry.get("outputs", {}).get(o),
                        lost_nodes)]
            if lost:
                replayed.append(jname)
                self._log("replay", f"{workflow}:{jname} lost "
                          f"outputs {lost}")
            else:
                pre_done[jname] = entry
                self._log("skip", f"{workflow}:{jname} outputs "
                          f"recoverable (acked)")
        result = self.run(jobs, workflow=workflow,
                          max_concurrent=max_concurrent,
                          _pre_done=pre_done)
        # replayed = previously-done jobs re-run because outputs were
        # lost; jobs the journal never recorded as done (new, or failed
        # mid-run) ran too, but they are not loss-driven replays
        result.replayed = sorted(replayed)
        result.repair_report = repair_report
        return result

    # ---- lifecycle ---------------------------------------------------
    def cleanup(self, keep: Sequence[str] = ()) -> None:
        """Post-workflow reclaim (paper §V items 6/10) via the catalog's
        lease/refcount GC — NOT a blanket scrub: datasets named in
        ``keep`` stay retained, everything else this scheduler published
        is unretained and reclaimed only at refcount zero (an active
        lease from another consumer defers reclaim to its expiry).
        Staged external input copies are scrubbed too."""
        with self._lock:
            mine = set(self._workflows)
        for rec in self.catalog.records():
            if rec.get("reclaimed") or rec["workflow"] not in mine:
                continue
            if rec["name"] in keep:
                continue
            self.catalog.unretain(rec["name"], rec["workflow"],
                                  rec["version"])
        for wf, name, version in self.catalog.gc():
            self._log("cleanup", f"{wf}:{name}@v{version} reclaimed")
        for nid, name in sorted(self._staged):
            if name in keep:
                continue
            try:
                if self.stores[nid].exists(name):
                    self.stores[nid].delete(name)
                    self._log("cleanup", f"{name} on {nid}")
            except IOError:
                continue
        self._staged = {(n, o) for n, o in self._staged if o in keep}
