"""Workflow-aware job scheduling with pmem data retention (paper §V-A, §VI).

A workflow is a DAG of jobs. The scheduler implements the paper's Fig. 8
sequence: allocate nodes -> set memory mode -> stage inputs into node pmem
(burst buffer) -> launch -> leave retained outputs in pmem for dependent
jobs (in-situ sharing, no external round-trip) -> drain final outputs ->
clean up pmem (data security: nothing survives unless retained).

Placement is data-affine: a job preferentially lands on nodes already
holding the largest share of its inputs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.data_scheduler import DataScheduler, ExternalStore
from repro.core.object_store import DistributedStore, PMemObjectStore


@dataclass
class JobSpec:
    name: str
    fn: Callable[["JobContext"], Dict[str, Any]]
    inputs: Tuple[str, ...] = ()        # object names (from deps or external)
    after: Tuple[str, ...] = ()         # job-name dependencies
    retain: Tuple[str, ...] = ()        # outputs kept in pmem for deps
    drain: Tuple[str, ...] = ()         # outputs drained to external at end
    n_nodes: int = 1
    memory_mode: str = "slm"            # slm | dlm (paper §V-A item 9)


@dataclass
class JobContext:
    job: JobSpec
    nodes: List[str]
    stores: Dict[str, PMemObjectStore]
    view: DistributedStore

    def read(self, name: str):
        return self.view.get(name, prefer=self.nodes[0])


class WorkflowScheduler:
    def __init__(self, stores: Dict[str, PMemObjectStore],
                 scheduler: DataScheduler, external: ExternalStore):
        self.stores = stores
        self.nodes = sorted(stores)
        self.dsched = scheduler
        self.external = external
        self.view = DistributedStore(stores)
        self.events: List[Tuple[float, str, str]] = []  # (ts, kind, detail)
        self._retained: Dict[str, str] = {}  # object -> producing job

    def _log(self, kind: str, detail: str) -> None:
        self.events.append((time.time(), kind, detail))

    # ---- placement: data affinity ----
    def _place(self, job: JobSpec) -> List[str]:
        score = {n: 0 for n in self.nodes}
        for obj in job.inputs:
            for n in self.view.locate(obj):
                score[n] += 1
        ranked = sorted(self.nodes, key=lambda n: -score[n])
        return ranked[:job.n_nodes]

    # ---- Fig. 8 lifecycle ----
    def run(self, jobs: Sequence[JobSpec]) -> Dict[str, Dict[str, Any]]:
        by_name = {j.name: j for j in jobs}
        done: Dict[str, Dict[str, Any]] = {}
        pending = list(jobs)
        while pending:
            ready = [j for j in pending if all(a in done for a in j.after)]
            if not ready:
                raise RuntimeError("workflow deadlock (cyclic deps?)")
            job = ready[0]
            pending.remove(job)
            nodes = self._place(job)                       # (2) allocate
            self._log("allocate", f"{job.name} -> {nodes} "
                      f"mode={job.memory_mode}")
            # (3) stage-in: burst-buffer any inputs not already in pmem
            futs = []
            for obj in job.inputs:
                if not self.view.locate(obj):
                    if not self.external.exists(obj):
                        raise KeyError(f"input {obj} nowhere to be found")
                    futs.append(self.dsched.stage_in(nodes[0], obj, obj))
                    self._log("stage_in", f"{obj} -> {nodes[0]}")
                else:
                    self._log("in_situ", f"{obj} already in pmem "
                              f"(retained by {self._retained.get(obj)})")
            for f in futs:
                f.result()
            # (4-7) run the job
            ctx = JobContext(job, nodes, self.stores, self.view)
            self._log("launch", job.name)
            outputs = job.fn(ctx) or {}
            done[job.name] = outputs
            # retained outputs stay in pmem (spread across the job's nodes)
            for i, (name, tree) in enumerate(sorted(outputs.items())):
                node = nodes[i % len(nodes)]
                self.stores[node].put(name, tree)
                if name in job.retain:
                    self._retained[name] = job.name
                    self._log("retain", f"{name} on {node}")
            # (8) drain requested outputs to the external store (async)
            for name in job.drain:
                src = self.view.locate(name)[0]
                self.dsched.drain(src, name, name)
                self._log("drain", f"{name} {src} -> external")
        return done

    def cleanup(self, keep: Sequence[str] = ()) -> None:
        """Post-workflow pmem scrub (paper §V items 6/10)."""
        for nid, st in self.stores.items():
            for name, v in st.list_objects():
                if name not in keep:
                    st.delete(name, v)
                    self._log("cleanup", f"{name} on {nid}")
