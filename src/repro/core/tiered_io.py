"""TieredIO: one nonblocking engine over the B-APM memory hierarchy.

The paper's architecture (Fig. 4 data scheduler, Fig. 8 burst-buffer
staging) hinges on a single property: the application never blocks on a
tier slower than node-local B-APM. The repo grew three separate paths
with that goal — the shadow-slot checkpoint writer (core/checkpoint.py),
the drain/replicate/stage-in scheduler (core/data_scheduler.py) and the
SLM/DLM placement policies (core/tiering.py). ``TieredIO`` unifies them
behind one engine; the existing modules remain as thin policy layers.

API surface:

  save_async(step, tree)  -> SaveTicket (a Future): checkpoint writes
        happen on a dedicated I/O thread, double-buffered across the
        checkpointer's pmem slots, so the step-N write overlaps step-N+1
        compute. Post-commit drain/replicate futures ride on the ticket.
  offload(name, tree)     -> Future: generic object persist (serve KV /
        session state) through the DLM write-back cache.
  fetch(name) / prefetch(names): demand vs. anticipatory reads through
        the DLM cache — prefetch warms DRAM from pmem in the background
        and feeds the serve engine's cold KV pages.
  stage_in(names)         -> burst-buffer pre-load, external -> pmem,
        delegated to the data scheduler (hit-rate accounted).
  evict_cold(max_idle_s)  -> spill idle DRAM entries back to pmem.
  quiesce()               -> join every in-flight future, collecting
        (not raising) errors — the recovery path consumes in-flight
        work safely even when a buddy node died mid-replicate.

Backpressure: at most ``checkpointer.slots`` save tickets may be in
flight; submitting another blocks until the oldest commits. Combined
with the FIFO I/O thread this guarantees a slot is never overwritten
while a write to it is still in flight.

Replication channel and the ``SaveTicket.durability()`` contract
----------------------------------------------------------------
Checkpoint replicate/drain fan-out is a first-class TieredIO channel
(``ReplicationChannel``), not an inline step of the checkpointer: each
replicate/drain task records a per-node ACK into the manifest's ack map
(replicated to every live pool) the moment its transfer is durable.
``SaveTicket.durability()`` reports the acknowledged durability level:

  "PENDING"     the node-local commit has not finished yet;
  "FAILED"      the commit itself raised (nothing durable);
  "LOCAL"       committed to node-local pmem only — a node loss inside
                this window loses the step (recovery walks back);
  "REPLICATED"  every shard owner has an acknowledged buddy replica —
                any single node loss is recoverable over the fabric;
  "DRAINED"     every shard owner's drain to the external store has
                been acknowledged — survives cluster-wide pmem loss.

Levels are monotonic in that order; DRAINED ranks above REPLICATED even
when replication was disabled (external durability subsumes it). The
levels are derived from the PERSISTED ack map, not in-process futures,
so ``restore_latest_recoverable`` ranks steps by the same records after
a crash: a step whose ack map shows a lost shard owner without a replica
ack is skipped without a single store read.

DLM and dataset acks — the whole data plane, not just checkpoints
----------------------------------------------------------------
The same under-promise discipline covers the other two ack surfaces:

  * **DLM objects** (``offload``, serve KV/session spill): every buddy
    copy of ``dlm/<name>`` is registered through the replication channel
    and acknowledged into the replicated ack log ``dlm/ackslog``
    (``DLMAckRegistry`` — one ``MetaLog`` event per registration; a
    legacy ``dlm/acks.json`` from a pre-log deployment is read as the
    replay base). A dirty DLM write-back (eviction/flush of a mutated
    object) re-queues the buddy copy through the same path, so replicas
    never go stale behind the cache. Replica-fallback reads consult the
    acked targets first.
  * **Datasets** (``DatasetCatalog.publish``): the exchange channel's
    ack is appended to the catalog's record log (``acks.replica`` in
    the folded record).

Every ack records the full ``targets`` list of nodes holding an
acknowledged copy (legacy records carry a single ``target``; readers
treat it as a one-element list). An object is recoverable for a lost
set as long as ANY acked copy survives it.

The metadata log durability contract
------------------------------------
All three ack surfaces (and the catalog records and workflow journals)
persist through ``MetaLog`` (core/meta_log.py) — an append-only,
CRC-guarded record log replicated to every live pool — instead of
rewriting whole JSON blobs per update. The guarantees recovery relies
on:

  * **Committed-tail appends**: an update is one appended entry — entry
    bytes are flushed BEFORE the header's committed tail advances, so a
    torn append is invisible to replay; an ack visible to any reader is
    complete and durable on at least one pool.
  * **Union replay**: recovery replays the newest snapshot plus the
    seq-union of newer entries across all readable copies — an ack that
    landed on any surviving pool is never lost, exactly like the old
    per-pool JSON merge, at O(tail) instead of O(state) read cost.
  * **Acked compaction**: the log folds its prefix into a snapshot only
    after the snapshot file is written + flushed on every live pool;
    the prefix trim is a per-pool atomic rename. A crash anywhere in
    compaction leaves every pool with a log that replays the identical
    state (old log, or snapshot-equivalent new one).
  * **Per-pool cursors + reseed**: the writer tracks (epoch, tail) per
    copy; a pool that missed appends (down, then rejoined) is reseeded
    with a full snapshot before the next entry lands on it, so every
    synced copy is individually sufficient for replay.

The ranking in ``restore_latest_recoverable``, the repair scans and the
workflow resume decisions all read these logs' folded state — still
metadata-only, zero blind object-store probes.

Replica repair — restoring the replication factor after node loss
-----------------------------------------------------------------
Write-time replication alone decays: one node loss silently drops every
object it homed or buddied to a single copy, and a SECOND loss then
destroys data that was "REPLICATED" the whole time. ``RepairChannel``
(``TieredIO.repair(lost_nodes)``) closes that loop. It walks the three
ack surfaces — ``ckpt/acks_step<N>.json``, the catalog records' ``acks``
and ``dlm/acks.json`` — and for every object whose acked copies
intersect ``lost_nodes`` down to a SINGLE survivor, re-replicates the
surviving copy to a fresh live buddy through the data scheduler,
re-acking (with the pruned + extended ``targets`` list) only when the
new copy is durable. The scan is metadata-only: zero blind object-store
probes — the only object reads are the sources of the copies actually
made. Objects that were never acked are not repair's business (nothing
promised), and objects with zero surviving pmem copies are reported
(``unrepairable`` / ``drain_only``) rather than guessed at. A source
overwritten since its ack (checkpoint slot reuse) raises the benign
``SupersededError`` and is skipped. After ``repair``, every previously
acked object again tolerates any single node loss, and recovery after a
SECOND loss still decides from acks alone.

Continuous repair daemon and drain-tier rehydration
---------------------------------------------------
Recovery-point repair still leaves a WINDOW: between a node loss and the
next ``check_and_recover``/``resume``, every object the loss touched
sits on a single pmem copy. ``RepairDaemon`` closes it:

  * **Single-copy window**: the daemon polls ``Heartbeat.dead_nodes``
    every ``poll_s`` and sweeps on each NEW death, so the window shrinks
    from "until the next recovery point" to roughly one poll interval
    plus the (rate-limited) repair makespan
    (``benchmarks/bench_repair_daemon.py`` measures both). Sweeps are
    incremental — an already-handled death never re-triggers — and a
    membership change mid-sweep re-plans the cumulative dead set from
    the acks on the next poll (the persisted ``targets`` lists make
    re-planning idempotent and safe).
  * **Rehydration**: a checkpoint shard whose pmem copies ALL died but
    whose acked external drain survives (``drain_only``) is staged back
    from the external tier into a live pmem pool under its replica
    name, re-replicated to a second live node, and re-acked — restoring
    fast-tier redundancy, not just external survivability. The scan
    stays metadata-only: the ONLY external reads are the rehydration
    sources, and each ack is written only after its copy is durable
    (a crash between the two stages leaves a truthful single-target
    ack the next sweep extends).
  * **Rate limiting**: repair transfers run at a background scheduler
    priority (below stage-in/drain/replicate/compute) and at most
    ``max_inflight`` of them are queued/running at once, so a repair
    storm after a loss never swamps foreground saves or serving I/O
    (the report's ``peak_inflight`` records the high-water mark; the
    bench measures foreground step-time overhead under a storm).
  * **Ledger**: ``covers(lost)`` / ``report()`` let recovery points
    (``FailureRecovery.check_and_recover``,
    ``WorkflowScheduler.resume``, ``ServeEngine.repair``) reuse the
    daemon's already-completed sweeps instead of re-scanning from
    scratch; the daemon never quiesces foreground work, which is safe
    because acks only ever describe already-durable transfers.

Zero-copy byte-range data plane and the wire codec
--------------------------------------------------
Every channel above moves bytes through the object store's raw copy
primitives (``copy_object``/``export_object``/``import_object``) — no
transfer materializes a tree. The durability contract each channel
inherits from them:

  * **Replicate (pmem -> pmem)**: the backing region streams src -> dst
    in bounded chunks, each chunk flushed before the next is written; a
    rolling CRC per physical segment is checked against the SOURCE
    manifest's own leaf CRCs, and that manifest commits on dst verbatim
    (same leaf table, same digests). The commit point is the dst pool's
    atomic manifest rename — a crash at ANY earlier instruction leaves
    data bytes without a manifest, invisible to every reader and to
    recovery. Acks record only after the commit returns, so the ack map
    still under-promises. A source overwritten mid-copy (slot reuse)
    fails the CRC or the manifest snapshot check and raises the benign
    ``SupersededError`` — never a torn replica.
  * **Drain (pmem -> external)**: ``export_object`` reads the region
    once against one manifest snapshot and serializes exactly once, at
    the external-store boundary; stage-in ingests the wire payload with
    ``import_object`` (leaf bytes at manifest offsets, carried manifest
    committed over them) so a rehydrated shard is byte-identical to the
    drained one, CRCs included.
  * **Wire codec (opt-in, ``wire_codec=``)**: the pallas delta-int8
    codec encodes eligible float leaves at the SOURCE of replicate /
    drain / repair transfers; encoded tiles + per-tile scales land on
    the destination with their own CRCs recorded in the manifest's
    ``meta["wire_codec"]`` — the leaf table keeps the ORIGINAL digests,
    so acks, repair scans and ``content_digest`` stay metadata-only and
    encoding-invariant. Readers decode on demand (``get_leaf`` /
    ``read_leaf_slice`` decode just the tiles they touch); strict mode
    (default) snaps scales to powers of two and verifies round-trip
    bit-equality at encode time, falling back to raw per leaf when the
    data won't survive quantization. A second-hop copy of an encoded
    replica raw-streams the encoded segments — never double-encodes.
  * **Byte-range reads**: ``fetch_leaf`` (DLM), ``get_leaf`` and
    ``DistributedCheckpointer.restore_leaves``/``restore_shard`` read
    only the byte range of the leaves they need — sibling leaves are
    never touched, which is what makes N->M warm resize and partial
    KV-page reads O(bytes needed), not O(object).

Telemetry plane — metrics, spans, and the crash-persistent recorder
-------------------------------------------------------------------
Every channel reports into an optional ``TelemetryPlane``
(``repro.obs``), threaded through the ``obs=`` constructor kwarg of
every component (``SimCluster`` wires one automatically; ``obs=None``
degrades every hook to a no-op or a DRAM-only counter update):

  * **Metrics**: channel counters (``tiered.saves`` etc. — the legacy
    ``TieredIO.stats`` dict survives as a registry-backed ``StatsView``
    alias), queue-depth gauges, and bounded histograms for the
    latencies the paper's analysis needs: ``ckpt.save_commit_s`` (the
    node-local commit the trainer blocks on) and
    ``ckpt.submit_to_ack_s`` (submit -> durable ack, per transfer —
    the replication/drain QoS signal).
  * **Trace spans**: ``save_async`` mints one trace id per checkpoint;
    it rides the manifest into the replication channel (per-node
    ``ckpt.replicate``/``ckpt.drain`` child spans), the scheduler's
    task meta (``sched.*`` spans with queue-wait), and the persisted
    ack records (``"trace"`` key) — so one save's
    commit -> replicate -> drain -> ack fan-out reconstructs as a
    single causally-ordered tree, post-hoc, from durable state alone.
    Repair sweeps (``repair.sweep``) and workflow DAGs (``wf.job``)
    mint their own traces the same way. Trace keys are NEVER added to
    ``expect_meta`` (which is equality-compared at the destination).
  * **Flight recorder**: span/point events append to a fixed-size
    per-node pmem ring (``obs/flightring``) under the same
    committed-tail discipline as ``MetaLog`` — slot bytes -> flush ->
    tail -> flush — so a torn final event is invisible to replay and
    everything behind the committed tail survives a crash.
    ``python -m repro.obs.report <pmem-root>`` replays surviving rings
    into the merged timeline; ``analysis/README.md`` documents the
    recording contract and overhead bounds
    (``benchmarks/bench_obs.py`` enforces <5% on the save path).

Serve-tier sessions — leased catalog datasets, not bare keys
------------------------------------------------------------
The multi-tenant serve tier (``serve/sessions.py``) stores every
session's KV/cursor state and every shared prefix cache as a dataset in
the exchange catalog (``sess/<name>`` / ``prefix/<name>``, workflow
``serve``), which makes the session durability contract a corollary of
the dataset one above — no serve-specific machinery:

  * **Spill = publish**: each suspend publishes version N+1 (home
    chosen by stable hash across live pools; lineage = producing engine
    + previous version + forked prefix; content digest; buddy replica
    acked into the record). A session is loss-of-one-node durable
    exactly when its ack lands (``serve.spill_to_ack_s`` measures the
    window; the publish itself rides ``run_async`` on the I/O thread so
    the decode loop never blocks, and ``quiesce`` covers it).
  * **Liveness = lease**: the manager holds a lease on the latest
    version of every live session; ``catalog.gc`` therefore can never
    reclaim one (acquire's under-lock reclaimed check closes the
    acquire/gc race), and the DLM cache's lease-pinned admission
    (``DLMCache.protected``) keeps leased sessions DRAM-resident under
    capacity pressure. Eviction of a cold session is a LEASE RELEASE —
    a metadata write — never byte deletion; ``end()`` unretains every
    version and lets the next gc sweep reclaim the bytes (records and
    lineage survive).
  * **Recovery = records**: ``recoverable_sessions(lost)`` and the
    eviction choice are ``@metadata_only`` (lint-enforced); post-kill
    resumes read the home or an ACKED replica holder — zero blind
    probes — and session repair rides the existing catalog-record scan
    of ``RepairChannel``/``RepairDaemon`` with zero new scan code.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.annotations import metadata_only, rehydration_entry
from repro.core.checkpoint import DistributedCheckpointer
from repro.core.data_scheduler import DataScheduler, SupersededError
from repro.core.dataset_exchange import ack_targets, read_json_copies
from repro.core.meta_log import MetaLog
from repro.core.object_store import _flatten
from repro.core.tiering import DLMCache
from repro.core.wire_codec import normalize_codec
from repro.obs.metrics import Registry, StatsView
from repro.obs.trace import ctx as _span_ctx


#: acknowledged durability levels, weakest to strongest (module
#: docstring has the full contract)
DURABILITY_LEVELS = ("PENDING", "FAILED", "LOCAL", "REPLICATED", "DRAINED")


class SaveTicket:
    """Handle for one asynchronous checkpoint save.

    ``result()`` blocks until the node-local pmem commit (the manifest
    rename) finishes and returns the global manifest. ``post_commit``
    holds the background drain/replicate futures, which may complete —
    or fail, e.g. when a buddy node dies — long after the commit.
    ``durability()`` reports the acknowledged durability level from the
    persisted ack map (see module docstring).
    """

    def __init__(self, step: int, slot: Optional[int] = None,
                 checkpointer: Optional[DistributedCheckpointer] = None):
        self.step = step
        self.slot = slot  # filled in once the writer allocates it
        self.future: Future = Future()
        self.post_commit: List[Future] = []
        self._checkpointer = checkpointer

    def result(self, timeout: Optional[float] = None) -> dict:
        return self.future.result(timeout)

    def done(self) -> bool:
        return self.future.done()

    def exception(self, timeout: Optional[float] = None):
        return self.future.exception(timeout)

    def wait_post_commit(self, timeout: Optional[float] = None
                         ) -> List[Exception]:
        """Join drain/replicate; returns their errors instead of raising
        (a dead replica target must not poison an otherwise-good save)."""
        errors: List[Exception] = []
        for f in self.post_commit:
            try:
                f.result(timeout)
            except Exception as e:  # noqa: BLE001 — collected for caller
                errors.append(e)
        return errors

    @metadata_only
    def durability(self) -> str:
        """Acknowledged durability of this save (DURABILITY_LEVELS).
        Reads the persisted ack map, so it stays truthful after the
        ticket is retired and across processes — an unacked replicate
        still in flight (or dead with its node) keeps the step LOCAL.
        For a delta checkpoint the level is capped by the base chain's:
        a delta whose base lost its replicas is NOT single-node-loss
        safe, however fully its own slot replicated."""
        if not self.future.done():
            return "PENDING"
        if self.future.exception() is not None:
            return "FAILED"
        ckpt = self._checkpointer
        if ckpt is None:
            return "LOCAL"
        man = self.future.result()
        return _acked_level(ckpt, self.step,
                            man.get("nodes") or ckpt.nodes,
                            man.get("delta_base"))


_LEVEL_RANK = {lvl: i for i, lvl in enumerate(DURABILITY_LEVELS)}


@metadata_only
def _acked_level(ckpt: DistributedCheckpointer, step: int,
                 ring: Sequence[str], delta_base: Optional[int]) -> str:
    acks = ckpt.acks(step)
    if ring and all(acks.get(n, {}).get("drain") for n in ring):
        level = "DRAINED"
    elif len(ring) > 1 and \
            all(acks.get(n, {}).get("replica") for n in ring):
        level = "REPLICATED"
    else:
        level = "LOCAL"
    if delta_base is not None and delta_base < step:
        try:
            bman = ckpt._meta_get_json(
                f"ckpt/manifest_step{delta_base}.json")
        except (IOError, FileNotFoundError):
            return "LOCAL"  # base manifest gone: chain not protected
        base_level = _acked_level(ckpt, delta_base,
                                  bman.get("nodes") or ckpt.nodes,
                                  bman.get("delta_base"))
        if _LEVEL_RANK[base_level] < _LEVEL_RANK[level]:
            level = base_level
    return level


class ReplicationChannel:
    """First-class replicate/drain fan-out with per-node acks.

    One ``submit`` per committed checkpoint: every shard owner's slot
    object is replicated to its ring buddy (and optionally drained to
    the external store) through the data scheduler, and each task
    records its ack into the manifest's ack map the moment the transfer
    is durable. A superseded or failed transfer records nothing — the
    ack map can under-promise durability, never over-promise it.
    """

    def __init__(self, checkpointer: DistributedCheckpointer,
                 scheduler: DataScheduler, obs=None, codec=None):
        self.checkpointer = checkpointer
        self.scheduler = scheduler
        self.obs = obs
        # wire codec spec (already normalized by TieredIO): encodes at
        # the source of every replicate/drain this channel submits
        self.codec = codec
        reg = obs.registry if obs is not None else Registry()
        # submit -> durable-ack wall clock, per transfer (the QoS
        # feedback signal ROADMAP item 5 needs)
        self._ack_s = reg.histogram("ckpt.submit_to_ack_s")

    def _begin(self, name: str, nid: str, tid: int, parent: int,
               **attrs):
        """Child span on ``nid``'s ring when the manifest carried a
        trace context (None otherwise — spans are opt-in per save)."""
        if self.obs is None or not tid:
            return None
        return self.obs.begin(name, node=nid, trace=tid, parent=parent,
                              **attrs)

    @rehydration_entry
    def submit(self, manifest: dict, *, drain: bool = False,
               sink: Optional[List[Future]] = None) -> List[Future]:
        ckpt = self.checkpointer
        step, slot = manifest["step"], manifest["slot"]
        ring = manifest.get("nodes") or ckpt.nodes
        obj = f"ckpt/slot{slot}"
        # trace context minted at save_async and stamped into the
        # manifest: every per-node transfer gets a child span, and the
        # trace id rides the ack info into the durable ack log
        trace = manifest.get("trace") or {}
        tid, root = trace.get("trace", 0), trace.get("span", 0)
        futs: List[Future] = []
        if ckpt.buddy and len(ring) > 1:
            for nid in ring:
                buddy = ckpt.buddy_of(nid, ring)
                sp = self._begin("ckpt.replicate", nid, tid, root,
                                 step=step, target=buddy)
                info = {"target": buddy, "targets": [buddy]}
                if tid:
                    info["trace"] = tid
                futs.append(self.scheduler.replicate(
                    nid, obj, buddy, expect_meta={"step": step},
                    codec=self.codec, span=_span_ctx(sp),
                    on_complete=self._ack(step, nid, "replica", info,
                                          span=sp)))
        if drain and ckpt.external is not None:
            for nid in ring:
                ext = f"ckpt_step{step}_{nid}"
                sp = self._begin("ckpt.drain", nid, tid, root,
                                 step=step, external=ext)
                info = {"external": ext}
                if tid:
                    info["trace"] = tid
                futs.append(self.scheduler.drain(
                    nid, obj, ext, expect_meta={"step": step},
                    codec=self.codec, span=_span_ctx(sp),
                    on_complete=self._ack(step, nid, "drain", info,
                                          span=sp)))
        if sink is not None:
            sink.extend(futs)
        return futs

    @rehydration_entry
    def replicate_object(self, src: str, name: str, dst: str,
                         dst_name: Optional[str] = None,
                         expect_meta: Optional[dict] = None,
                         on_complete=None) -> Future:
        """Replicate a non-checkpoint pmem object (DLM page, session
        state) to a buddy node — readable as ``replica/<src>/<name>``
        when the home pool dies (multi-node DLM fallback). ``on_complete``
        runs inside the task once the copy is durable — the DLM ack
        registry records per-object acks through it."""
        return self.scheduler.replicate(src, name, dst, dst_name=dst_name,
                                        expect_meta=expect_meta,
                                        codec=self.codec,
                                        on_complete=on_complete)

    def _ack(self, step: int, nid: str, kind: str, info: dict,
             span=None):
        ckpt = self.checkpointer
        obs = self.obs
        t_submit = time.time()

        def record(_result) -> None:
            ckpt.record_ack(step, nid, kind, info)
            self._ack_s.observe(time.time() - t_submit)
            if obs is not None and span is not None:
                # the ack lands as a point event on the transfer's span,
                # then the span closes: submit -> durable ack, one arc
                obs.event(f"ckpt.ack.{kind}", node=nid,
                          trace=span.trace, parent=span.span, step=step)
                obs.end(span)
        return record


class ExchangeChannel:
    """Dataset replica fan-out with per-dataset acks — the dataset
    exchange's sibling of ``ReplicationChannel``. One ``submit`` per
    published dataset version: the home node's object is copied to a
    buddy through the data scheduler, and ``on_ack`` (the catalog's
    record updater) runs inside the task the moment the replica is
    durable. A failed or superseded transfer records nothing — the
    catalog's placement map under-promises durability, never
    over-promises it. TieredIO tracks the futures so ``quiesce``/``join``
    cover in-flight dataset replication alongside checkpoints."""

    def __init__(self, scheduler: DataScheduler, track=None, codec=None):
        self.scheduler = scheduler
        self._track = track  # TieredIO future-tracking hook
        self.codec = codec   # wire codec for dataset replica fan-out

    @rehydration_entry
    def submit(self, src: str, obj: str, dst: str, *, version: int = 0,
               dst_name: Optional[str] = None,
               expect_meta: Optional[dict] = None,
               on_ack=None, priority: int = 2,
               span: Optional[dict] = None) -> Future:
        """``dst_name`` overrides the replica name — repair copies a
        surviving replica ``replica/<home>/<obj>`` from its HOLDER, so
        the destination name must keep the original home, not the
        holder, or reads would never find it. ``priority`` passes
        through to the scheduler (the repair daemon runs at background
        priority so foreground I/O outranks it)."""
        fut = self.scheduler.replicate(src, obj, dst, version=version,
                                       dst_name=dst_name,
                                       expect_meta=expect_meta,
                                       codec=self.codec,
                                       on_complete=on_ack,
                                       priority=priority, span=span)
        if self._track is not None:
            self._track(fut)
        return fut


def _fold_dlm_acks(state: dict, ev: dict) -> None:
    """MetaLog reducer for the DLM ack registry: state maps the full
    object name to its ack record; a ``record`` event wins wholesale
    (the repair-pruned targets list must not be resurrected)."""
    state[ev["name"]] = {"home": ev["home"],
                         "targets": list(ev["targets"]),
                         "ts": ev["ts"]}


class DLMAckRegistry:
    """Per-object replica acks for DLM objects — the third ack surface.

    The registry is an append-only replicated pmem log (``dlm/ackslog``,
    a ``MetaLog``): each ack APPENDS one small entry to every live pool
    instead of rewriting the whole object map, and the folded head state
    maps object names to their newest record — for the same object the
    latest entry wins wholesale, so a repair that PRUNED dead targets
    never has them resurrected by a stale copy (log order replaces the
    old per-``ts`` merge). State entries:

      {"dlm/<name>": {"home": nid, "targets": [nids], "ts": ...}}

    ``record`` is called from scheduler worker threads inside the
    replicate task, after the buddy copy is durable — a failed copy
    records nothing, so the registry under-promises, never
    over-promises. A fresh process replays the log cold; the legacy
    pre-log ``dlm/acks.json`` record (if present) is folded in as the
    replay base, so old deployments migrate transparently."""

    NAME = "dlm/acks.json"  # legacy pre-log record (read-only base)
    LOG = "dlm/ackslog"

    def __init__(self, stores, nodes: Sequence[str], obs=None):
        self.stores = stores
        self.nodes = sorted(nodes)
        self._lock = threading.Lock()
        self._log = MetaLog(stores, self.nodes, self.LOG,
                            fold=_fold_dlm_acks, base=self._legacy_base,
                            obs=obs)

    def _legacy_base(self) -> Dict[str, dict]:
        try:
            copies = read_json_copies(self.stores, self.nodes, self.NAME)
        except (IOError, FileNotFoundError):
            return {}
        merged: Dict[str, dict] = {}
        for c in copies:
            for name, rec in (c.get("objects") or {}).items():
                if name not in merged or \
                        rec.get("ts", 0) > merged[name].get("ts", 0):
                    merged[name] = rec
        return merged

    def record(self, name: str, home: str, target: str,
               targets: Optional[Sequence[str]] = None) -> None:
        """Ack one durable buddy copy of ``name`` (a full store object
        name, e.g. ``dlm/serve/sess``). Default: ``target`` joins the
        existing target set. Repair passes an explicit ``targets`` list
        to REPLACE it (pruning targets lost with their nodes)."""
        with self._lock:
            if targets is None:
                targets = sorted(
                    set(ack_targets(self._log.state().get(name)))
                    | {target})
            self._log.append({"op": "record", "name": name,
                              "home": home,
                              "targets": sorted(targets)})

    def objects(self) -> Dict[str, dict]:
        """The merged per-object ack map ({} when nothing ever acked)."""
        with self._lock:
            return dict(self._log.state())

    def targets(self, name: str) -> List[str]:
        """Acked replica holders of ``name`` (possibly empty)."""
        with self._lock:
            return ack_targets(self._log.state().get(name))


class RepairChannel:
    """Ack-driven replica repair: restore the replication factor.

    ``repair(lost_nodes)`` scans the three ack surfaces (checkpoint
    step acks, dataset catalog records, the DLM ack registry) for
    objects whose acked copy set — {home} ∪ acked targets — intersects
    ``lost_nodes`` down to exactly ONE survivor, and re-replicates each
    from that survivor to a fresh live buddy via data-scheduler tasks,
    re-acking (pruned targets + the new one) only when the copy is
    durable. Decisions come from the persisted ack records alone; the
    only object-store reads are the sources of the copies made."""

    def __init__(self, tiered: "TieredIO"):
        self.tiered = tiered

    # ---- shared mechanics --------------------------------------------
    @staticmethod
    def _single_survivor(home: str, targets: Sequence[str],
                         lost: Set[str]) -> Optional[str]:
        """The lone surviving acked copy holder, or None when the object
        needs no repair (>= 2 survivors), was never replicated (nothing
        was promised), or lost every pmem copy (repair cannot invent
        bytes; the drain tier, when acked, still covers checkpoints)."""
        pre = {home} | set(targets)
        cur = pre - lost
        if len(pre) >= 2 and len(cur) == 1:
            return next(iter(cur))
        return None

    def _new_target(self, live: Sequence[str], survivor: str,
                    exclude: Set[str]) -> Optional[str]:
        """The next live node after ``survivor`` in ring order that
        holds no copy yet — the same rotation ``buddy_of`` uses, so
        repair load spreads instead of piling onto one node."""
        ring = list(live)
        if survivor not in ring:
            return None
        i = ring.index(survivor)
        for k in range(1, len(ring)):
            cand = ring[(i + k) % len(ring)]
            if cand not in exclude:
                return cand
        return None

    def _live(self, lost: Set[str]) -> List[str]:
        ckpt = self.tiered.checkpointer
        nodes = ckpt._live_nodes() if ckpt is not None else \
            sorted(self.tiered.scheduler.stores)
        return [n for n in nodes if n not in lost]

    @metadata_only
    def _plan(self, home: str, targets: Sequence[str], lost: Set[str],
              live: Sequence[str], report: dict, *,
              drain_ok: bool = False
              ) -> Optional[Tuple[str, str, List[str]]]:
        """One object's repair decision + report accounting, shared by
        the three scans: (survivor, new_target, new_targets) when a
        re-replication is due, else None after counting the object as
        ``healthy`` (>= 2 surviving copies), ``skipped`` (never acked a
        replica — repair does not own single-copy-by-design objects),
        or ``unrepairable`` (no surviving pmem copy, or no live node
        left to host a new one; ``drain_only`` when an acked external
        drain still covers it)."""
        survivor = self._single_survivor(home, targets, lost)
        if survivor is None:
            pre = {home} | set(targets)
            if len(pre) < 2:
                report["skipped"] += 1
            elif not (pre - lost):
                report["unrepairable"] += 1
                if drain_ok:
                    report["drain_only"] += 1
            else:
                report["healthy"] += 1
            return None
        new = self._new_target(live, survivor,
                               ({home} | set(targets)) - lost)
        if new is None:
            report["unrepairable"] += 1
            return None
        return survivor, new, sorted((set(targets) - lost) | {new})

    def _rehydrate_target(self, nid: str, live: Sequence[str],
                          exclude: Set[str]) -> Optional[str]:
        """Where a rehydrated shard of dead node ``nid`` should land:
        the first live node after ``nid``'s position in the full ring
        (same rotation as ``buddy_of``/``_new_target``, so rehydration
        load spreads instead of piling onto one node)."""
        ckpt = self.tiered.checkpointer
        ring = ckpt.nodes if ckpt is not None else sorted(live)
        i = ring.index(nid) if nid in ring else 0
        for k in range(1, len(ring) + 1):
            cand = ring[(i + k) % len(ring)]
            if cand in live and cand not in exclude:
                return cand
        return None

    # ---- the scan ----------------------------------------------------
    @metadata_only
    def repair(self, lost_nodes: Sequence[str], *,
               max_inflight: Optional[int] = None,
               priority: Optional[int] = None,
               rehydrate: bool = True) -> dict:
        """Scan + re-replicate + join. Returns a report:
        ``checkpoint``/``dataset``/``dlm`` count completed re-acked
        copies, ``repaired`` lists them as (surface, object, survivor,
        new_target), ``rehydrated`` counts drain-tier rehydrations
        (checkpoint shards with zero surviving pmem copies staged back
        from the acked external drain and re-replicated to a live
        buddy), ``healthy`` objects that still have >= 2 surviving
        acked copies (nothing to do), ``superseded`` sources overwritten
        since their ack (benign — the newer object carries its own
        acks), ``unrepairable`` objects with no surviving pmem copy or
        no live node left to host a new one (``drain_only`` the subset
        an acked external drain still covers but that was NOT
        rehydrated), ``skipped`` single-copy objects that never acked a
        replica (repair does not own them), and ``errors`` real copy
        failures.

        ``max_inflight`` is the repair-traffic budget: at most that many
        repair transfers are queued/running at once, the rest wait — the
        continuous daemon uses it so a repair storm never swamps
        foreground I/O (``peak_inflight`` in the report records the high
        water mark). ``priority`` overrides the scheduler priority of
        every repair task (the daemon passes a background priority so
        foreground saves/stage-ins always outrank repairs). Plans run in
        newest-checkpoint-first order. ``rehydrate=False`` disables the
        drain-tier path (drain-only objects are then only counted)."""
        lost = set(lost_nodes)
        report = {"checkpoint": 0, "dataset": 0, "dlm": 0,
                  "rehydrated": 0, "healthy": 0, "superseded": 0,
                  "unrepairable": 0, "drain_only": 0, "skipped": 0,
                  "peak_inflight": 0, "repaired": [], "errors": []}
        obs = self.tiered.obs
        sweep_span = None
        if obs is not None:
            # one trace per sweep: scan + every copy/re-ack hangs off it
            sweep_span = obs.begin("repair.sweep", lost=sorted(lost))
        sctx = _span_ctx(sweep_span)
        live = self._live(lost)
        plans: collections.deque = collections.deque()
        if self.tiered.checkpointer is not None:
            self._scan_checkpoints(lost, live, report, plans,
                                   priority=priority, rehydrate=rehydrate,
                                   span=sctx)
        self._scan_dlm(lost, live, report, plans, priority=priority,
                       span=sctx)
        if self.tiered.catalog is not None:
            self._scan_datasets(lost, live, report, plans,
                                priority=priority, span=sctx)
        self._execute(plans, report, max_inflight)
        if obs is not None:
            for k in ("checkpoint", "dataset", "dlm", "rehydrated",
                      "healthy", "superseded", "unrepairable",
                      "drain_only", "skipped"):
                obs.counter(f"repair.{k}").inc(report[k])
            obs.counter("repair.errors").inc(len(report["errors"]))
            obs.end(sweep_span, repaired=len(report["repaired"]),
                    errors=len(report["errors"]))
        return report

    def _execute(self, plans: "collections.deque", report: dict,
                 max_inflight: Optional[int]) -> None:
        """Run repair plans through a bounded submission window.
        Each plan: {surface, obj, survivor, new, submit, then?,
        on_error?}. ``then`` chains a follow-up plan on success
        (rehydration stages external->pmem, THEN replicates pmem->pmem);
        it re-enters at the FRONT of the queue so a chain completes
        before new objects start. Completion of a plan without ``then``
        is what the per-surface counters and ``repaired`` record."""
        outstanding: collections.deque = collections.deque()
        while plans or outstanding:
            while plans and (max_inflight is None
                             or len(outstanding) < max_inflight):
                p = plans.popleft()
                outstanding.append((p, p["submit"]()))
                report["peak_inflight"] = max(report["peak_inflight"],
                                              len(outstanding))
            p, fut = outstanding.popleft()
            try:
                fut.result()
            except SupersededError:
                report["superseded"] += 1
            except Exception as e:  # noqa: BLE001 — reported, not raised
                report["errors"].append(e)
                if p.get("on_error") is not None:
                    p["on_error"](e)
            else:
                then = p.get("then")
                if then is not None:
                    plans.appendleft(then)
                    continue
                report[p["counter"]] += 1
                report["repaired"].append(
                    (p["surface"], p["obj"], p["survivor"], p["new"]))

    @metadata_only
    def _scan_checkpoints(self, lost: Set[str], live: List[str],
                          report: dict, plans: "collections.deque", *,
                          priority: Optional[int],
                          rehydrate: bool,
                          span: Optional[dict] = None) -> None:
        ckpt = self.tiered.checkpointer
        sched = self.tiered.scheduler
        prio = {} if priority is None else {"priority": priority}
        if span is not None:
            prio["span"] = span
        seen_slots: Set[int] = set()
        for step in sorted(ckpt.available_steps(), reverse=True):
            try:
                rec_map = ckpt.ack_record(step)
                if rec_map is None:
                    continue  # pre-ack legacy step: nothing promised
                slot = ckpt._meta_get_json(
                    f"ckpt/manifest_step{step}.json")["slot"]
            except (IOError, FileNotFoundError, KeyError):
                continue  # pre-ack legacy step: nothing was promised
            if slot in seen_slots:
                # a newer step reused this slot: the bytes on pmem are
                # no longer this step's (its own replicate would only
                # raise SupersededError) — skip on metadata alone. The
                # same holds for rehydration: the replica name is keyed
                # by slot, so staging the old step back would collide
                # with the newer step's replicas.
                report["superseded"] += 1
                continue
            seen_slots.add(slot)
            ring = rec_map.get("ring") or ckpt.nodes
            acks = rec_map.get("acks") or {}
            obj = f"ckpt/slot{slot}"
            for nid in ring:
                targets = ack_targets(acks.get(nid, {}).get("replica"))
                drain_rec = acks.get(nid, {}).get("drain") \
                    if ckpt.external is not None else None
                if rehydrate and drain_rec and \
                        not (({nid} | set(targets)) - lost):
                    # drain-tier rehydration: every pmem copy died, the
                    # acked external drain survives — stage it back into
                    # a live pool (the only external read this scan
                    # makes), then re-replicate to a fresh buddy
                    self._plan_rehydration(step, nid, slot, drain_rec,
                                           live, report, plans, prio)
                    continue
                plan = self._plan(
                    nid, targets, lost, live, report,
                    drain_ok=bool(drain_rec))
                if plan is None:
                    continue
                survivor, new, new_targets = plan
                src_obj = obj if survivor == nid else \
                    f"replica/{nid}/{obj}"

                def ack(_man, step=step, nid=nid, new=new,
                        new_targets=new_targets) -> None:
                    info = {"target": new, "targets": new_targets}
                    if span is not None:
                        info["trace"] = span["trace"]
                    ckpt.record_ack(step, nid, "replica", info)
                plans.append({"surface": "checkpoint",
                              "counter": "checkpoint",
                              "obj": f"step{step}/{nid}",
                              "survivor": survivor, "new": new,
                              "submit": lambda s=survivor, so=src_obj,
                              n=new, st=step, ni=nid, a=ack, o=obj:
                              sched.replicate(
                                  s, so, n, dst_name=f"replica/{ni}/{o}",
                                  expect_meta={"step": st},
                                  codec=self.tiered.wire_codec,
                                  on_complete=a, **prio)})

    def _plan_rehydration(self, step: int, nid: str, slot: int,
                          drain_rec: dict, live: List[str], report: dict,
                          plans: "collections.deque",
                          prio: dict) -> None:
        """Queue the two-stage rehydration of ``nid``'s shard at
        ``step``: (1) stage the acked external drained copy into a live
        pool under the replica name (acked immediately — one durable
        pmem copy), (2) replicate that staged copy to a second live node
        and re-ack the pair. Either stage failing counts the object as
        ``unrepairable``/``drain_only`` (the drain still covers it), and
        a later sweep re-plans from whatever the acks then say."""
        ckpt = self.tiered.checkpointer
        sched = self.tiered.scheduler
        t1 = self._rehydrate_target(nid, live, set())
        if t1 is None:
            report["unrepairable"] += 1
            report["drain_only"] += 1
            return
        t2 = self._rehydrate_target(nid, live, {t1})
        ext = drain_rec.get("external") or f"ckpt_step{step}_{nid}"
        rep = f"replica/{nid}/ckpt/slot{slot}"
        obj = f"step{step}/{nid}"

        def count_lost(_e) -> None:
            report["unrepairable"] += 1
            report["drain_only"] += 1

        def ack_stage(_man, targets=(t1,)) -> None:
            # the staged pmem copy is durable: ack it alone first —
            # under-promise, so a crash between the stages leaves a
            # truthful single-target record the next sweep extends
            ckpt.record_ack(step, nid, "replica",
                            {"target": t1, "targets": sorted(targets)})

        stage = {"surface": "rehydrate", "counter": "rehydrated",
                 "obj": obj, "survivor": "external", "new": t1,
                 "on_error": count_lost,
                 "submit": lambda: sched.stage_in(
                     t1, ext, rep,
                     meta={"step": step, "replica_of": nid},
                     on_complete=ack_stage, **prio)}
        if t2 is not None:
            def ack_pair(_man) -> None:
                ckpt.record_ack(step, nid, "replica",
                                {"target": t2,
                                 "targets": sorted((t1, t2))})
            stage["then"] = {
                "surface": "rehydrate", "counter": "rehydrated",
                "obj": obj, "survivor": "external", "new": t1,
                "on_error": count_lost,
                "submit": lambda: sched.replicate(
                    t1, rep, t2, dst_name=rep,
                    expect_meta={"step": step},
                    codec=self.tiered.wire_codec,
                    on_complete=ack_pair, **prio)}
        plans.append(stage)

    @metadata_only
    def _scan_dlm(self, lost: Set[str], live: List[str],
                  report: dict, plans: "collections.deque", *,
                  priority: Optional[int],
                  span: Optional[dict] = None) -> None:
        reg = self.tiered.dlm_acks
        if reg is None:
            return
        sched = self.tiered.scheduler
        prio = {} if priority is None else {"priority": priority}
        if span is not None:
            prio["span"] = span
        for name, rec in reg.objects().items():
            home = rec.get("home")
            targets = ack_targets(rec)
            plan = self._plan(home, targets, lost, live, report)
            if plan is None:
                continue
            survivor, new, new_targets = plan
            src_obj = name if survivor == home else \
                f"replica/{home}/{name}"

            def ack(_man, name=name, home=home, new=new,
                    new_targets=new_targets) -> None:
                reg.record(name, home, new, targets=new_targets)
            plans.append({"surface": "dlm", "counter": "dlm",
                          "obj": name, "survivor": survivor, "new": new,
                          "submit": lambda s=survivor, so=src_obj, n=new,
                          h=home, nm=name, a=ack: sched.replicate(
                              s, so, n, dst_name=f"replica/{h}/{nm}",
                              codec=self.tiered.wire_codec,
                              on_complete=a, **prio)})

    @metadata_only
    def _scan_datasets(self, lost: Set[str], live: List[str],
                       report: dict, plans: "collections.deque", *,
                       priority: Optional[int],
                       span: Optional[dict] = None) -> None:
        catalog = self.tiered.catalog
        sched = self.tiered.scheduler
        prio = {} if priority is None else {"priority": priority}
        if span is not None:
            prio["span"] = span
        for rec in catalog.records():
            if rec.get("reclaimed"):
                continue
            home = rec["home"]
            targets = ack_targets((rec.get("acks") or {}).get("replica"))
            plan = self._plan(home, targets, lost, live, report)
            if plan is None:
                continue
            survivor, new, new_targets = plan
            wf, name, v = rec["workflow"], rec["name"], rec["version"]
            src_obj = rec["object"] if survivor == home else \
                f"replica/{home}/{rec['object']}"
            dst_name = f"replica/{home}/{rec['object']}"

            def ack(_man, wf=wf, name=name, v=v, new=new,
                    new_targets=new_targets) -> None:
                catalog.record_repair_ack(wf, name, v, target=new,
                                          targets=new_targets)
            chan = self.tiered.exchange
            key = f"exch/{wf}/{name}@v{v}"

            def submit(survivor=survivor, src_obj=src_obj, new=new,
                       v=v, name=name, dst_name=dst_name, ack=ack,
                       chan=chan) -> Future:
                if chan is not None:
                    return chan.submit(
                        survivor, src_obj, new, version=v,
                        dst_name=dst_name,
                        expect_meta={"dataset": name, "version": v},
                        on_ack=ack, **prio)
                return sched.replicate(
                    survivor, src_obj, new, version=v, dst_name=dst_name,
                    expect_meta={"dataset": name, "version": v},
                    codec=self.tiered.wire_codec,
                    on_complete=ack, **prio)
            plans.append({"surface": "dataset", "counter": "dataset",
                          "obj": key, "survivor": survivor, "new": new,
                          "submit": submit})


def _merge_sweep(acc: dict, sweep: dict) -> None:
    """Fold one sweep's report into the daemon's accumulated ledger.
    Event counters (copies made, rehydrations, supersedes, errors,
    repaired entries) accumulate across sweeps; STATE counters
    (healthy / unrepairable / drain_only / skipped) are the LAST
    sweep's values — every sweep re-scans all three ack surfaces
    against the cumulative dead set, so the newest scan is the current
    truth (an object sweep N rehydrated must not keep an old sweep's
    ``drain_only`` count alive)."""
    for k in ("checkpoint", "dataset", "dlm", "rehydrated",
              "superseded"):
        acc[k] = acc.get(k, 0) + sweep.get(k, 0)
    for k in ("healthy", "unrepairable", "drain_only", "skipped"):
        acc[k] = sweep.get(k, 0)
    acc["peak_inflight"] = max(acc.get("peak_inflight", 0),
                               sweep.get("peak_inflight", 0))
    acc.setdefault("repaired", []).extend(sweep.get("repaired", ()))
    acc.setdefault("errors", []).extend(sweep.get("errors", ()))


class RepairDaemon:
    """Continuous, heartbeat-driven background repair sweeps.

    PR 4's repair runs only at recovery points (``check_and_recover`` /
    ``resume``), so an object sits on a single pmem copy for the whole
    window between a node loss and the next recovery event. The daemon
    closes that window: it polls ``Heartbeat.dead_nodes`` and, on every
    NEW death, runs ``RepairChannel.repair`` over the CUMULATIVE dead
    set — incrementally (already-handled deaths don't re-trigger),
    rate-limited (``max_inflight`` bounds concurrent repair transfers;
    ``priority`` puts them below every foreground channel in the
    scheduler queues), newest-checkpoint-first, and with drain-tier
    rehydration on. It quiesces nothing: repair decisions come from
    persisted acks, which are only ever written after a transfer is
    durable, so the sweep coexists with in-flight foreground I/O.

    A second loss mid-sweep simply fails the transfers aimed at the
    newly-dead node; the next poll sees an unhandled death and
    re-plans the whole cumulative set from the acks (PR 4's ``targets``
    lists make the re-plan safe). Error-only sweeps retry up to
    ``max_retries`` times before the dead set is marked handled with
    the errors kept in the ledger.

    The **ledger**: ``covers(lost)`` says whether every node in
    ``lost`` has been swept cleanly, and ``report()`` returns the
    merged accumulated report — recovery points
    (``FailureRecovery.check_and_recover``,
    ``WorkflowScheduler.resume``, ``ServeEngine.repair``) consult it
    instead of re-scanning from scratch. ``wait_for(lost)`` blocks
    until the ledger covers ``lost`` (the train loop's fault hook uses
    it to resume only after the replication factor is back)."""

    def __init__(self, tiered: "TieredIO", heartbeat, *,
                 timeout_s: float = 10.0, poll_s: float = 0.05,
                 max_inflight: int = 2, priority: int = 4,
                 max_retries: int = 3, rehydrate: bool = True):
        self.tiered = tiered
        self.hb = heartbeat
        self.timeout_s = timeout_s
        self.poll_s = poll_s
        self.max_inflight = max_inflight
        self.priority = priority
        self.max_retries = max_retries
        self.rehydrate = rehydrate
        self.handled: Set[str] = set()
        self._attempts: Dict[frozenset, int] = {}
        self._ledger: dict = {"sweeps": 0}
        self._cv = threading.Condition()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ---- lifecycle ---------------------------------------------------
    @property
    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def start(self) -> "RepairDaemon":
        if self.running:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="repair-daemon")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=60.0)
            if t.is_alive():
                # a wedged sweep survived the join timeout: keep the
                # thread visible (running stays True) so a later
                # start() cannot spawn a SECOND daemon racing this one
                # on the ledger; the stop flag ends it when it unwedges
                return
            self._thread = None

    def _run(self) -> None:
        backoff = self.poll_s
        while not self._stop.is_set():
            try:
                self.poll_once()
                backoff = self.poll_s
            except Exception as e:  # noqa: BLE001 — daemon must survive
                # a sweep that RAISES (vs per-object errors, which the
                # report collects) means even the metadata scan failed;
                # back off exponentially so a dead cluster doesn't fill
                # the ledger at poll rate
                with self._cv:
                    self._ledger.setdefault("errors", []).append(e)
                backoff = min(backoff * 2, 1.0)
            self._stop.wait(backoff)

    # ---- one poll/sweep (also the unit tests' entry point) -----------
    def poll_once(self, now: Optional[float] = None) -> Optional[dict]:
        """Detect new deaths and sweep if any; returns that sweep's
        report, or None when nothing new happened. Runs inline on the
        caller's thread — the background loop is just this on a timer."""
        dead = set(self.hb.dead_nodes(self.timeout_s, now))
        with self._cv:
            # a rejoined node may die again later: it leaves the
            # handled set the moment it stops being dead
            self.handled &= dead
            new = dead - self.handled
        if not new:
            return None
        sweep = self.tiered.repair(sorted(dead),
                                   max_inflight=self.max_inflight,
                                   priority=self.priority,
                                   rehydrate=self.rehydrate)
        key = frozenset(dead)
        with self._cv:
            _merge_sweep(self._ledger, sweep)
            self._ledger["sweeps"] += 1
            if not sweep["errors"]:
                self.handled |= dead
                self._attempts.clear()
            else:
                # transfers died mid-sweep (e.g. a SECOND loss): leave
                # the set unhandled so the next poll re-plans from the
                # acks — but give up after max_retries so a permanent
                # failure doesn't storm the scheduler forever
                self._attempts[key] = self._attempts.get(key, 0) + 1
                if self._attempts.get(key, 0) >= self.max_retries:
                    self.handled |= dead
            self._cv.notify_all()
        obs = self.tiered.obs
        if obs is not None:
            obs.counter("repair.daemon_sweeps").inc()
            obs.event("repair.daemon_sweep", dead=sorted(dead),
                      errors=len(sweep["errors"]))
        return sweep

    # ---- the ledger --------------------------------------------------
    def covers(self, lost_nodes: Sequence[str]) -> bool:
        """True when every node in ``lost_nodes`` has been swept: a
        recovery point may then take ``report()`` instead of running a
        redundant scan of its own."""
        with self._cv:
            return set(lost_nodes) <= self.handled

    def wait_for(self, lost_nodes: Sequence[str],
                 timeout: Optional[float] = None) -> bool:
        """Block until the ledger covers ``lost_nodes`` (or timeout)."""
        lost = set(lost_nodes)
        with self._cv:
            return self._cv.wait_for(lambda: lost <= self.handled,
                                     timeout)

    def report(self) -> dict:
        """The accumulated ledger: merged sweep reports plus ``sweeps``
        (count) and ``handled`` (nodes swept cleanly)."""
        with self._cv:
            out = dict(self._ledger)
            out["repaired"] = list(self._ledger.get("repaired", ()))
            out["errors"] = list(self._ledger.get("errors", ()))
            out["handled"] = sorted(self.handled)
            return out


class TieredIO:
    """Async engine over checkpointer + scheduler + DLM cache."""

    def __init__(self, checkpointer: Optional[DistributedCheckpointer] = None,
                 scheduler: Optional[DataScheduler] = None,
                 cache: Optional[DLMCache] = None,
                 max_inflight_saves: Optional[int] = None,
                 wire_codec=None, obs=None):
        self.checkpointer = checkpointer
        self.scheduler = scheduler
        self.cache = cache
        self.obs = obs
        # opt-in delta-int8 wire codec for every fabric/external
        # transfer this engine submits (True -> defaults, or a spec
        # dict); None keeps every channel raw
        self.wire_codec = normalize_codec(wire_codec)
        reg = obs.registry if obs is not None else Registry()
        # the replication channel owns ALL replicate/drain fan-out; the
        # checkpointer delegates to it at every save commit
        self.replication: Optional[ReplicationChannel] = None
        if checkpointer is not None and scheduler is not None:
            self.replication = ReplicationChannel(checkpointer, scheduler,
                                                  obs=obs,
                                                  codec=self.wire_codec)
            checkpointer.replication = self.replication
        # dataset-exchange fan-out (catalog attached via attach_catalog)
        self.exchange: Optional[ExchangeChannel] = None
        self.catalog = None
        if scheduler is not None:
            self.exchange = ExchangeChannel(scheduler,
                                            track=self._track_future,
                                            codec=self.wire_codec)
        # home node of the DLM cache (whose store it fronts): replica
        # fallback reads resolve relative to it
        self._home_nid: Optional[str] = None
        # per-object DLM replica acks (dlm/acks.json) + the repair scan
        # over all three ack surfaces
        self.dlm_acks: Optional[DLMAckRegistry] = None
        self.repair_channel = RepairChannel(self)
        # the continuous RepairDaemon, when one is running against this
        # engine (FailureRecovery.start_daemon wires it): recovery
        # points consult its ledger instead of re-scanning
        self.repair_daemon: Optional[RepairDaemon] = None
        # dlm/<name>s the caller opted out of replicating (offload
        # replicate=False): dirty write-backs skip them too
        self._dlm_no_replicate: Set[str] = set()
        if checkpointer is not None:
            self._home_nid = checkpointer.nodes[0]
            self.dlm_acks = DLMAckRegistry(checkpointer.stores,
                                           checkpointer.nodes, obs=obs)
            if cache is not None:
                for nid, st in checkpointer.stores.items():
                    if st is cache.store:
                        self._home_nid = nid
                        break
                if cache.fallback_reader is None:
                    cache.fallback_reader = self._dlm_replica_read
                if cache.on_writeback is None:
                    # every durable DLM write-back (offload flush, dirty
                    # eviction) re-queues the buddy copy + ack, so the
                    # replica tier never lags the home pool
                    cache.on_writeback = self._queue_dlm_replica
        self.max_inflight = max_inflight_saves or (
            checkpointer.slots if checkpointer is not None else 2)
        self.errors: List[Exception] = []       # post-commit failures
        self.save_errors: List[Exception] = []  # checkpoint COMMIT failures
        # registry-backed channel counters; ``stats`` stays dict-shaped
        # (StatsView) so existing callers/tests read it unchanged
        self._counters = {k: reg.counter(f"tiered.{k}")
                          for k in ("saves", "offloads", "prefetch_hits",
                                    "prefetch_loads", "stage_in_hits",
                                    "stage_in_loads")}
        self.stats = StatsView(self._counters)
        self._g_inflight = reg.gauge("tiered.inflight_saves")
        self._t_commit = reg.histogram("ckpt.save_commit_s")
        self._tickets: "collections.deque[SaveTicket]" = collections.deque()
        self._retired: List[SaveTicket] = []  # committed, drains may run
        self._futures: List[Future] = []   # offload/prefetch futures
        self._lock = threading.Lock()
        # one FIFO writer thread: serialises pmem writes (slot safety),
        # overlaps them with the caller's compute. Reads (prefetch
        # warms) go through their own pool so a large warm-up batch
        # never delays the next checkpoint commit.
        self._io = ThreadPoolExecutor(max_workers=1,
                                      thread_name_prefix="tiered-io-wr")
        self._read = ThreadPoolExecutor(max_workers=2,
                                        thread_name_prefix="tiered-io-rd")

    def _submit(self, fn) -> Future:
        return self._io.submit(fn)  # raises RuntimeError after shutdown

    def run_async(self, fn) -> Future:
        """Run ``fn`` on the engine's FIFO I/O thread, tracked like an
        offload: ``quiesce``/``join`` cover the returned future, so a
        crash-time drain never strands it. The serve tier's nonblocking
        session spill (a catalog ``publish`` that must not stall the
        decode loop) rides this hook."""
        fut = self._submit(fn)
        self._track_future(fut)
        return fut

    def _track_future(self, fut: Future) -> None:
        with self._lock:
            self._prune_done_locked()
            self._futures.append(fut)

    def attach_catalog(self, catalog) -> None:
        """Wire a DatasetCatalog into the engine: its replica fan-out
        goes through the exchange channel (futures joined by quiesce),
        its reads admit into the DLM cache, and ``evict_cold`` keeps the
        catalog's actively-leased datasets DRAM-resident."""
        self.catalog = catalog
        catalog.exchange = self.exchange
        if self.cache is not None:
            catalog.cache = self.cache
            if self.cache.protected is None:
                # lease-pinned admission: capacity-pressure LRU never
                # evicts a dataset someone holds a live lease on (serve
                # sessions mid-request, workflow consumers mid-lease)
                self.cache.protected = catalog.leased_cache_keys

    # ---- checkpoint channel ------------------------------------------
    def save_async(self, step: int, tree, *,
                   base_step: Optional[int] = None,
                   drain: bool = False) -> SaveTicket:
        """Nonblocking checkpoint: returns immediately (modulo slot
        backpressure); the write overlaps the caller's next step."""
        assert self.checkpointer is not None, "no checkpointer attached"
        ckpt = self.checkpointer
        ticket = SaveTicket(step, checkpointer=ckpt)
        retiring: List[SaveTicket] = []
        with self._lock:
            self._prune_done_locked()
            # double-buffer backpressure: never exceed the slot count.
            # The FIFO writer thread already serialises the pmem writes;
            # this only bounds how far the caller can run ahead. Only
            # the node-local COMMIT of the retiring ticket gates it —
            # its drain/replicate futures keep overlapping.
            while len(self._tickets) >= self.max_inflight:
                retiring.append(self._tickets.popleft())
            self._tickets.append(ticket)
            self._g_inflight.set(len(self._tickets))
        for old in retiring:  # wait OUTSIDE the lock: offload/prefetch
            try:              # submissions must not stall behind a write
                old.result()
            except Exception as e:  # noqa: BLE001 — surfaced by
                self.save_errors.append(e)  # raise_if_failed / quiesce
            with self._lock:
                self._retired.append(old)

        obs = self.obs
        root = None
        if obs is not None:
            # root span of the whole checkpoint trace: commit + every
            # per-node replicate/drain/ack hangs off this id
            root = obs.begin("ckpt.save", node=self._home_nid,
                             step=step, drain=drain)

        def _save():
            t0 = time.time()
            try:
                man = ckpt.save(step, tree, base_step=base_step,
                                drain=drain,
                                post_commit=ticket.post_commit,
                                trace=_span_ctx(root))
            except Exception:
                if obs is not None:
                    obs.end(root, status="error")
                raise
            self._t_commit.observe(time.time() - t0)
            ticket.slot = man["slot"]
            self._counters["saves"].inc()
            if obs is not None:
                obs.end(root, slot=man["slot"])
            return man

        # chain into the ticket's pre-existing future: the ticket is
        # already visible (in _tickets) to concurrent quiesce callers
        def _chain(f: Future) -> None:
            e = f.exception()
            if e is not None:
                ticket.future.set_exception(e)
            else:
                ticket.future.set_result(f.result())

        try:
            self._submit(_save).add_done_callback(_chain)
        except RuntimeError:
            with self._lock:
                self._tickets.remove(ticket)
            raise
        return ticket

    def raise_if_failed(self) -> None:
        """Raise the first pending checkpoint COMMIT failure. The
        training loop calls this at every checkpoint boundary so a run
        doesn't continue for hours believing it is protected while every
        save fails. Post-commit drain/replicate errors (e.g. a dead
        buddy) are NOT raised here — they degrade durability, not the
        node-local checkpoint itself.

        The raised error is POPPED: one failed commit surfaces exactly
        once, so a run that recovers (e.g. restores and resumes on the
        survivors) is not re-failed forever at every later boundary by
        the same stale record."""
        with self._lock:
            for t in list(self._tickets):
                if t.done() and t.exception() is not None:
                    self.save_errors.append(t.exception())
                    self._tickets.remove(t)
            if self.save_errors:
                raise self.save_errors.pop(0)

    def _prune_done_locked(self) -> None:
        """Drop fully-completed retired tickets and offload/prefetch
        futures so steady-state training/serving doesn't accumulate one
        record per checkpoint/spill forever. Failures are folded into
        ``errors`` before the record is dropped."""
        keep_t = []
        for t in self._retired:
            if all(f.done() for f in t.post_commit):
                for f in t.post_commit:
                    e = f.exception()
                    if e is not None:
                        self.errors.append(e)
            else:
                keep_t.append(t)
        self._retired = keep_t
        keep_f = []
        for f in self._futures:
            if f.done():
                e = f.exception()
                if e is not None:
                    self.errors.append(e)
            else:
                keep_f.append(f)
        self._futures = keep_f

    def _drain_ticket(self, ticket: SaveTicket) -> None:
        try:
            ticket.result()
        except Exception as e:  # noqa: BLE001 — kept for quiesce callers
            self.save_errors.append(e)
        self.errors.extend(ticket.wait_post_commit())

    def last_ticket(self) -> Optional[SaveTicket]:
        with self._lock:
            return self._tickets[-1] if self._tickets else None

    # ---- object channel (serve KV pages, session state) --------------
    def _queue_dlm_replica(self, name: str) -> None:
        """Queue a buddy copy of ``dlm/<name>`` + its ack (into the
        DLM ack registry) the moment the home-pool bytes are durable.
        Called by ``offload`` and by the cache's write-back hook (dirty
        eviction/flush), so replicas track every durable write, not
        just the first. The buddy comes from the LIVE ring, like the
        checkpoint path: after the static buddy dies, replicas must
        land on a survivor instead of failing forever."""
        ckpt, home = self.checkpointer, self._home_nid
        if (self.replication is None or ckpt is None or home is None
                or name in self._dlm_no_replicate):
            return
        ring = ckpt._live_nodes()
        if home not in ring or len(ring) < 2:
            return
        buddy = ckpt.buddy_of(home, ring)
        obj = f"dlm/{name}"
        reg = self.dlm_acks

        def ack(_man) -> None:
            if reg is not None:
                # REPLACE the target list: this copy carries the bytes
                # just written back, so every other acked copy is now
                # stale (a repair-added extra, or a buddy that died and
                # may rejoin with old pmem) and must leave the record —
                # acked targets always hold the CURRENT bytes
                reg.record(obj, home, buddy, targets=[buddy])
        rfut = self.replication.replicate_object(
            home, obj, buddy, on_complete=ack)
        self._track_future(rfut)

    def offload(self, name: str, tree, *, replicate: bool = True) -> Future:
        """Persist an object through the DLM write-back cache (or the
        checkpointer's meta store when no cache is attached). The future
        resolves once the object is durable in the home node's pmem;
        with ``replicate`` (default) a buddy replica is then queued
        through the replication channel — acked per object into
        ``dlm/acks.json`` when durable — so reads survive the home
        node's death (multi-node DLM) and ``repair`` can restore the
        replication factor after a loss. ``replicate=False`` marks the
        object node-local: later dirty write-backs skip it too."""
        if replicate:
            self._dlm_no_replicate.discard(name)
        else:
            self._dlm_no_replicate.add(name)

        def _persist():
            if self.cache is not None:
                self.cache.put(name, tree)
                # write back just this object; the cache's write-back
                # hook queues the buddy replica + ack
                self.cache.flush(name)
            else:
                assert self.checkpointer is not None
                self.checkpointer._meta_store().put(f"dlm/{name}", tree)
                self._queue_dlm_replica(name)
            self._counters["offloads"].inc()
            return name

        fut = self._submit(_persist)
        with self._lock:
            self._prune_done_locked()
            self._futures.append(fut)
        return fut

    def _dlm_candidates(self, name: str) -> Tuple[str, List[str]]:
        """Replica name + fallback read order for ``dlm/<name>``:
        ack-recorded targets first, then the home's ring buddy, then
        every other surviving node (home itself excluded)."""
        ckpt = self.checkpointer
        home = self._home_nid
        assert ckpt is not None and home is not None
        rep = f"replica/{home}/dlm/{name}"
        acked = self.dlm_acks.targets(f"dlm/{name}") \
            if self.dlm_acks is not None else []
        order = acked + [ckpt.buddy_of(home)] + \
            [n for n in ckpt.nodes if n != home]
        out: List[str] = []
        seen: Set[str] = set()
        for nid in order:
            if nid not in seen and nid != home:
                seen.add(nid)
                out.append(nid)
        return rep, out

    def _dlm_replica_read(self, name: str):
        """Multi-node DLM fallback: when the home node's pool is dead
        (or no longer holds ``dlm/<name>``), read the buddy replica
        placed by ``offload``/``repair`` — preferring the ack-recorded
        targets, then the home's ring buddy, then any surviving node
        holding ``replica/<home>/dlm/<name>``."""
        ckpt = self.checkpointer
        rep, order = self._dlm_candidates(name)
        last: Optional[Exception] = None
        for nid in order:
            try:
                if ckpt.stores[nid].exists(rep):
                    return ckpt.stores[nid].get(rep)
            except IOError as e:  # that node is dead too — keep walking
                last = e
        if last is not None:
            raise last
        raise FileNotFoundError(
            f"dlm/{name} (home {self._home_nid} unreadable and no node "
            f"holds {rep})")

    def fetch_leaf(self, name: str, leaf: str):
        """Byte-range demand read: ONE leaf of ``dlm/<name>`` without
        touching its siblings. A DRAM-resident cache copy serves from
        memory (it may be dirtier than pmem); otherwise the leaf's byte
        range is read straight from the home pool — falling back to
        acked replicas exactly like ``fetch`` — decoding only the tiles
        of that leaf when the copy travelled wire-encoded. The partial
        object is never admitted into the cache. Raises ``KeyError``
        when the object exists but has no such leaf."""
        if self.cache is not None and self.cache.contains(name):
            flat = dict(_flatten(self.cache.get(name)))
            if leaf not in flat:
                raise KeyError(leaf)
            return flat[leaf]
        ckpt = self.checkpointer
        home = self._home_nid
        assert ckpt is not None and home is not None, "no pmem backend"
        try:
            return ckpt.stores[home].get_leaf(f"dlm/{name}", leaf)
        except IOError:
            pass  # home pool dead or object gone — walk the replicas
        rep, order = self._dlm_candidates(name)
        last: Optional[Exception] = None
        for nid in order:
            try:
                if ckpt.stores[nid].exists(rep):
                    return ckpt.stores[nid].get_leaf(rep, leaf)
            except IOError as e:
                last = e
        if last is not None:
            raise last
        raise FileNotFoundError(f"dlm/{name} leaf {leaf!r} (home {home} "
                                f"unreadable and no node holds {rep})")

    def fetch(self, name: str):
        """Demand read through the DLM cache (hit/miss accounted), or
        straight from pmem when no cache is attached — symmetric with
        ``offload`` so an engine without a cache still round-trips."""
        if self.cache is not None:
            return self.cache.get(name)
        assert self.checkpointer is not None, "no pmem backend attached"
        return self.checkpointer._meta_store().get(f"dlm/{name}")

    def prefetch(self, names: Iterable[str]) -> Future:
        """Warm DRAM with ``names`` from pmem in the background. The
        future resolves to ``{"hits": n_already_resident, "loads":
        n_pulled_from_pmem, "missing": n_not_in_pmem}``. Advisory: an
        object absent from pmem is counted, never raised — the demand
        path is the arbiter of real misses."""
        assert self.cache is not None, "no DLM cache attached"
        names = list(names)

        def _warm():
            obs = self.obs
            sp = obs.begin("dlm.prefetch", node=self._home_nid,
                           n=len(names)) if obs is not None else None
            hits = loads = missing = 0
            for n in names:
                try:
                    if self.cache.prefetch(n):
                        hits += 1
                    else:
                        loads += 1
                except (IOError, FileNotFoundError, KeyError):
                    missing += 1
            self._counters["prefetch_hits"].inc(hits)
            self._counters["prefetch_loads"].inc(loads)
            if obs is not None:
                obs.end(sp, hits=hits, loads=loads, missing=missing)
            return {"hits": hits, "loads": loads, "missing": missing}

        fut = self._read.submit(_warm)
        with self._lock:
            self._prune_done_locked()
            self._futures.append(fut)
        return fut

    def evict_cold(self, max_idle_s: float = 0.0) -> int:
        """Spill idle DRAM entries back to pmem; returns count evicted.
        Lease-aware: datasets the attached catalog holds live leases on
        are pinned (a consumer mid-lease never loses DRAM residency)."""
        if self.cache is None:
            return 0
        keep = (self.catalog.leased_cache_keys()
                if self.catalog is not None else ())
        return self.cache.evict_cold(max_idle_s, keep=keep)

    def prefetch_datasets(self, refs, workflow: str = "default") -> Future:
        """Anticipatory dataset warm-up through the catalog: resolve each
        named dataset (home pmem or acked replica) on the read pool and
        admit it into the DLM cache, so a consumer job's first ``read``
        hits DRAM. Same advisory contract as ``prefetch``: absent or
        reclaimed datasets are counted, never raised."""
        assert self.catalog is not None, "no catalog attached"
        refs = list(refs)

        def _warm():
            obs = self.obs
            sp = obs.begin("exch.prefetch", node=self._home_nid,
                           n=len(refs)) if obs is not None else None
            hits = loads = missing = 0
            from repro.core.dataset_exchange import cache_key
            for name in refs:
                try:
                    rec = self.catalog.record(name, workflow)
                    key = cache_key(workflow, name, rec["version"])
                    if self.cache is not None and self.cache.contains(key):
                        hits += 1
                        continue
                    self.catalog.get(name, workflow)
                    loads += 1
                except (KeyError, IOError, FileNotFoundError):
                    missing += 1
            self._counters["prefetch_hits"].inc(hits)
            self._counters["prefetch_loads"].inc(loads)
            if obs is not None:
                obs.end(sp, hits=hits, loads=loads, missing=missing)
            return {"hits": hits, "loads": loads, "missing": missing}

        fut = self._read.submit(_warm)
        with self._lock:
            self._prune_done_locked()
            self._futures.append(fut)
        return fut

    # ---- repair channel (restore the replication factor) -------------
    def repair(self, lost_nodes: Sequence[str], **kw) -> dict:
        """Re-replicate every acked object (checkpoint shard, dataset,
        DLM object) whose copies ``lost_nodes`` reduced to a single
        survivor, to a fresh live buddy — re-acked when durable — and
        rehydrate drain-only checkpoint shards back into pmem. Joins
        the copies; returns the RepairChannel report (kwargs —
        ``max_inflight``, ``priority``, ``rehydrate`` — pass through).
        Call after the recovery path has quiesced in-flight work
        (FailureRecovery and WorkflowScheduler.resume do this wiring
        for you); the continuous RepairDaemon calls it WITHOUT
        quiescing, which is safe because acks only ever describe
        already-durable transfers."""
        return self.repair_channel.repair(lost_nodes, **kw)

    # ---- burst-buffer channel (external -> pmem) ---------------------
    def stage_in(self, nid: str, names: Sequence[str],
                 prefix: str = "staged/") -> List[Future]:
        """Pre-load external objects into node ``nid``'s pmem (Fig. 8
        steps 1-3). Objects already resident count as stage-in hits."""
        assert self.scheduler is not None, "no scheduler attached"
        obs = self.obs
        sp = obs.begin("stage.stage_in", node=nid,
                       n=len(names)) if obs is not None else None
        futs: List[Future] = []
        for name in names:
            obj = prefix + name
            if self.scheduler.stores[nid].exists(obj):
                self._counters["stage_in_hits"].inc()
                done: Future = Future()
                done.set_result(None)
                futs.append(done)
                continue
            self._counters["stage_in_loads"].inc()
            futs.append(self.scheduler.stage_in(nid, name, obj,
                                                span=_span_ctx(sp)))
        if obs is not None:
            obs.end(sp, submitted=len(futs))
        with self._lock:
            self._prune_done_locked()
            self._futures.extend(futs)
        return futs

    def stage_in_hit_rate(self) -> float:
        tot = self.stats["stage_in_hits"] + self.stats["stage_in_loads"]
        return self.stats["stage_in_hits"] / tot if tot else 0.0

    # ---- lifecycle ---------------------------------------------------
    def quiesce(self) -> List[Exception]:
        """Join every in-flight save/offload/prefetch. Errors are
        collected (and returned), never raised: recovery must be able to
        consume in-flight futures even when nodes died under them."""
        while True:
            with self._lock:
                if self._tickets:
                    ticket, fresh = self._tickets.popleft(), True
                elif self._retired:
                    ticket, fresh = self._retired.pop(), False
                else:
                    break
            if fresh:
                self._drain_ticket(ticket)
            else:  # commit already joined at backpressure time
                self.errors.extend(ticket.wait_post_commit())
        while True:
            with self._lock:
                if not self._futures:
                    break
                fut = self._futures.pop()
            try:
                fut.result()
            except Exception as e:  # noqa: BLE001
                self.errors.append(e)
        with self._lock:
            errors = self.save_errors + self.errors
            self.save_errors, self.errors = [], []
        return errors

    def join(self) -> None:
        """Strict barrier: wait for all in-flight work, raising the first
        REAL error. A ``SupersededError`` (a drain/replicate outpaced by
        slot reuse — the newer checkpoint's own transfer covers it) is
        benign and must not fail an otherwise-clean run. Use at clean
        shutdown; recovery paths use ``quiesce``."""
        errors = [e for e in self.quiesce()
                  if not isinstance(e, SupersededError)]
        if errors:
            raise errors[0]

    def shutdown(self) -> None:
        self.quiesce()
        self._io.shutdown(wait=True)
        self._read.shutdown(wait=True)
