"""Persistent Dataset Exchange: a pmem-resident dataset catalog (§V-A).

The paper's differentiating B-APM scenario is cross-application data
sharing: a producer leaves a dataset in node-local persistent memory and
consumers map it in place, skipping the external-filesystem round-trip
(Fig. 8 "retain"). Bare ``store.put`` calls give you the bytes but none
of the contract — no lifetime, no lineage, no way to know after a node
loss whether the bytes still exist anywhere. ``DatasetCatalog`` supplies
that contract:

  * every shared object is a named, versioned **Dataset** whose catalog
    record (small JSON, replicated to every live pool like checkpoint
    manifests) persists name, version, producing job, workflow id, the
    input dataset versions it was derived from, a content digest, byte
    size, and the placement map (home node + acked buddy replica);
  * consumers **acquire leases**; the refcount is the set of unexpired
    leases. ``gc()`` reclaims pmem bytes only for datasets that are
    unretained AND lease-free — replacing the blanket end-of-workflow
    scrub. Reclaim keeps the record (minus bytes): lineage queries
    survive garbage collection;
  * placement stays durable across node loss: ``publish`` registers a
    buddy replica through the TieredIO exchange channel, whose ack is
    recorded into the catalog record the moment the transfer is durable.
    ``recoverable(name, lost_nodes)`` then answers "does this dataset
    survive losing those nodes?" from the record alone — zero
    object-store probes, mirroring ``restore_latest_recoverable``;
  * reads fall back to the acked replica (``replica/<home>/<obj>``)
    when the home pool is dead, and admit the tree into the DLM cache
    (when attached) so repeat consumers hit DRAM.

Record schema (``exch/<workflow>/<name>@v<version>.json``):

  {"name", "workflow", "version", "object", "home", "nbytes", "digest",
   "ts", "retained": bool, "reclaimed": bool,
   "lineage": {"job": producing job, "workflow": wf id,
               "inputs": [[name, workflow, version] | ["__external__",
                          external name, 0], ...]},
   "leases": {lease_id: {"owner", "expires", "ts",
                         "released": bool (terminal tombstone)}},
   "acks":   {"replica": {"target", "targets": [nids], "ts"}}}

``acks.replica.targets`` lists EVERY node holding an acknowledged buddy
copy (``target`` is kept for legacy single-replica records); replica
repair (``TieredIO.repair``) prunes targets lost with their nodes and
appends the freshly-placed buddy, so ``recoverable`` stays truthful
across successive node losses.

Storage: log-structured records (B-APM appends, not rewrites)
-------------------------------------------------------------
``publish`` writes the full birth record once as a replicated JSON file
(discovery: ``versions``/``records`` list these, and legacy readers
still merge them), but every subsequent mutation — replica acks, lease
grants, release tombstones, unretain, gc reclaim — is ONE small typed
event APPENDED to the replicated catalog log (``exch/catalog.log``, a
``MetaLog``). ``record()``/``_get_json_merged`` read the log's folded
head state (replay = same reducer, deterministic), falling back to the
cross-pool JSON merge only for pre-log legacy records. GC decisions
(which leases to prune, whether to reclaim) are computed once at
decision time and recorded IN the event, so replay never re-evaluates
clocks. Terminal semantics (``released``/``reclaimed`` win) now follow
from log order instead of tombstone merging — but the tombstones are
still written, so a pool holding only a stale pre-mutation JSON copy
can never resurrect a lease or a reclaimed record.

**Single-writer-per-record contract**: the read-check-then-append
sections (``acquire``'s reclaimed check, ``gc``'s keep/reclaim
decision) serialise on ``self._lock`` — per process only. Concurrent
mutators of the SAME record in different processes are not ordered:
the log's seq-union replay loses no events, but cross-process
check-then-act races (e.g. two gc sweeps deciding from different
snapshots) are the deployment's responsibility to avoid — one catalog
writer per record (in practice: the producing workflow's scheduler
process) is the assumed topology, matching how ``SimCluster`` wires a
single shared catalog.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.annotations import metadata_only
from repro.core.meta_log import MetaLog
from repro.core.object_store import PMemObjectStore, content_digest

#: lineage marker for inputs that came from outside the catalog
EXTERNAL_INPUT = "__external__"

DEFAULT_LEASE_TTL_S = 300.0

#: default clock-skew margin for GC expiry decisions (see DatasetCatalog)
DEFAULT_CLOCK_SKEW_S = 2.0


def ack_targets(rec: Optional[dict]) -> List[str]:
    """The acked replica holders recorded in one ack entry. Modern
    records carry the full ``targets`` list (repair prunes + extends
    it); legacy records carry a single ``target`` — read as a
    one-element list, so every consumer (recoverability checks, replica
    read order, the repair scan) handles both shapes identically."""
    if not rec:
        return []
    targets = rec.get("targets")
    if targets:
        return list(targets)
    target = rec.get("target")
    return [target] if target else []


@dataclass
class Lease:
    """One consumer's hold on a dataset version. The dataset's bytes
    cannot be reclaimed while any unexpired lease exists.

    ``expires`` is stamped with the ACQUIRING node's wall clock;
    ``expired`` here compares against the local clock and is only a
    local-process hint. The authoritative reclaim decision is
    ``DatasetCatalog.gc``, which pads expiry with the catalog's
    ``clock_skew_s`` margin before touching bytes."""
    lease_id: str
    name: str
    workflow: str
    version: int
    owner: str
    expires: float

    def expired(self, now: Optional[float] = None) -> bool:
        return (now if now is not None else time.time()) >= self.expires


def _rec_name(workflow: str, name: str, version: int) -> str:
    return f"exch/{workflow}/{name}@v{version}.json"


def live_pools(stores: Dict[str, PMemObjectStore],
               nodes: Sequence[str]) -> List[str]:
    """Nodes whose pmem is reachable (all of them when none are —
    let the writes themselves surface the outage)."""
    live = [n for n in nodes
            if getattr(stores[n].pool, "alive", True)]
    return list(live or nodes)


def put_json_all_pools(stores: Dict[str, PMemObjectStore],
                       nodes: Sequence[str], name: str, obj: dict) -> int:
    """Replicate a small metadata record to every live pool (the same
    discipline as checkpoint manifests) — shared by catalog records and
    workflow journals. Returns the number of pools written; raises when
    none were reachable."""
    wrote = 0
    for nid in live_pools(stores, nodes):
        try:
            stores[nid].pool.put_json(name, obj)
            wrote += 1
        except IOError:
            continue
    if not wrote:
        raise IOError(f"no reachable pool for metadata {name}")
    return wrote


def read_json_copies(stores: Dict[str, PMemObjectStore],
                     nodes: Sequence[str], name: str) -> List[dict]:
    """All readable pool copies of a replicated record (callers merge
    with their own semantics). Raises the last error when none read."""
    copies: List[dict] = []
    err: Optional[Exception] = None
    for nid in nodes:
        try:
            copies.append(stores[nid].pool.get_json(name))
        except (IOError, FileNotFoundError, ValueError) as e:
            # ValueError: a torn/truncated JSON copy (media damage on
            # one pool — put_json itself commits atomically). The
            # surviving well-formed copies still win the merge.
            err = e
    if not copies:
        raise err if err is not None else FileNotFoundError(name)
    return copies


def cache_key(workflow: str, name: str, version: int) -> str:
    """DLM-cache key for a dataset version (lease-aware eviction keys)."""
    return f"exch/{workflow}/{name}@v{version}"


def _fold_catalog(state: dict, ev: dict) -> None:
    """MetaLog reducer for catalog records: state maps the record name
    (``exch/<wf>/<name>@v<N>.json``) to the full record dict. Every
    event bumps the record's ``ts`` (the old every-update-advances-ts
    rule); records are copy-on-write so readers holding the previous
    dict keep a consistent snapshot. ``gc`` events carry the lease-keep
    list and the reclaim verdict VERBATIM — the decision was made once,
    under the writer's lock, against the writer's clock; replay only
    re-applies it."""
    op, rname = ev["op"], ev["rec"]
    if op == "put":
        rec = dict(ev["record"])
        rec["leases"] = dict(rec.get("leases") or {})
        rec["acks"] = dict(rec.get("acks") or {})
        state[rname] = rec
        return
    old = state.get(rname)
    if old is None:
        return  # event for a record the log never saw (pruned/foreign)
    rec = {**old, "leases": dict(old.get("leases") or {}),
           "acks": dict(old.get("acks") or {}), "ts": ev["ts"]}
    if op == "ack_add":
        targets = sorted(set(ack_targets(rec["acks"].get("replica")))
                         | {ev["target"]})
        rec["acks"]["replica"] = {"target": ev["target"],
                                  "targets": targets, "ts": ev["ts"]}
    elif op == "ack_put":
        rec["acks"]["replica"] = {"target": ev["target"],
                                  "targets": sorted(ev["targets"]),
                                  "ts": ev["ts"]}
    elif op == "lease":
        rec["leases"][ev["lid"]] = {"owner": ev["owner"],
                                    "expires": ev["expires"],
                                    "ts": ev["ts"]}
    elif op == "lease_release":
        old_l = rec["leases"].get(ev["lid"]) or {}
        rec["leases"][ev["lid"]] = {
            "owner": ev["owner"],
            "expires": old_l.get("expires", ev["expires"]),
            "released": True, "ts": ev["ts"]}
    elif op == "unretain":
        rec["retained"] = False
    elif op == "gc":
        keep = set(ev["keep"])
        rec["leases"] = {lid: l for lid, l in rec["leases"].items()
                         if lid in keep}
        if ev.get("reclaimed"):
            rec["reclaimed"] = True
    state[rname] = rec


class DatasetCatalog:
    """Pmem-resident catalog of named, versioned, leased datasets."""

    def __init__(self, stores: Dict[str, PMemObjectStore],
                 exchange=None, cache=None,
                 clock_skew_s: float = DEFAULT_CLOCK_SKEW_S):
        self.stores = stores
        self.nodes = sorted(stores)
        # GC expiry margin: lease `expires` stamps are written with the
        # PRODUCER's wall clock, so a consumer-side gc() must not trust
        # its own clock to the second. A lease is only treated as
        # expired (reclaimable / prunable) once local time passes
        # `expires + clock_skew_s` — bytes are never reclaimed while a
        # lease could still be live on a node up to clock_skew_s ahead.
        self.clock_skew_s = float(clock_skew_s)
        # TieredIO ExchangeChannel (replica fan-out with acks); attached
        # by TieredIO.attach_catalog, or left None for standalone use
        self.exchange = exchange
        self.cache = cache  # DLMCache: read path admits, leases pin
        # serialises every read-check-then-append on a record (see the
        # single-writer-per-record contract in the module docstring);
        # reentrant so gc/acquire can compose reads with event appends
        self._lock = threading.RLock()
        self._lease_seq = itertools.count(1)
        self._leases: Dict[str, Lease] = {}  # issued by THIS process
        self._version_cache: Dict[Tuple[str, str], int] = {}
        # records live in the replicated catalog log: the folded head
        # state IS the cache (replay rebuilds it cold). _rec_cache only
        # fronts legacy pre-log records written via _put_json_all.
        self._rec_cache: Dict[str, dict] = {}
        self._log = MetaLog(stores, self.nodes, "exch/catalog.log",
                            fold=_fold_catalog)
        self.stats = {"published": 0, "reclaimed": 0, "replica_reads": 0}

    # ---- replicated record I/O (same discipline as checkpoint meta) ---
    def _live(self) -> List[str]:
        return live_pools(self.stores, self.nodes)

    def _put_json_all(self, name: str, obj: dict) -> None:
        put_json_all_pools(self.stores, self.nodes, name, obj)
        self._rec_cache[name] = obj

    def _get_json_merged(self, name: str) -> dict:
        """One record's current state: the catalog log's folded head
        state (log replay + cache — the modern path), else the legacy
        cross-pool JSON union merge for records that predate the log
        (newest ``ts`` wins the scalar fields; ``leases`` and ``acks``
        are merged; ``released``/``reclaimed`` stay terminal)."""
        rec = self._log.state().get(name)
        if rec is not None:
            return rec
        cached = self._rec_cache.get(name)
        if cached is not None:
            return cached
        copies = read_json_copies(self.stores, self.nodes, name)
        best = dict(max(copies, key=lambda c: c.get("ts", 0)))
        leases: Dict[str, dict] = {}
        acks: Dict[str, dict] = {}
        for c in copies:
            for lid, rec in (c.get("leases") or {}).items():
                if lid not in leases or \
                        rec.get("ts", 0) > leases[lid].get("ts", 0):
                    leases[lid] = rec
                # release is TERMINAL, like reclaim: a stale pool copy
                # that missed the release write still holds the lease
                # live — without the tombstone winning the merge it
                # would resurrect and block gc() forever
                if rec.get("released"):
                    leases[lid] = {**leases[lid], "released": True}
            for kind, rec in (c.get("acks") or {}).items():
                if kind not in acks or \
                        rec.get("ts", 0) > acks[kind].get("ts", 0):
                    acks[kind] = rec
        # reclaim is terminal: a stale unreclaimed copy on a pool that
        # missed the GC write must not resurrect the bytes' record
        best["reclaimed"] = any(c.get("reclaimed") for c in copies)
        best["leases"], best["acks"] = leases, acks
        return best

    # ---- versions -----------------------------------------------------
    @metadata_only
    def versions(self, name: str, workflow: str) -> List[int]:
        """All published versions of (workflow, name), ascending."""
        prefix = f"exch/{workflow}/"
        out: Set[int] = set()
        tag = f"{name}@v"
        for nid in self.nodes:
            pool = self.stores[nid].pool
            if not getattr(pool, "alive", True):
                continue
            for f in pool.list(prefix):
                base = f[len(prefix):]
                if base.startswith(tag) and base.endswith(".json"):
                    out.add(int(base[len(tag):-len(".json")]))
        return sorted(out)

    @metadata_only
    def latest_version(self, name: str, workflow: str) -> Optional[int]:
        # publishes in this process keep the cache current; a cold
        # process (resume) falls through to the replicated pool records
        v = self._version_cache.get((workflow, name))
        if v is not None:
            return v
        vs = self.versions(name, workflow)
        if vs:
            with self._lock:
                # publish writes this cache under the catalog lock; the
                # cold-path fill must too (lockset discipline)
                self._version_cache[(workflow, name)] = vs[-1]
        return vs[-1] if vs else None

    def exists(self, name: str, workflow: str) -> bool:
        """A record exists for (workflow, name) — including reclaimed
        ones (records outlive bytes). Use ``available`` to ask whether
        the BYTES of the latest version are still held."""
        return self.latest_version(name, workflow) is not None

    def available(self, name: str, workflow: str) -> bool:
        """The latest version's bytes are still held (not reclaimed) —
        the readiness check for consumers; a reclaimed dataset must fall
        back to whatever external/raw copy the caller knows about."""
        try:
            return not self.record(name, workflow).get("reclaimed")
        except (KeyError, IOError, FileNotFoundError):
            return False

    # ---- publish ------------------------------------------------------
    def publish(self, name: str, tree, *, workflow: str = "default",
                producer: Optional[str] = None,
                inputs: Sequence[Sequence] = (),
                node: Optional[str] = None, retained: bool = True,
                replicate: bool = True,
                annotations: Optional[dict] = None,
                on_replica=None) -> dict:
        """Write a new version of ``name``: bytes to the home node's
        store, record to every live pool, buddy replica (acked) through
        the exchange channel. ``inputs`` are lineage refs —
        ``(name, workflow, version)`` tuples or ``(EXTERNAL_INPUT,
        external_name, 0)``. Returns the catalog record.

        ``annotations`` (small JSON dict) persists verbatim in the
        record — the serve tier stamps its session trace id here so one
        session's span tree reconnects across process restarts.
        ``on_replica`` is called (no args, from the replicate task's
        worker thread) after the buddy replica's ack has been recorded —
        the serve tier's spill-to-ack latency probe."""
        with self._lock:
            key = (workflow, name)
            v = self._version_cache.get(key)
            if v is None:
                v = self.latest_version(name, workflow) or 0
            v += 1
            self._version_cache[key] = v
        live = self._live()
        home = node if node in live else live[0]
        obj = f"wf/{workflow}/{name}"
        man = self.stores[home].put(
            obj, tree, version=v,
            meta={"dataset": name, "workflow": workflow, "version": v})
        rec = {
            "name": name, "workflow": workflow, "version": v,
            "object": obj, "home": home, "nbytes": man["nbytes"],
            "digest": content_digest(man), "ts": time.time(),
            "retained": bool(retained), "reclaimed": False,
            "lineage": {"job": producer, "workflow": workflow,
                        "inputs": [list(ref) for ref in inputs]},
            "leases": {}, "acks": {},
        }
        if annotations:
            rec["annotations"] = dict(annotations)
        rname = _rec_name(workflow, name, v)
        # birth record: ONE full JSON write for discovery (versions/
        # records list these files; legacy readers merge them) ...
        self._put_json_all(rname, rec)
        # ... then every mutation is an appended log event; the "put"
        # seeds the log's folded state with the same birth record
        with self._lock:
            self._log.append({"op": "put", "rec": rname, "record": rec,
                              "ts": rec["ts"]})
        self.stats["published"] += 1
        if replicate and self.exchange is not None and len(live) > 1:
            ring = live
            buddy = ring[(ring.index(home) + 1) % len(ring)]
            self.exchange.submit(
                home, obj, buddy, version=v,
                expect_meta={"dataset": name, "version": v},
                on_ack=self._ack_recorder(workflow, name, v, buddy,
                                          then=on_replica))
        return rec

    def _ack_recorder(self, workflow: str, name: str, version: int,
                      target: str, then=None):
        def record(_result) -> None:
            self._append_event(workflow, name, version,
                               {"op": "ack_add", "target": target})
            if then is not None:
                then()
        return record

    def record_repair_ack(self, workflow: str, name: str, version: int,
                          *, target: str, targets: Sequence[str]) -> None:
        """Record a repair's completed re-replication: REPLACES the
        target list (pruning holders lost with their nodes, adding the
        fresh buddy). Runs only after the new copy is durable — the
        RepairChannel calls this from inside the replicate task."""
        self._append_event(workflow, name, version,
                           {"op": "ack_put", "target": target,
                            "targets": sorted(targets)})

    def _append_event(self, workflow: str, name: str, version: int,
                      ev: dict) -> dict:
        """Append one mutation event for a record to the catalog log
        (the replacement for the old read-merge-rewrite of the whole
        JSON record). A record that predates the log is adopted first:
        its legacy cross-pool merge is logged as a ``put`` so the event
        lands on a complete base. Returns the record's new head state."""
        rname = _rec_name(workflow, name, version)
        with self._lock:
            if self._log.state().get(rname) is None:
                base = self._get_json_merged(rname)  # legacy/birth copy
                self._log.append({"op": "put", "rec": rname,
                                  "record": base,
                                  "ts": base.get("ts", time.time())})
            self._log.append({**ev, "rec": rname, "ts": time.time()})
            return self._log.state()[rname]

    # ---- read path ----------------------------------------------------
    @metadata_only
    def record(self, name: str, workflow: str = "default",
               version: Optional[int] = None) -> dict:
        if version is None:
            version = self.latest_version(name, workflow)
            if version is None:
                raise KeyError(f"dataset {workflow}/{name}: never published")
        return self._get_json_merged(_rec_name(workflow, name, version))

    def get(self, name: str, workflow: str = "default",
            version: Optional[int] = None):
        """Read a dataset version: DLM cache, then home pmem, then the
        acked buddy replica (then any node holding one) when the home
        pool is dead or lost the object."""
        rec = self.record(name, workflow, version)
        if rec.get("reclaimed"):
            raise KeyError(f"dataset {workflow}/{name}@v{rec['version']} "
                           f"was reclaimed (lease expired, refcount zero)")
        ckey = cache_key(workflow, name, rec["version"])
        if self.cache is not None:
            hit = self.cache.peek(ckey)
            if hit is not None:
                return hit
        tree = self._read_object(rec)
        if self.cache is not None:
            self.cache.admit(ckey, tree)
        return tree

    def _read_object(self, rec: dict):
        v, obj, home = rec["version"], rec["object"], rec["home"]
        try:
            if self.stores[home].exists(obj, v):
                return self.stores[home].get(obj, v)
        except IOError:
            pass  # home pool dead — fall through to replicas
        rep = f"replica/{home}/{obj}"
        order = ack_targets((rec.get("acks") or {}).get("replica")) + \
            [n for n in self.nodes if n != home]
        seen: Set[str] = set()
        last: Optional[Exception] = None
        for nid in order:
            if nid is None or nid in seen or nid == home:
                continue
            seen.add(nid)
            try:
                if self.stores[nid].exists(rep, v):
                    self.stats["replica_reads"] += 1
                    return self.stores[nid].get(rep, v)
            except IOError as e:
                last = e
        raise KeyError(
            f"dataset {rec['workflow']}/{rec['name']}@v{v}: home {home} "
            f"unreadable and no replica found") from last

    def get_leaf(self, name: str, leaf: str, workflow: str = "default",
                 version: Optional[int] = None) -> "np.ndarray":
        """Byte-range read of ONE leaf of a dataset version — a single
        KV page of a spilled serve session, the ``pos`` cursor — without
        rehydrating the rest of the tree. The read covers exactly that
        leaf's bytes (home pool first, then the ACKED replica holders
        when the home died — never a blind fan-out), decoding only its
        own tiles when the copy travelled wire-encoded. Nothing is
        admitted into the DLM cache. Raises ``KeyError`` for a
        reclaimed dataset or a leaf the object does not carry."""
        rec = self.record(name, workflow, version)
        if rec.get("reclaimed"):
            raise KeyError(f"dataset {workflow}/{name}@v{rec['version']} "
                           f"was reclaimed")
        v, obj, home = rec["version"], rec["object"], rec["home"]
        try:
            if self.stores[home].exists(obj, v):
                return self.stores[home].get_leaf(obj, leaf, v)
        except IOError:
            pass  # home pool dead — fall through to acked replicas
        rep = f"replica/{home}/{obj}"
        last: Optional[Exception] = None
        for nid in ack_targets((rec.get("acks") or {}).get("replica")):
            if nid == home:
                continue
            try:
                if self.stores[nid].exists(rep, v):
                    self.stats["replica_reads"] += 1
                    return self.stores[nid].get_leaf(rep, leaf, v)
            except IOError as e:
                last = e
        raise KeyError(
            f"dataset {workflow}/{name}@v{v} leaf {leaf!r}: home {home} "
            f"unreadable and no acked replica survives") from last

    # ---- recoverability (metadata only — the resume contract) ---------
    @metadata_only
    def recoverable(self, name: str, workflow: str = "default",
                    version: Optional[int] = None,
                    lost_nodes: Sequence[str] = ()) -> bool:
        """Would this dataset survive losing ``lost_nodes``? Decided from
        the catalog record's placement + replica ack alone — ZERO
        object-store probes (``WorkflowScheduler.resume`` ranks whole
        workflows with this, mirroring ``restore_latest_recoverable``)."""
        try:
            rec = self.record(name, workflow, version)
        except (KeyError, IOError, FileNotFoundError):
            return False
        if rec.get("reclaimed"):
            return False
        if rec["home"] not in lost_nodes:
            return True
        targets = ack_targets((rec.get("acks") or {}).get("replica"))
        return any(t not in lost_nodes for t in targets)

    # ---- leases / refcount / GC --------------------------------------
    def acquire(self, name: str, *, workflow: str = "default",
                version: Optional[int] = None, owner: str = "anon",
                ttl_s: float = DEFAULT_LEASE_TTL_S) -> Lease:
        """Take a lease on a dataset version; GC cannot reclaim its bytes
        until every lease is released or expired."""
        rec = self.record(name, workflow, version)
        v = rec["version"]
        lid = f"{owner}-{next(self._lease_seq)}"
        lease = Lease(lid, name, workflow, v, owner, time.time() + ttl_s)
        with self._lock:
            # checked under the catalog lock: a GC that won the race and
            # logged the reclaim must refuse the lease (the check and
            # the lease event are atomic w.r.t. gc's decide-and-append)
            if self.record(name, workflow, v).get("reclaimed"):
                raise KeyError(f"dataset {workflow}/{name}@v{v} "
                               f"already reclaimed")
            self._append_event(workflow, name, v,
                               {"op": "lease", "lid": lid,
                                "owner": owner,
                                "expires": lease.expires})
        self._leases[lid] = lease
        return lease

    def release(self, lease: Lease) -> None:
        """Release a lease by writing a TERMINAL tombstone (``released``,
        like ``reclaimed``) rather than deleting the entry: a pool that
        was down during this write keeps a stale copy with the lease
        still live, and a plain deletion loses against it in the
        cross-pool union — the lease would resurrect and block ``gc()``
        until its far-off expiry. The tombstone keeps the original
        ``expires`` and is pruned by gc once safely past it (when any
        stale live copy is expired too)."""
        self._leases.pop(lease.lease_id, None)
        try:
            self._append_event(lease.workflow, lease.name, lease.version,
                               {"op": "lease_release",
                                "lid": lease.lease_id,
                                "owner": lease.owner,
                                "expires": lease.expires})
        except (IOError, FileNotFoundError):
            pass  # record unreachable — expiry reclaims it eventually

    def refcount(self, name: str, workflow: str = "default",
                 version: Optional[int] = None,
                 now: Optional[float] = None) -> int:
        """Number of unexpired, unreleased leases on the dataset
        version (released tombstones no longer hold the bytes)."""
        rec = self.record(name, workflow, version)
        now = now if now is not None else time.time()
        return sum(1 for l in (rec.get("leases") or {}).values()
                   if l.get("expires", 0) > now and not l.get("released"))

    def unretain(self, name: str, workflow: str = "default",
                 version: Optional[int] = None) -> None:
        """Drop producer retention: the dataset becomes reclaimable as
        soon as its refcount reaches zero."""
        rec = self.record(name, workflow, version)
        self._append_event(workflow, name, rec["version"],
                           {"op": "unretain"})

    def leased_cache_keys(self, now: Optional[float] = None) -> Set[str]:
        """DLM-cache keys of datasets this process holds live leases on
        (TieredIO's lease-aware eviction keeps these DRAM-resident)."""
        now = now if now is not None else time.time()
        return {cache_key(l.workflow, l.name, l.version)
                for l in self._leases.values() if not l.expired(now)}

    def records(self, workflow: Optional[str] = None) -> List[dict]:
        """All catalog records (optionally one workflow's), merged."""
        names: Set[str] = set()
        prefix = f"exch/{workflow}/" if workflow else "exch/"
        for nid in self.nodes:
            pool = self.stores[nid].pool
            if not getattr(pool, "alive", True):
                continue
            names.update(f for f in pool.list(prefix)
                         if f.endswith(".json"))
        return [self._get_json_merged(n) for n in sorted(names)]

    def gc(self, now: Optional[float] = None,
           skew_s: Optional[float] = None) -> List[Tuple[str, str, int]]:
        """Reclaim pmem bytes of every dataset that is unretained AND has
        no unexpired lease. Expired leases are dropped; the record stays
        (marked ``reclaimed``) so lineage survives the bytes. Returns
        the reclaimed ``(workflow, name, version)`` triples.

        **Expiry contract**: lease ``expires`` stamps come from the
        PRODUCER's wall clock; this gc runs on the local one. A lease is
        treated as expired only once ``now > expires + skew_s`` (default
        ``self.clock_skew_s``), so a consumer node up to that margin
        ahead never has bytes reclaimed out from under a live lease.
        Released tombstones are pruned on the same schedule — only after
        any stale still-live pool copy of the lease is expired too, so
        pruning can never let one resurrect.

        The decision runs under the catalog lock against the CURRENT
        head state (not the scan snapshot), is recorded verbatim in the
        appended ``gc`` event (keep-list + reclaim verdict — replay
        re-applies the decision, never re-evaluates clocks), and the
        terminal ``reclaimed`` mark lands BEFORE any bytes are deleted —
        a lease acquired concurrently either lands first (and defers
        reclaim) or sees ``reclaimed`` and is refused; it is never
        silently destroyed."""
        now = now if now is not None else time.time()
        margin = self.clock_skew_s if skew_s is None else float(skew_s)
        reclaimed: List[Tuple[str, str, int]] = []
        for rec in self.records():
            if rec.get("reclaimed"):
                continue
            try:
                with self._lock:
                    # decide against the CURRENT head state (a lease may
                    # have landed since the scan snapshot)
                    r = self.record(rec["name"], rec["workflow"],
                                    rec["version"])
                    if r.get("reclaimed"):
                        continue
                    leases = r.get("leases") or {}
                    # keep everything not safely past expiry (skew
                    # margin), tombstones included; live = the subset
                    # actually holding the bytes (unexpired AND
                    # unreleased)
                    keep = {lid: l for lid, l in leases.items()
                            if l.get("expires", 0) + margin > now}
                    live = {lid: l for lid, l in keep.items()
                            if not l.get("released")}
                    reclaim = not r.get("retained") and not live
                    if reclaim or len(keep) != len(leases):
                        self._append_event(
                            rec["workflow"], rec["name"], rec["version"],
                            {"op": "gc", "keep": sorted(keep),
                             "reclaimed": reclaim})
            except (IOError, FileNotFoundError, KeyError):
                continue  # record unreachable right now — next sweep
            if reclaim:
                self._delete_bytes(rec)
                reclaimed.append(
                    (rec["workflow"], rec["name"], rec["version"]))
                self.stats["reclaimed"] += 1
        return reclaimed

    def _delete_bytes(self, rec: dict) -> None:
        v, obj, home = rec["version"], rec["object"], rec["home"]
        for nid, name in [(home, obj)] + \
                [(n, f"replica/{home}/{obj}") for n in self.nodes
                 if n != home]:
            try:
                if self.stores[nid].exists(name, v):
                    self.stores[nid].delete(name, v)
            except IOError:
                continue  # dead pool: its bytes died with it
        if self.cache is not None:
            self.cache.drop(cache_key(rec["workflow"], rec["name"], v))

    # ---- lineage ------------------------------------------------------
    def lineage(self, name: str, workflow: str = "default",
                version: Optional[int] = None) -> List[dict]:
        """The transitive derivation chain of a dataset version, root
        inputs last: each entry is the catalog record (which persists
        producing job, workflow, input versions and content digest).
        External inputs appear as ``{"external": <name>}`` markers.
        Works on reclaimed datasets too — records outlive bytes."""
        out: List[dict] = []
        seen: Set[Tuple[str, str, int]] = set()
        queue: List[Tuple[str, str, Optional[int]]] = [
            (name, workflow, version)]
        while queue:
            n, wf, v = queue.pop(0)
            try:
                rec = self.record(n, wf, v)
            except (KeyError, FileNotFoundError):
                continue
            key = (rec["workflow"], rec["name"], rec["version"])
            if key in seen:
                continue
            seen.add(key)
            out.append(rec)
            for ref in rec["lineage"]["inputs"]:
                if ref and ref[0] == EXTERNAL_INPUT:
                    marker = {"external": ref[1]}
                    if marker not in out:
                        out.append(marker)
                elif ref:
                    queue.append((ref[0], ref[1], ref[2]))
        return out
