"""Per-node asynchronous data scheduler (the paper's §V-B).

A daemon per node moves data without blocking the application:
  stage_in   - external store -> node pmem (burst-buffer pre-load, Fig. 8)
  drain      - node pmem -> external store (async checkpoint flush)
  replicate  - node pmem -> buddy-node pmem (the paper's remote B-APM
               access over the fabric; used for failure tolerance)

Work items run on per-node worker threads with priority queues; idle nodes
can *steal* stage-in work from overloaded ones (straggler mitigation,
core/resilience.py). Byte counters per channel feed the benchmarks.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

from repro.analysis.annotations import rehydration_entry
# SupersededError and _check_expect_meta live with the copy primitives
# in object_store now; re-exported here for the existing import sites
from repro.core.object_store import (PMemObjectStore,  # noqa: F401
                                     SupersededError, _check_expect_meta,
                                     copy_object, export_object,
                                     import_object, is_wire_object)
from repro.obs.metrics import Registry, StatsView


class ExternalStore:
    """The 'external high performance filesystem' of Fig. 4 (emulated as a
    directory with configurable artificial bandwidth for benchmarks)."""

    def __init__(self, root: Path, bandwidth_bytes_s: Optional[float] = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.bandwidth = bandwidth_bytes_s

    def _throttle(self, nbytes: int) -> None:
        if self.bandwidth:
            time.sleep(nbytes / self.bandwidth)

    def put(self, name: str, tree) -> None:
        import pickle
        p = self.root / (name.replace("/", "_") + ".pkl")
        data = pickle.dumps(tree)
        self._throttle(len(data))
        tmp = p.with_suffix(".tmp")
        tmp.write_bytes(data)
        tmp.replace(p)

    def get(self, name: str):
        import pickle
        p = self.root / (name.replace("/", "_") + ".pkl")
        data = p.read_bytes()
        self._throttle(len(data))
        return pickle.loads(data)

    def exists(self, name: str) -> bool:
        return (self.root / (name.replace("/", "_") + ".pkl")).exists()


@dataclass(order=True)
class _Task:
    priority: int
    seq: int
    fn: Callable = field(compare=False)
    future: Future = field(compare=False)


class DataScheduler:
    """Async movement daemons over {node_id -> PMemObjectStore}."""

    def __init__(self, stores: Dict[str, PMemObjectStore],
                 external: ExternalStore, workers_per_node: int = 1,
                 obs=None):
        self.stores = stores
        self.external = external
        self.obs = obs
        self.queues: Dict[str, "queue.PriorityQueue[_Task]"] = {
            nid: queue.PriorityQueue() for nid in stores}
        # per-channel byte counters live in the telemetry registry;
        # ``stats`` keeps the legacy dict shape as a read-through view.
        # Workers update the internally-locked counters directly, which
        # retires the old unguarded ``self.stats[nid][...] += n`` writes
        reg = obs.registry if obs is not None else Registry()
        self._counters = {
            nid: {k: reg.counter(f"sched.{k}_bytes.{nid}")
                  for k in ("staged_in", "drained", "replicated")}
            for nid in stores}
        self.stats = {nid: StatsView(self._counters[nid])
                      for nid in stores}
        self._depth = {nid: reg.gauge(f"sched.queue_depth.{nid}")
                       for nid in stores}
        self._qwait = reg.histogram("sched.queue_wait_s")
        self._task_s = reg.histogram("sched.task_s")
        self._seq = 0
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._lock = threading.Lock()
        for nid in stores:
            for w in range(workers_per_node):
                t = threading.Thread(target=self._worker, args=(nid,),
                                     daemon=True, name=f"dsched-{nid}-{w}")
                t.start()
                self._threads.append(t)

    # ---- worker loop with work stealing ----
    def _worker(self, nid: str) -> None:
        while not self._stop.is_set():
            task = self._next_task(nid)
            if task is None:
                time.sleep(0.002)
                continue
            try:
                task.future.set_result(task.fn())
            except Exception as e:  # surfaced via the future
                task.future.set_exception(e)

    def _next_task(self, nid: str) -> Optional[_Task]:
        try:
            return self.queues[nid].get_nowait()
        except queue.Empty:
            pass
        # steal from the deepest queue (straggler mitigation)
        victim = max(self.queues, key=lambda n: self.queues[n].qsize())
        if victim != nid and self.queues[victim].qsize() > 1:
            try:
                return self.queues[victim].get_nowait()
            except queue.Empty:
                return None
        return None

    def _submit(self, nid: str, fn: Callable, priority: int,
                label: str = "task",
                span: Optional[dict] = None) -> Future:
        fut: Future = Future()
        with self._lock:
            self._seq += 1
            seq = self._seq
        obs = self.obs
        t_enq = time.time()

        def run():
            # queue-depth/wait instruments + (when a caller threaded a
            # trace context through ``span=``) a child span bracketing
            # the task body on the executing node's flight ring
            self._qwait.observe(time.time() - t_enq)
            self._depth[nid].dec()
            sp = None
            if obs is not None and span is not None:
                sp = obs.begin(f"sched.{label}", node=nid,
                               trace=span.get("trace"),
                               parent=span.get("span", 0))
            t0 = time.time()
            try:
                out = fn()
            except Exception:
                self._task_s.observe(time.time() - t0)
                if sp is not None:
                    obs.end(sp, status="error")
                raise
            self._task_s.observe(time.time() - t0)
            if sp is not None:
                obs.end(sp)
            return out

        self._depth[nid].inc()
        self.queues[nid].put(_Task(priority, seq, run, fut))
        return fut

    # ---- public channels ----
    @rehydration_entry
    def stage_in(self, nid: str, external_name: str, obj_name: str,
                 version: int = 0, priority: int = 0,
                 meta: Optional[dict] = None,
                 on_complete: Optional[Callable[[Any], None]] = None,
                 span: Optional[dict] = None) -> Future:
        """External -> pmem pre-load. ``meta`` stamps the staged object
        (drain-tier rehydration stages a checkpoint shard back and must
        carry its step tag so restore's slot-reuse check still holds);
        ``on_complete`` runs inside the task once the pmem copy is
        durable — same ack discipline as replicate/drain. A wire payload
        (the drain channel's export format) ingests through
        ``import_object`` — leaf bytes land at manifest offsets with the
        carried manifest committed over them, no tree is ever built, and
        an encoded payload stays encoded (decoded on demand by readers);
        legacy pickled trees still go through ``put``."""
        def go():
            obj = self.external.get(external_name)
            if is_wire_object(obj):
                man = import_object(self.stores[nid], obj, obj_name,
                                    version, meta_update=meta)
            else:
                man = self.stores[nid].put(obj_name, obj, version,
                                           meta=meta)
            self._counters[nid]["staged_in"].inc(man["nbytes"])
            if on_complete is not None:
                on_complete(man)
            return man
        return self._submit(nid, go, priority, label="stage_in",
                            span=span)

    @rehydration_entry
    def drain(self, nid: str, obj_name: str, external_name: str,
              version: int = 0, priority: int = 1,
              delete_after: bool = False,
              expect_meta: Optional[dict] = None,
              on_complete: Optional[Callable[[Any], None]] = None,
              codec=None,
              span: Optional[dict] = None) -> Future:
        def go():
            # zero-copy export against ONE manifest snapshot: leaf bytes
            # stream out CRC-verified (a concurrent slot reuse raises
            # SupersededError instead of draining torn bytes) and are
            # serialized exactly ONCE, at the external boundary below;
            # ``expect_meta`` additionally pins the object identity
            # (e.g. checkpoint step) the caller intended. ``codec``
            # engages the delta-int8 wire codec on the exported bytes.
            wire = export_object(self.stores[nid], obj_name, version,
                                 expect_meta=expect_meta, codec=codec,
                                 obs=self.obs)
            self.external.put(external_name, wire)
            self._counters[nid]["drained"].inc(
                wire["manifest"]["nbytes"])
            if delete_after:
                self.stores[nid].delete(obj_name, version)
            # ack hook: runs INSIDE the task, after the external copy is
            # durable, so a recorded ack always describes a finished
            # transfer; if recording fails, the task (and its future)
            # fails and no one can mistake the step for drained.
            if on_complete is not None:
                on_complete(external_name)
            return external_name
        return self._submit(nid, go, priority, label="drain",
                            span=span)

    @rehydration_entry
    def replicate(self, src: str, obj_name: str, dst: str,
                  version: int = 0, priority: int = 2,
                  dst_name: Optional[str] = None,
                  expect_meta: Optional[dict] = None,
                  on_complete: Optional[Callable[[Any], None]] = None,
                  codec=None,
                  span: Optional[dict] = None) -> Future:
        """Copy an object to another node's pmem under ``dst_name``
        (defaults to replica/<src>/<obj> so it never shadows the
        destination's own objects). ``expect_meta`` pins the object
        identity the caller intended (e.g. the checkpoint step);
        ``on_complete`` runs inside the task once the replica is placed —
        the replication channel uses it to record per-node acks.
        ``codec`` engages the delta-int8 wire codec at the source (an
        already-encoded source raw-streams, never double-encodes)."""
        name = dst_name or f"replica/{src}/{obj_name}"

        def go():
            # zero-copy raw path against ONE manifest snapshot: region
            # bytes stream src -> dst in bounded chunks with a rolling
            # CRC checked against the manifest's own leaf CRCs, and the
            # source manifest commits verbatim on dst. No tree is ever
            # materialized and no CRC recomputed. A concurrent source
            # overwrite (checkpoint slot reuse racing this queued task)
            # raises SupersededError before the manifest commit — the
            # overwriting save queues its own replicate, so dropping
            # this one is benign (filtered at join). Destination-side
            # failures (dead pool, capacity) still propagate as real
            # errors. replica_of records the ORIGIN node: when repair
            # copies an existing replica off a surviving holder, the
            # source meta already carries the origin — preserve it, so
            # a twice-moved replica still says whose data it is.
            man = copy_object(
                self.stores[src], self.stores[dst], obj_name, version,
                dst_name=name, expect_meta=expect_meta, codec=codec,
                meta_update=lambda m: {
                    "replica_of": m.get("replica_of", src)},
                obs=self.obs)
            self._counters[src]["replicated"].inc(man["nbytes"])
            # ack hook after the replica is durable on ``dst`` — a
            # failure here fails the task, never records a false ack
            if on_complete is not None:
                on_complete(man)
            return man
        return self._submit(src, go, priority, label="replicate",
                            span=span)

    def run_job(self, nid: str, fn: Callable, priority: int = 3,
                span: Optional[dict] = None) -> Future:
        """Compute channel: run a workflow job body on node ``nid``'s
        worker. Jobs ride the same priority queues as data movement
        (movement outranks them) and the same work stealing, so ready
        jobs placed on different nodes genuinely run concurrently while
        an overloaded node's backlog can drain elsewhere."""
        return self._submit(nid, fn, priority, label="run_job",
                            span=span)

    def queue_depth(self, nid: str) -> int:
        return self.queues[nid].qsize()

    def shutdown(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=2.0)
