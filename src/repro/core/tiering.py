"""SLM / DLM memory-mode policies (paper §II-B).

SLM (single-level): DRAM and B-APM are two explicit spaces. ``SLMTier``
places chosen pytree leaves in pmem and stages them in/out explicitly at
step boundaries — used for optimizer-state offload and cold KV pages.

DLM (dual-level): DRAM acts as a transparent cache over pmem. ``DLMCache``
is an LRU write-back cache keyed by object name — readers always use
``get``; eviction spills to pmem; nothing else changes for the caller.
The mode is selected per job by the workflow scheduler (paper §V-A item 9).
"""
from __future__ import annotations

import collections
import random
import threading
import time
from typing import (Any, Callable, Container, Dict, Iterable, List,
                    Optional, Tuple)

import numpy as np

from repro.core.object_store import PMemObjectStore, _flatten, _unflatten


class SLMTier:
    """Explicit two-space placement: leaves listed in ``pmem_leaves`` live
    in the pool; the rest stay in DRAM (the returned pytree).

    Every ``offload`` writes under a fresh per-tier version (threaded
    through the store's version namespace AND stamped in the object
    meta), and ``fetch`` validates both before merging — a racing
    offload from another tier instance over the same store/name can no
    longer be silently merged into this instance's resident tree."""

    def __init__(self, store: PMemObjectStore, name: str):
        self.store = store
        self.name = name
        self._placed: Dict[str, int] = {}  # leaf path -> version tag
        self._version: Optional[int] = None  # store version of last offload
        # superseded-version reclaims that failed (leaked pmem bytes);
        # surfaced so operators can see garbage accumulating instead of
        # the failure vanishing in an except
        self.cleanup_failures = 0

    def offload(self, tree, leaf_paths: Iterable[str]):
        """Move selected leaves to pmem; returns (resident_tree, handle).
        Offloaded leaves are replaced by None placeholders."""
        paths = set(leaf_paths)
        leaves = dict(_flatten(tree))
        off = {p: leaves[p] for p in paths if p in leaves}
        version = random.getrandbits(31) or 1
        # reclaim the version we supersede — ours, or (after a process
        # restart, when _version is gone) the one the head points at
        prev = self._version
        if prev is None:
            try:
                prev = self.store.pool.get_json(
                    f"slm/{self.name}.head.json")["v"]
            except (IOError, FileNotFoundError, KeyError):
                prev = None
        self.store.put(f"slm/{self.name}", off, version=version,
                       meta={"v": version})
        # head pointer: offloaded state must survive a PROCESS restart
        # (the point of B-APM offload) — a fresh tier instance resolves
        # the current version from here instead of guessing
        self.store.pool.put_json(f"slm/{self.name}.head.json",
                                 {"v": version})
        if prev is not None and prev != version:
            try:
                self.store.delete(f"slm/{self.name}", prev)
            except OSError:
                # the NEW version is already committed (head points at
                # it); a failed reclaim only leaks the old bytes —
                # count it rather than losing the signal
                self.cleanup_failures += 1
        self._version = version
        resident = {p: v for p, v in leaves.items() if p not in paths}
        self._placed = {p: version for p in off}
        return _unflatten(resident), sorted(off)

    def fetch(self, resident_tree, handle: List[str]):
        """Stage offloaded leaves back in and merge with the resident
        part. Fails loudly if the pmem object is not the one THIS tier
        placed (racing offload / tampered version tag). A fresh instance
        (post-restart) adopts the persisted head pointer's version."""
        name = f"slm/{self.name}"
        if self._version is None:
            try:  # restart recovery: adopt the last committed offload
                self._version = self.store.pool.get_json(
                    f"{name}.head.json")["v"]
            except (IOError, FileNotFoundError, KeyError):
                raise RuntimeError(f"{name}: nothing offloaded")
        try:
            off_tree, man = self.store.get_with_manifest(
                name, version=self._version)
        except FileNotFoundError as e:
            raise IOError(
                f"{name}@v{self._version}: offloaded leaves vanished "
                f"(deleted or overwritten by a racing tier instance)"
            ) from e
        got = man.get("meta", {}).get("v")
        if got != self._version:
            raise IOError(
                f"{name}: version mismatch (placed v{self._version}, "
                f"found v{got}) — racing offload from another tier "
                f"instance")
        off = dict(_flatten(off_tree))
        leaves = dict(_flatten(resident_tree))
        leaves.update(off)
        return _unflatten(leaves)


class DLMCache:
    """LRU DRAM cache over a pmem object store (write-back).

    Occupancy is tracked as a RUNNING byte total (O(1) per admission,
    not O(n) re-sums per eviction), and objects larger than the whole
    capacity BYPASS DRAM: a ``put`` writes them straight through to
    pmem and a ``get`` serves them uncached, so one oversized object can
    never leave the cache permanently over budget. ``fallback_reader``
    (wired by TieredIO) serves misses from a buddy node's replica when
    the home pool is dead — the multi-node DLM read path."""

    def __init__(self, store: PMemObjectStore, capacity_bytes: int,
                 fallback_reader: Optional[Callable[[str], Any]] = None,
                 on_writeback: Optional[Callable[[str], None]] = None,
                 protected: Optional[Callable[[], Container[str]]] = None,
                 obs=None):
        from repro.obs.metrics import Registry
        self.store = store
        self.capacity = capacity_bytes
        self.fallback_reader = fallback_reader
        # lease-pinned admission: a callable returning the names that
        # capacity-pressure LRU eviction must skip (TieredIO wires the
        # catalog's actively-leased cache keys here, so admitting a new
        # object never pushes a mid-lease consumer's — or a live serve
        # session's — working set out of DRAM). ``evict_cold`` has its
        # own explicit ``keep`` parameter; this guards the implicit
        # evictions ``put``/``admit``/``get`` perform under pressure.
        # When every resident entry is protected the admission proceeds
        # over budget (like the oversized bypass, pressure is visible in
        # ``dlm.used_bytes``) rather than evicting a pinned entry.
        self.protected = protected
        # called with the object name after every durable write-back to
        # pmem (dirty eviction, flush, oversized bypass). TieredIO wires
        # it to queue a buddy replica + ack, so the replica tier tracks
        # every durable write instead of only the first offload — a
        # mutated object's buddy copy must never serve stale bytes after
        # the home pool dies. Must not call back into this cache.
        self.on_writeback = on_writeback
        self._cache: "collections.OrderedDict[str, Any]" = \
            collections.OrderedDict()
        self._sizes: Dict[str, int] = {}
        self._used = 0  # running sum of _sizes (cache-occupancy bytes)
        self._dirty: Dict[str, bool] = {}
        self._last_used: Dict[str, float] = {}
        self._gen: Dict[str, int] = {}  # bumped on put/evict (TOCTOU)
        self._lock = threading.RLock()
        # registry-backed counters; the legacy int attributes survive as
        # read-through properties so callers/tests keep reading ints
        reg = obs.registry if obs is not None else Registry()
        self._counters = {k: reg.counter(f"dlm.{k}")
                          for k in ("hits", "misses", "evictions",
                                    "prefetches", "prefetch_hits",
                                    "bypasses")}
        self._g_used = reg.gauge("dlm.used_bytes")

    @property
    def hits(self) -> int:
        return self._counters["hits"].value

    @property
    def misses(self) -> int:
        return self._counters["misses"].value

    @property
    def evictions(self) -> int:
        return self._counters["evictions"].value

    @property
    def prefetches(self) -> int:
        return self._counters["prefetches"].value

    @property
    def prefetch_hits(self) -> int:
        return self._counters["prefetch_hits"].value

    @property
    def bypasses(self) -> int:
        # oversized objects served/persisted uncached
        return self._counters["bypasses"].value

    def _bytes(self, tree) -> int:
        return sum(np.asarray(a).nbytes for _, a in _flatten(tree))

    def used_bytes(self) -> int:
        with self._lock:
            return self._used

    def _evict_one(self, name: str) -> None:
        """Drop ``name`` from DRAM (write-back if dirty). Lock held."""
        tree = self._cache.pop(name)
        if self._dirty.pop(name, False):
            self.store.put(f"dlm/{name}", tree)  # write-back
            if self.on_writeback is not None:
                self.on_writeback(name)
        self._used -= self._sizes.pop(name, 0)
        self._g_used.set(self._used)
        self._last_used.pop(name, None)
        self._gen[name] = self._gen.get(name, 0) + 1
        self._counters["evictions"].inc()

    def _evict_until_fits(self, incoming: int) -> None:
        pinned: Container[str] = ()
        if self.protected is not None:
            pinned = self.protected() or ()
        while self._cache and self._used + incoming > self.capacity:
            victim = next((n for n in self._cache if n not in pinned),
                          None)  # LRU order, pinned entries skipped
            if victim is None:
                return  # everything resident is pinned: admit over budget
            self._evict_one(victim)

    def _drop_stale(self, name: str) -> None:
        """Remove a superseded entry WITHOUT write-back (the caller is
        replacing it); keeps the running total exact. Lock held."""
        if name in self._cache:
            self._cache.pop(name)
            self._used -= self._sizes.pop(name, 0)
            self._g_used.set(self._used)
            self._dirty.pop(name, None)
            self._last_used.pop(name, None)

    def _insert(self, name: str, tree, nb: int, dirty: bool) -> None:
        """Admit ``name`` (lock held); caller has checked nb <= capacity."""
        self._drop_stale(name)
        self._evict_until_fits(nb)
        self._cache[name] = tree
        self._sizes[name] = nb
        self._used += nb
        self._g_used.set(self._used)
        self._dirty[name] = dirty
        self._last_used[name] = time.time()

    def put(self, name: str, tree) -> None:
        with self._lock:
            nb = self._bytes(tree)
            self._gen[name] = self._gen.get(name, 0) + 1
            if nb > self.capacity:
                # oversized: would evict EVERYTHING and still not fit —
                # bypass DRAM, persist straight to pmem (write-back now)
                self._drop_stale(name)
                self.store.put(f"dlm/{name}", tree)
                if self.on_writeback is not None:
                    self.on_writeback(name)
                self._counters["bypasses"].inc()
                return
            self._insert(name, tree, nb, dirty=True)

    def _read_through(self, name: str):
        """Pmem read with buddy-replica fallback when the home store is
        UNREACHABLE. A plain miss on a live pool (FileNotFoundError)
        fails fast — fanning the fabric out for every never-written name
        would multiply miss-path metadata traffic by node count."""
        try:
            return self.store.get(f"dlm/{name}")
        except FileNotFoundError:
            raise
        except IOError:
            if self.fallback_reader is None:
                raise
            return self.fallback_reader(name)

    def get(self, name: str):
        with self._lock:
            if name in self._cache:
                self._counters["hits"].inc()
                self._cache.move_to_end(name)
                self._last_used[name] = time.time()
                return self._cache[name]
            self._counters["misses"].inc()
            tree = self._read_through(name)
            nb = self._bytes(tree)
            if nb > self.capacity:
                self._counters["bypasses"].inc()  # serve uncached
                return tree
            self._insert(name, tree, nb, dirty=False)
            return tree

    def contains(self, name: str) -> bool:
        with self._lock:
            return name in self._cache

    def admit(self, name: str, tree) -> None:
        """Insert a CLEAN entry loaded by an external reader (the
        dataset-exchange read path): cached for reuse and simply dropped
        at eviction — never written back to ``dlm/``, since the reader
        owns the persistent copy. Oversized trees bypass DRAM."""
        with self._lock:
            nb = self._bytes(tree)
            self._gen[name] = self._gen.get(name, 0) + 1
            if nb > self.capacity:
                self._counters["bypasses"].inc()
                return
            self._insert(name, tree, nb, dirty=False)

    def peek(self, name: str):
        """The cached entry or None — no read-through (the caller owns
        the miss path, e.g. the catalog's home/replica resolution)."""
        with self._lock:
            if name in self._cache:
                self._counters["hits"].inc()
                self._cache.move_to_end(name)
                self._last_used[name] = time.time()
                return self._cache[name]
            self._counters["misses"].inc()
            return None

    def drop(self, name: str) -> None:
        """Forget an entry without write-back (its backing object was
        reclaimed — writing back would resurrect deleted bytes)."""
        with self._lock:
            self._gen[name] = self._gen.get(name, 0) + 1
            self._drop_stale(name)

    def prefetch(self, name: str) -> bool:
        """Warm ``name`` into DRAM without counting toward hit/miss demand
        stats. Returns True when the entry was already resident (a
        prefetch hit). Used by TieredIO to hide pmem->DRAM latency.

        The pmem read happens OUTSIDE the lock — a background warm must
        not stall concurrent demand gets on the serving hot path."""
        with self._lock:
            self._counters["prefetches"].inc()
            if name in self._cache:
                self._counters["prefetch_hits"].inc()
                self._cache.move_to_end(name)
                self._last_used[name] = time.time()  # warm != cold
                return True
            gen = self._gen.get(name, 0)
        tree = self._read_through(name)
        with self._lock:
            # insert only if nobody touched the entry while we read pmem
            # (a concurrent put+evict would make our snapshot stale)
            if name not in self._cache and \
                    self._gen.get(name, 0) == gen:
                nb = self._bytes(tree)
                if nb > self.capacity:
                    self._counters["bypasses"].inc()  # warmed bytes stay in pmem only
                else:
                    self._insert(name, tree, nb, dirty=False)
            return False

    def evict_cold(self, max_idle_s: float = 0.0,
                   now: Optional[float] = None,
                   keep: Container[str] = ()) -> int:
        """Spill entries idle for > ``max_idle_s`` back to pmem and drop
        them from DRAM (write-back for dirty ones). Returns the number of
        entries evicted. ``max_idle_s=0`` evicts everything. Names in
        ``keep`` are never evicted — TieredIO passes the catalog's
        actively-leased dataset keys here, so a consumer mid-lease keeps
        its working set DRAM-resident across cold sweeps."""
        now = now if now is not None else time.time()
        with self._lock:
            cold = [n for n, ts in self._last_used.items()
                    if now - ts >= max_idle_s and n not in keep]
            for name in cold:
                self._evict_one(name)
            return len(cold)

    def flush(self, name: Optional[str] = None) -> None:
        """Write back dirty entries — all of them, or just ``name`` (so a
        single-object persist doesn't rewrite the whole cache while
        holding the lock)."""
        with self._lock:
            targets = [name] if name is not None else list(self._cache)
            for n in targets:
                if self._dirty.get(n) and n in self._cache:
                    self.store.put(f"dlm/{n}", self._cache[n])
                    self._dirty[n] = False
                    if self.on_writeback is not None:
                        self.on_writeback(n)


class TieredKVCache:
    """Paged KV spill tier for serving: hot pages in DRAM (DLM-cached),
    cold pages in pmem — the long-context serving use of the paper's
    memory hierarchy (serve/engine.py)."""

    def __init__(self, store: PMemObjectStore, dram_capacity_bytes: int):
        self.cache = DLMCache(store, dram_capacity_bytes)

    @staticmethod
    def page_name(seq_id: int, layer: int, page: int) -> str:
        return f"kv/{seq_id}/{layer}/{page}"

    def put_page(self, seq_id: int, layer: int, page: int, kv) -> None:
        self.cache.put(self.page_name(seq_id, layer, page), kv)

    def get_page(self, seq_id: int, layer: int, page: int):
        return self.cache.get(self.page_name(seq_id, layer, page))

    def prefetch_page(self, seq_id: int, layer: int, page: int) -> bool:
        return self.cache.prefetch(self.page_name(seq_id, layer, page))

    def evict_cold(self, max_idle_s: float = 0.0) -> int:
        return self.cache.evict_cold(max_idle_s)

    @property
    def stats(self):
        return {"hits": self.cache.hits, "misses": self.cache.misses,
                "evictions": self.cache.evictions,
                "prefetches": self.cache.prefetches,
                "prefetch_hits": self.cache.prefetch_hits,
                "bypasses": self.cache.bypasses}
