"""SLM / DLM memory-mode policies (paper §II-B).

SLM (single-level): DRAM and B-APM are two explicit spaces. ``SLMTier``
places chosen pytree leaves in pmem and stages them in/out explicitly at
step boundaries — used for optimizer-state offload and cold KV pages.

DLM (dual-level): DRAM acts as a transparent cache over pmem. ``DLMCache``
is an LRU write-back cache keyed by object name — readers always use
``get``; eviction spills to pmem; nothing else changes for the caller.
The mode is selected per job by the workflow scheduler (paper §V-A item 9).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.object_store import PMemObjectStore, _flatten, _unflatten


class SLMTier:
    """Explicit two-space placement: leaves listed in ``pmem_leaves`` live
    in the pool; the rest stay in DRAM (the returned pytree)."""

    def __init__(self, store: PMemObjectStore, name: str):
        self.store = store
        self.name = name
        self._placed: Dict[str, int] = {}  # leaf path -> version counter

    def offload(self, tree, leaf_paths: Iterable[str]):
        """Move selected leaves to pmem; returns (resident_tree, handle).
        Offloaded leaves are replaced by None placeholders."""
        paths = set(leaf_paths)
        leaves = dict(_flatten(tree))
        off = {p: leaves[p] for p in paths if p in leaves}
        version = int(time.time() * 1e6) % (1 << 31)
        self.store.put(f"slm/{self.name}", off, version=0,
                       meta={"v": version})
        resident = {p: v for p, v in leaves.items() if p not in paths}
        self._placed = {p: version for p in off}
        return _unflatten(resident), sorted(off)

    def fetch(self, resident_tree, handle: List[str]):
        """Stage offloaded leaves back in and merge with the resident part."""
        off = dict(_flatten(self.store.get(f"slm/{self.name}")))
        leaves = dict(_flatten(resident_tree))
        leaves.update(off)
        return _unflatten(leaves)


class DLMCache:
    """LRU DRAM cache over a pmem object store (write-back)."""

    def __init__(self, store: PMemObjectStore, capacity_bytes: int):
        self.store = store
        self.capacity = capacity_bytes
        self._cache: "collections.OrderedDict[str, Any]" = \
            collections.OrderedDict()
        self._sizes: Dict[str, int] = {}
        self._dirty: Dict[str, bool] = {}
        self._last_used: Dict[str, float] = {}
        self._gen: Dict[str, int] = {}  # bumped on put/evict (TOCTOU)
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.prefetches = 0
        self.prefetch_hits = 0

    def _bytes(self, tree) -> int:
        return sum(np.asarray(a).nbytes for _, a in _flatten(tree))

    def _evict_one(self, name: str) -> None:
        """Drop ``name`` from DRAM (write-back if dirty). Lock held."""
        tree = self._cache.pop(name)
        if self._dirty.pop(name, False):
            self.store.put(f"dlm/{name}", tree)  # write-back
        self._sizes.pop(name, None)
        self._last_used.pop(name, None)
        self._gen[name] = self._gen.get(name, 0) + 1
        self.evictions += 1

    def _evict_until_fits(self, incoming: int) -> None:
        while self._cache and \
                sum(self._sizes.values()) + incoming > self.capacity:
            self._evict_one(next(iter(self._cache)))  # LRU head

    def put(self, name: str, tree) -> None:
        with self._lock:
            nb = self._bytes(tree)
            self._evict_until_fits(nb)
            self._cache[name] = tree
            self._cache.move_to_end(name)
            self._sizes[name] = nb
            self._dirty[name] = True
            self._last_used[name] = time.time()
            self._gen[name] = self._gen.get(name, 0) + 1

    def get(self, name: str):
        with self._lock:
            if name in self._cache:
                self.hits += 1
                self._cache.move_to_end(name)
                self._last_used[name] = time.time()
                return self._cache[name]
            self.misses += 1
            tree = self.store.get(f"dlm/{name}")
            nb = self._bytes(tree)
            self._evict_until_fits(nb)
            self._cache[name] = tree
            self._sizes[name] = nb
            self._dirty[name] = False
            self._last_used[name] = time.time()
            return tree

    def contains(self, name: str) -> bool:
        with self._lock:
            return name in self._cache

    def prefetch(self, name: str) -> bool:
        """Warm ``name`` into DRAM without counting toward hit/miss demand
        stats. Returns True when the entry was already resident (a
        prefetch hit). Used by TieredIO to hide pmem->DRAM latency.

        The pmem read happens OUTSIDE the lock — a background warm must
        not stall concurrent demand gets on the serving hot path."""
        with self._lock:
            self.prefetches += 1
            if name in self._cache:
                self.prefetch_hits += 1
                self._cache.move_to_end(name)
                self._last_used[name] = time.time()  # warm != cold
                return True
            gen = self._gen.get(name, 0)
        tree = self.store.get(f"dlm/{name}")
        with self._lock:
            # insert only if nobody touched the entry while we read pmem
            # (a concurrent put+evict would make our snapshot stale)
            if name not in self._cache and \
                    self._gen.get(name, 0) == gen:
                nb = self._bytes(tree)
                self._evict_until_fits(nb)
                self._cache[name] = tree
                self._sizes[name] = nb
                self._dirty[name] = False
                self._last_used[name] = time.time()
            return False

    def evict_cold(self, max_idle_s: float = 0.0,
                   now: Optional[float] = None) -> int:
        """Spill entries idle for > ``max_idle_s`` back to pmem and drop
        them from DRAM (write-back for dirty ones). Returns the number of
        entries evicted. ``max_idle_s=0`` evicts everything."""
        now = now if now is not None else time.time()
        with self._lock:
            cold = [n for n, ts in self._last_used.items()
                    if now - ts >= max_idle_s]
            for name in cold:
                self._evict_one(name)
            return len(cold)

    def flush(self, name: Optional[str] = None) -> None:
        """Write back dirty entries — all of them, or just ``name`` (so a
        single-object persist doesn't rewrite the whole cache while
        holding the lock)."""
        with self._lock:
            targets = [name] if name is not None else list(self._cache)
            for n in targets:
                if self._dirty.get(n) and n in self._cache:
                    self.store.put(f"dlm/{n}", self._cache[n])
                    self._dirty[n] = False


class TieredKVCache:
    """Paged KV spill tier for serving: hot pages in DRAM (DLM-cached),
    cold pages in pmem — the long-context serving use of the paper's
    memory hierarchy (serve/engine.py)."""

    def __init__(self, store: PMemObjectStore, dram_capacity_bytes: int):
        self.cache = DLMCache(store, dram_capacity_bytes)

    @staticmethod
    def page_name(seq_id: int, layer: int, page: int) -> str:
        return f"kv/{seq_id}/{layer}/{page}"

    def put_page(self, seq_id: int, layer: int, page: int, kv) -> None:
        self.cache.put(self.page_name(seq_id, layer, page), kv)

    def get_page(self, seq_id: int, layer: int, page: int):
        return self.cache.get(self.page_name(seq_id, layer, page))

    def prefetch_page(self, seq_id: int, layer: int, page: int) -> bool:
        return self.cache.prefetch(self.page_name(seq_id, layer, page))

    def evict_cold(self, max_idle_s: float = 0.0) -> int:
        return self.cache.evict_cold(max_idle_s)

    @property
    def stats(self):
        return {"hits": self.cache.hits, "misses": self.cache.misses,
                "evictions": self.cache.evictions,
                "prefetches": self.cache.prefetches,
                "prefetch_hits": self.cache.prefetch_hits}
