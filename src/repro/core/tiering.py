"""SLM / DLM memory-mode policies (paper §II-B).

SLM (single-level): DRAM and B-APM are two explicit spaces. ``SLMTier``
places chosen pytree leaves in pmem and stages them in/out explicitly at
step boundaries — used for optimizer-state offload and cold KV pages.

DLM (dual-level): DRAM acts as a transparent cache over pmem. ``DLMCache``
is an LRU write-back cache keyed by object name — readers always use
``get``; eviction spills to pmem; nothing else changes for the caller.
The mode is selected per job by the workflow scheduler (paper §V-A item 9).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.object_store import PMemObjectStore, _flatten, _unflatten


class SLMTier:
    """Explicit two-space placement: leaves listed in ``pmem_leaves`` live
    in the pool; the rest stay in DRAM (the returned pytree)."""

    def __init__(self, store: PMemObjectStore, name: str):
        self.store = store
        self.name = name
        self._placed: Dict[str, int] = {}  # leaf path -> version counter

    def offload(self, tree, leaf_paths: Iterable[str]):
        """Move selected leaves to pmem; returns (resident_tree, handle).
        Offloaded leaves are replaced by None placeholders."""
        paths = set(leaf_paths)
        leaves = dict(_flatten(tree))
        off = {p: leaves[p] for p in paths if p in leaves}
        version = int(time.time() * 1e6) % (1 << 31)
        self.store.put(f"slm/{self.name}", off, version=0,
                       meta={"v": version})
        resident = {p: v for p, v in leaves.items() if p not in paths}
        self._placed = {p: version for p in off}
        return _unflatten(resident), sorted(off)

    def fetch(self, resident_tree, handle: List[str]):
        """Stage offloaded leaves back in and merge with the resident part."""
        off = dict(_flatten(self.store.get(f"slm/{self.name}")))
        leaves = dict(_flatten(resident_tree))
        leaves.update(off)
        return _unflatten(leaves)


class DLMCache:
    """LRU DRAM cache over a pmem object store (write-back)."""

    def __init__(self, store: PMemObjectStore, capacity_bytes: int):
        self.store = store
        self.capacity = capacity_bytes
        self._cache: "collections.OrderedDict[str, Any]" = \
            collections.OrderedDict()
        self._sizes: Dict[str, int] = {}
        self._dirty: Dict[str, bool] = {}
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def _bytes(self, tree) -> int:
        return sum(np.asarray(a).nbytes for _, a in _flatten(tree))

    def _evict_until_fits(self, incoming: int) -> None:
        while self._cache and \
                sum(self._sizes.values()) + incoming > self.capacity:
            name, tree = self._cache.popitem(last=False)
            if self._dirty.pop(name, False):
                self.store.put(f"dlm/{name}", tree)  # write-back
            self._sizes.pop(name)
            self.evictions += 1

    def put(self, name: str, tree) -> None:
        with self._lock:
            nb = self._bytes(tree)
            self._evict_until_fits(nb)
            self._cache[name] = tree
            self._cache.move_to_end(name)
            self._sizes[name] = nb
            self._dirty[name] = True

    def get(self, name: str):
        with self._lock:
            if name in self._cache:
                self.hits += 1
                self._cache.move_to_end(name)
                return self._cache[name]
            self.misses += 1
            tree = self.store.get(f"dlm/{name}")
            nb = self._bytes(tree)
            self._evict_until_fits(nb)
            self._cache[name] = tree
            self._sizes[name] = nb
            self._dirty[name] = False
            return tree

    def flush(self) -> None:
        with self._lock:
            for name, tree in self._cache.items():
                if self._dirty.get(name):
                    self.store.put(f"dlm/{name}", tree)
                    self._dirty[name] = False


class TieredKVCache:
    """Paged KV spill tier for serving: hot pages in DRAM (DLM-cached),
    cold pages in pmem — the long-context serving use of the paper's
    memory hierarchy (serve/engine.py)."""

    def __init__(self, store: PMemObjectStore, dram_capacity_bytes: int):
        self.cache = DLMCache(store, dram_capacity_bytes)

    def put_page(self, seq_id: int, layer: int, page: int, kv) -> None:
        self.cache.put(f"kv/{seq_id}/{layer}/{page}", kv)

    def get_page(self, seq_id: int, layer: int, page: int):
        return self.cache.get(f"kv/{seq_id}/{layer}/{page}")

    @property
    def stats(self):
        return {"hits": self.cache.hits, "misses": self.cache.misses,
                "evictions": self.cache.evictions}
