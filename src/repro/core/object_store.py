"""Versioned pytree object store over PMem pools (the paper's §V-C).

Objects are named, versioned pytrees of numpy/jax arrays. Every leaf is a
byte range in a pool region (byte-addressable: readers can map any slice of
any leaf without deserialization — this is what enables elastic checkpoint
resharding). A JSON manifest (committed atomically) indexes leaves with
shape/dtype/offset/crc. The store doubles as the node-local "filesystem on
B-APM" of §V-D; ``DistributedStore`` unions per-node stores into the
cross-node view.
"""
from __future__ import annotations

import time
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pmem import PMemPool


def _flatten(tree, prefix="") -> List[Tuple[str, np.ndarray]]:
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out += _flatten(tree[k], f"{prefix}{k}/")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out += _flatten(v, f"{prefix}{i}/")
    elif tree is None:
        pass
    else:
        out.append((prefix[:-1], np.asarray(tree)))
    return out


def content_digest(manifest: dict) -> str:
    """Content digest of an object from its manifest alone: the CRC32 of
    the sorted per-leaf ``path:crc`` pairs. Identical trees produce
    identical digests without re-reading a byte of data — the dataset
    exchange stamps this into lineage records so derived datasets can be
    audited against their recorded inputs."""
    acc = 0
    for path in sorted(manifest.get("leaves", {})):
        ent = manifest["leaves"][path]
        acc = zlib.crc32(f"{path}:{ent['crc']}".encode(), acc)
    return f"{acc & 0xFFFFFFFF:08x}"


def _unflatten(leaves: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for path, v in leaves.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


class PMemObjectStore:
    """One node's object store."""

    def __init__(self, pool: PMemPool):
        self.pool = pool

    # ---- write path ----
    def put(self, name: str, tree, version: int = 0,
            meta: Optional[dict] = None) -> dict:
        leaves = _flatten(tree)
        region_name = f"objects/{name}@v{version}.data"
        total = sum(a.nbytes for _, a in leaves)
        region = self.pool.create(region_name, max(total, 1))
        manifest = {"name": name, "version": version, "ts": time.time(),
                    "meta": meta or {}, "leaves": {}, "nbytes": total}
        off = 0
        for path, arr in leaves:
            region.write(off, arr)
            manifest["leaves"][path] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "offset": off, "nbytes": arr.nbytes,
                "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes())
                & 0xFFFFFFFF,
            }
            off += arr.nbytes
        region.flush()  # CLWB+SFENCE before the commit point
        # commit point: manifest rename is atomic
        self.pool.put_json(f"objects/{name}@v{version}.manifest", manifest)
        return manifest

    # ---- read path ----
    def manifest(self, name: str, version: int = 0) -> dict:
        return self.pool.get_json(f"objects/{name}@v{version}.manifest")

    def exists(self, name: str, version: int = 0) -> bool:
        return self.pool.exists(f"objects/{name}@v{version}.manifest")

    def get(self, name: str, version: int = 0, verify: bool = False):
        tree, _ = self.get_with_manifest(name, version, verify=verify)
        return tree

    def get_with_manifest(self, name: str, version: int = 0,
                          verify: bool = True):
        """Read (tree, manifest) against ONE manifest snapshot, CRC-
        verifying every leaf against it. A concurrent overwrite (e.g.
        checkpoint slot reuse racing a queued replicate) produces bytes
        that do not match this manifest's CRCs and raises IOError instead
        of returning torn or wrongly-tagged data."""
        man = self.manifest(name, version)
        region = self.pool.open(f"objects/{name}@v{version}.data")
        leaves = {}
        for path, ent in man["leaves"].items():
            arr = region.read(ent["offset"], ent["nbytes"],
                              dtype=np.dtype(ent["dtype"]),
                              shape=tuple(ent["shape"])).copy()
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes()) \
                    & 0xFFFFFFFF
                if crc != ent["crc"]:
                    raise IOError(f"crc mismatch for {name}:{path}")
            leaves[path] = arr
        return _unflatten(leaves), man

    def read_leaf_slice(self, name: str, leaf: str, start_row: int,
                        n_rows: int, version: int = 0) -> np.ndarray:
        """Byte-range read of rows [start_row, start_row+n_rows) of a leaf —
        the elastic-reshard primitive (no full-object deserialization)."""
        man = self.manifest(name, version)
        ent = man["leaves"][leaf]
        shape = tuple(ent["shape"])
        dtype = np.dtype(ent["dtype"])
        row_bytes = dtype.itemsize
        for d in shape[1:]:
            row_bytes *= d
        region = self.pool.open(f"objects/{name}@v{version}.data")
        return region.read(ent["offset"] + start_row * row_bytes,
                           n_rows * row_bytes, dtype=dtype,
                           shape=(n_rows,) + shape[1:]).copy()

    def nbytes_of(self, name: str, version: int = 0) -> int:
        """Object size from the manifest alone (no data reads) — feeds
        byte-weighted workflow placement."""
        return int(self.manifest(name, version).get("nbytes", 0))

    def delete(self, name: str, version: int = 0) -> None:
        self.pool.delete(f"objects/{name}@v{version}.manifest")
        self.pool.delete(f"objects/{name}@v{version}.data")

    def list_objects(self) -> List[Tuple[str, int]]:
        out = []
        for f in self.pool.list("objects/"):
            if f.endswith(".manifest"):
                base = f[len("objects/"):-len(".manifest")]
                name, _, v = base.rpartition("@v")
                out.append((name, int(v)))
        return sorted(out)


class DistributedStore:
    """Union view over per-node stores (the distributed B-APM filesystem)."""

    def __init__(self, stores: Dict[str, PMemObjectStore]):
        self.stores = stores

    def locate(self, name: str, version: int = 0) -> List[str]:
        return [nid for nid, st in self.stores.items()
                if st.exists(name, version)]

    def get(self, name: str, version: int = 0, prefer: Optional[str] = None):
        nodes = self.locate(name, version)
        if not nodes:
            raise KeyError(f"{name}@v{version} not on any node")
        nid = prefer if prefer in nodes else nodes[0]
        return self.stores[nid].get(name, version)

    def nbytes_of(self, name: str, version: int = 0) -> int:
        """Size of an object wherever it lives (0 when nowhere): the
        byte-weighted placement input for raw (non-catalog) objects."""
        for nid in self.locate(name, version):
            try:
                return self.stores[nid].nbytes_of(name, version)
            except (IOError, FileNotFoundError):
                continue
        return 0
